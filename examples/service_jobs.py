"""The service layer: sampling as asynchronous jobs.

    PYTHONPATH=src python examples/service_jobs.py

`SamplingService` turns the blocking `session.sample()` call into jobs —
exactly what the paper's macro-batch independence (batch = f(seed, id))
was made for.  This demo drives the whole API surface at laptop scale:

* submit two jobs against ONE store — they coalesce onto one session, so
  the second never recompiles;
* stream the first job's macro-batch blocks as they complete (each block
  is bit-identical to a one-shot `session.sample` with the same seed);
* cancel the second mid-queue;
* kill a worker lane mid-job and watch the elastic WorkQueue requeue its
  batch — the survivor recomputes the exact same samples.
"""
import os
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import api  # noqa: E402
from repro.core import mps as M  # noqa: E402
from repro.data.gamma_store import GammaStore  # noqa: E402


def main() -> None:
    # a 48-site chain on disk — the streamed data plane is the natural
    # serving substrate (the store is shared by every job)
    sites, chi, d = 48, 12, 3
    mps = M.gbs_like_mps(jax.random.key(0), sites, chi, d,
                         dtype=jnp.float64)
    root = os.path.join(tempfile.gettempdir(), "fastmps_service_demo")
    store = GammaStore(root, storage_dtype=jnp.float64,
                       compute_dtype=jnp.float64)
    if store.n_sites == 0:
        store.write_mps(mps)
    store.close()

    cfg = api.SamplerConfig(segment_len=12)
    key = jax.random.key(1)

    with api.SamplingService(workers=2) as svc:
        # job A: 4 macro batches, streamed back as they finish
        job_a = svc.submit(root, cfg, n_samples=1024, key=key,
                           macro_batches=4, priority=1)
        # job B: lower priority, then cancelled before it is scheduled
        job_b = svc.submit(root, cfg, n_samples=4096,
                           key=jax.random.key(2), macro_batches=8)
        print(f"submitted: job {job_a.job_id} (prio 1) and "
              f"job {job_b.job_id} (prio 0)")
        print("coalescing:", svc.stats())       # sessions: 1 — one plan

        job_b.cancel()
        print(f"job {job_b.job_id} cancelled:", job_b.status())

        # stream job A; block b is bit-identical to the one-shot
        # session.sample(256, fold_in(key, b)) — assert it live
        with api.SamplingSession(root, cfg) as ref_sess:
            for b, block in job_a.stream():
                ref = ref_sess.sample(256, api.batch_key(key, b, 4))
                assert np.array_equal(block, ref), f"batch {b} diverged!"
                p = job_a.progress
                print(f"  block {b}: {block.shape}, mean photons "
                      f"{block.mean():.3f}  [{p['done']}/{p['total']} done]")
        print("job A:", job_a.status())

        # elasticity: kill a lane mid-job; its batch requeues and the
        # surviving lane emits the exact same samples
        killed = []

        def kill_once(job, b, worker):
            if b == 1 and not killed:
                killed.append(worker)
                print(f"  killing lane {worker!r} holding batch {b}")
                svc.remove_worker(worker)

        svc.batch_hook = kill_once
        job_c = svc.submit(root, cfg, n_samples=512, key=jax.random.key(3),
                           macro_batches=4)
        samples = job_c.result()
        p = job_c.progress
        print(f"job C survived a worker loss: {samples.shape}, "
              f"requeues={p['requeues']}, lanes left={p['workers']}")
        with api.SamplingSession(root, cfg) as ref_sess:
            ref = np.concatenate(
                [ref_sess.sample(128, api.batch_key(jax.random.key(3), b, 4))
                 for b in range(4)], axis=0)
        assert np.array_equal(samples, ref), "kill/requeue changed samples!"
        print("post-kill samples bit-identical to the one-shot schedule ✓")


if __name__ == "__main__":
    main()
