"""Streaming large chains: sample an MPS that never fully enters device memory.

    PYTHONPATH=src python examples/streaming_chain.py

Walks the paper's §3.1/§3.3.2 pipeline end-to-end at laptop scale through
the unified API: write Γ to a bf16 on-disk store, let the session's planner
pick segment sizes from the perf model, stream the chain with
double-buffered prefetch, a mid-run "crash", and an exact resume — all
behind ``SamplingSession.sample``.
"""
import os
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import api  # noqa: E402
from repro.core import mps as M  # noqa: E402
from repro.data.gamma_store import GammaStore  # noqa: E402


def main() -> None:
    # 1. a 96-site chain, written site-by-site to disk (bf16 storage halves
    # the I/O bytes, §3.3.2; fp32 upcast happens on read)
    sites, chi, d, n = 96, 16, 3, 2_000
    mps = M.gbs_like_mps(jax.random.key(0), sites, chi, d,
                         dtype=jnp.float64).astype(jnp.float32)
    root = os.path.join(tempfile.gettempdir(), "fastmps_stream_demo")
    store = GammaStore(root, storage_dtype=jnp.bfloat16,
                       compute_dtype=jnp.float32)
    if store.n_sites == 0:
        store.write_mps(mps)

    # 2. one config drives everything: a GammaStore source auto-selects the
    # streamed backend, and segment_len=AUTO asks the perf model for the
    # largest segment whose two buffers fit the device budget
    ckpt = os.path.join(root, "ckpt")
    config = api.SamplerConfig(
        segment_len=api.AUTO,
        device_budget=(n * chi * (1 + d) * 4) / 0.9 + sites * chi * chi * d,
        checkpoint_dir=ckpt, checkpoint_every=1)
    key = jax.random.key(1)

    # 3. stream the chain — at most two Γ segments are device-resident,
    # segment k+1 loads while segment k contracts
    with api.SamplingSession(store, config) as session:
        print("plan:", session.plan(n))
        print("why:", session.explain(n))
        out = session.sample(n, key)
        st = session.stats
        print(f"streamed {out.shape} samples over {st['segments']} segments; "
              f"{st['io_hidden_frac']:.0%} of disk time hidden behind "
              f"compute; max {st['max_live_segments']} segments live")

    # 4. bit-identical to the all-in-memory scan over the same Γ (the
    # session's §4.1 contract; "same Γ" = after the bf16 storage roundtrip)
    g_rt, lam_rt = store.get_segment(0, sites, prefetch_next_segment=False)
    mps_rt = M.MPS(jnp.asarray(g_rt), jnp.asarray(lam_rt), "linear")
    with api.SamplingSession(mps_rt) as session:
        ref = session.sample(n, key)
    print("bit-identical to the in-memory backend:",
          bool(np.all(out == ref)))

    # 5. kill mid-chain, resume from the checkpoint — still bit-identical.
    # resume=True continues from the newest per-segment checkpoint; the
    # resumed run draws the exact randoms the uninterrupted one would have.
    crash_cfg = api.SamplerConfig(
        segment_len=16, checkpoint_dir=os.path.join(root, "ckpt_crash"),
        checkpoint_every=1)
    with api.SamplingSession(store, crash_cfg) as session:
        session.sample(n, key, stop_after_segments=2)    # "crash" at seg 2
        resumed = session.sample(n, key, resume=True)
    print("resumed run bit-identical:", bool(np.all(resumed == ref)))
    store.close()


if __name__ == "__main__":
    main()
