"""Streaming large chains: sample an MPS that never fully enters device memory.

    PYTHONPATH=src python examples/streaming_chain.py

Walks the paper's §3.1/§3.3.2 pipeline end-to-end at laptop scale: write Γ
to a bf16 on-disk store, plan segment/batch sizes from the perf model, and
stream the chain with double-buffered prefetch, a mid-run "crash", and an
exact resume.
"""
import os
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import mps as M  # noqa: E402
from repro.core import sampler as S  # noqa: E402
from repro.core.perfmodel import TPU_V5E, Workload  # noqa: E402
from repro.data.gamma_store import GammaStore  # noqa: E402
from repro.engine import (StreamPlan, StreamingEngine,  # noqa: E402
                          explain_plan, plan_stream)


def main() -> None:
    # 1. a 96-site chain, written site-by-site to disk (bf16 storage halves
    # the I/O bytes, §3.3.2; fp32 upcast happens on read)
    sites, chi, d, n = 96, 16, 3, 2_000
    mps = M.gbs_like_mps(jax.random.key(0), sites, chi, d,
                         dtype=jnp.float64).astype(jnp.float32)
    root = os.path.join(tempfile.gettempdir(), "fastmps_stream_demo")
    store = GammaStore(root, storage_dtype=jnp.bfloat16,
                       compute_dtype=jnp.float32)
    store.write_mps(mps)

    # 2. let the perf model pick the segment length for a tight memory budget
    w = Workload(n_samples=n, n_sites=sites, chi=chi, d=d,
                 macro_batch=n, micro_batch=n)
    plan = plan_stream(w, TPU_V5E, compute_bytes=4,
                       device_budget=(n * chi * (1 + d) * 4) / 0.9
                       + sites * chi * chi * d)
    print("plan:", plan)
    print("why:", explain_plan(plan, w, TPU_V5E, compute_bytes=4))

    # 3. stream the chain — at most two Γ segments are device-resident,
    # segment k+1 loads while segment k contracts
    ckpt = os.path.join(root, "ckpt")
    eng = StreamingEngine(store, plan=StreamPlan(
        segment_len=plan.segment_len, checkpoint_every=1),
        checkpoint_dir=ckpt)
    key = jax.random.key(1)
    out = eng.sample(n, key)
    st = eng.stats
    print(f"streamed {out.shape} samples over {st['segments']} segments; "
          f"{st['io_hidden_frac']:.0%} of disk time hidden behind compute; "
          f"max {st['max_live_segments']} segments live")

    # 4. bit-identical to the all-in-memory scan over the same Γ (the
    # engine's §4.1 contract; "same Γ" = after the bf16 storage roundtrip)
    g_rt, lam_rt = store.get_segment(0, sites, prefetch_next_segment=False)
    mps_rt = M.MPS(jnp.asarray(g_rt), jnp.asarray(lam_rt), "linear")
    ref = np.asarray(S.sample(mps_rt, n, key))
    print("bit-identical to in-memory sample():", bool(np.all(out == ref)))

    # 5. kill mid-chain, resume from the checkpoint — still bit-identical
    store2 = GammaStore(root, storage_dtype=jnp.bfloat16,
                        compute_dtype=jnp.float32)
    half = StreamingEngine(store2, plan=StreamPlan(
        segment_len=plan.segment_len, checkpoint_every=1),
        checkpoint_dir=os.path.join(root, "ckpt_crash"))
    half.sample(n, key, stop_after_segments=2)      # "crash" after 2 segments
    resumed = half.sample(n, key, resume=True)
    print("resumed run bit-identical:", bool(np.all(resumed == ref)))
    eng.close()
    half.close()


if __name__ == "__main__":
    main()
