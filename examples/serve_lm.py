"""Serve a small LM with batched requests (greedy decode over a KV cache).

The paper's own observation (§5) is that MPS sampling ≈ LM decode: batch of
independent samples ↔ batch of requests, left environment ↔ KV/SSM state.
This example serves the deepseek-7b *smoke* config with a batch of 8
requests, streaming tokens step by step.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import steps
from repro.models import transformer as T


def main() -> None:
    cfg = configs.get_smoke_config("deepseek-7b")
    params, _ = T.init_params(jax.random.key(0), cfg)
    serve = jax.jit(steps.make_serve_step(cfg), donate_argnums=(2,))

    batch_size, gen_len, cache_len = 8, 24, 64
    state = T.init_decode_state(cfg, batch_size, cache_len)
    tokens = jax.random.randint(jax.random.key(1), (batch_size, 1), 0,
                                cfg.vocab)

    print(f"serving {cfg.name}: batch={batch_size}, generating {gen_len} "
          f"tokens per request")
    t0 = time.perf_counter()
    generated = [tokens]
    for _ in range(gen_len):
        tokens, state = serve(params, {"tokens": tokens}, state)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    dt = time.perf_counter() - t0

    seqs = jnp.concatenate(generated, axis=1)
    print(f"generated {batch_size}×{gen_len} tokens in {dt:.2f}s "
          f"({batch_size * gen_len / dt:.0f} tok/s)")
    for i in range(min(3, batch_size)):
        print(f"request {i}: {list(map(int, seqs[i, :12]))} ...")


if __name__ == "__main__":
    main()
