"""Quickstart: build an MPS, sample through the unified API, validate.

    PYTHONPATH=src python examples/quickstart.py

``repro.api.SamplingSession`` is the one front door: the same
``session.sample(n, key)`` call serves every backend (in-memory /
streamed), placement (seq / DP / TP), and χ-mode — this example uses the
simplest cell (in-memory, sequential) and validates it against exact
enumeration.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import api  # noqa: E402
from repro.core import displacement as D  # noqa: E402
from repro.core import mps as M  # noqa: E402


def main() -> None:
    # 1. a random 6-site, χ=8, d=3 MPS with the paper's "linear" semantics
    mps = M.random_linear_mps(jax.random.key(0), n_sites=6, chi=8, d=3)

    # 2. draw 50k samples through the session (Fig. 1 + Alg. 1); plan()
    # shows how the config resolved (backend, scheme, batching)
    with api.SamplingSession(mps) as session:
        print("plan:", session.plan(50_000))
        samples = session.sample(50_000, jax.random.key(1))
    print(f"samples: {samples.shape}  (N, M) outcomes in [0, d)")

    # 3. validate: empirical joint vs exact enumeration
    probs = M.enumerate_probabilities(mps)
    idx = np.ravel_multi_index(np.asarray(samples).T, (3,) * 6)
    emp = np.bincount(idx, minlength=3 ** 6) / samples.shape[0]
    tv = 0.5 * np.abs(emp - probs).sum()
    print(f"total-variation distance to exact joint: {tv:.4f} "
          f"(sampling noise ~{np.sqrt(3 ** 6 / 50_000):.3f})")

    # 4. the paper's adaptive mixed precision: bf16 GEMMs + fp32 accumulate
    # draw the same outcomes as full fp32 for the vast majority of samples
    # — and critically, the *distribution* is preserved (per-sample scaling
    # keeps every row's dynamic range inside bf16's exponent budget).
    # Precision is one config field; nothing else changes.
    mps32 = mps.astype(jnp.float32)
    with api.SamplingSession(mps32) as session:
        base32 = session.sample(50_000, jax.random.key(1))
    with api.SamplingSession(
            mps32, api.SamplerConfig(compute_dtype=jnp.bfloat16)) as session:
        mx = session.sample(50_000, jax.random.key(1))
    agree = float(np.mean(np.all(mx == base32, axis=1)))
    print(f"bf16-MXU draws identical to fp32 draws: {agree:.1%} of samples")
    idx_mx = np.ravel_multi_index(np.asarray(mx).T, (3,) * 6)
    emp_mx = np.bincount(idx_mx, minlength=3 ** 6) / mx.shape[0]
    print(f"bf16 path TV distance to exact joint: "
          f"{0.5 * np.abs(emp_mx - probs).sum():.4f}")

    # 5. GBS displacement via the Zassenhaus triangular split (§3.4.1)
    mu = (0.3 * jax.random.normal(jax.random.key(2), (4,))
          + 0.3j * jax.random.normal(jax.random.key(3), (4,)))
    dz = D.displacement_zassenhaus(mu.astype(jnp.complex128), d=6)
    de = D.displacement_exact(mu.astype(jnp.complex128), d=6)
    err = float(jnp.max(jnp.abs(dz[:, :3, :3] - de[:, :3, :3])))
    print(f"displacement triangular-split error (low Fock block): {err:.2e}")


if __name__ == "__main__":
    main()
