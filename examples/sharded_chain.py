"""Chain-sharded data plane (ROADMAP item 3): block-cyclic Γ, env handoff.

    PYTHONPATH=src python examples/sharded_chain.py

The §3.1 broadcast plane (examples/multihost_broadcast.py) scales the
*reads* — one process reads each Γ segment, the rest receive it over the
wire — but every host still holds, and pays wire bytes for, the whole
chain: O(hosts × chain).  Chain sharding is the third axis: the chain's
site *blocks* are dealt block-cyclically across hosts
(``owner(site) = (site // block) % hosts``), each host reads ONLY its own
blocks from its own slice of the store, and what crosses the interconnect
is the tiny (N, χ) sampling environment at each ownership boundary — plus
one final sample gather — O(chain), independent of Γ size.  This example
runs that wiring on an emulated 3-process cluster and shows:

* per-host store I/O proportional to owned sites (capacity and bandwidth
  scale with hosts), zero broadcast bytes;
* env handoffs orders of magnitude smaller than the Γ bytes they replace;
* every host emits samples bit-identical to a plain single-process
  ``runtime="local"`` unsharded run (the §4.1 contract, extended).
"""
import tempfile
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import mps as M
from repro.data.gamma_store import GammaStore
from repro.shard import ShardMap, chain_segments

HOSTS, SITES, CHI, D, N, SEG = 3, 48, 32, 3, 256, 8


def main() -> None:
    mps = M.gbs_like_mps(jax.random.key(0), SITES, CHI, D,
                         dtype=jnp.float32)
    root = tempfile.mkdtemp(prefix="fastmps_shard_demo_")
    with GammaStore(root, storage_dtype=jnp.bfloat16,
                    compute_dtype=jnp.float32) as store:
        store.write_mps(mps)
    key = jax.random.key(1)

    # reference: single-process local streaming, unsharded
    with api.SamplingSession(
            root, api.SamplerConfig(segment_len=SEG)) as session:
        ref = session.sample(N, key)
        local_bytes = session.stats["io_bytes"]
    print(f"local run: {ref.shape} samples, {local_bytes/1e6:.2f} MB "
          f"read from the Γ store")

    # the wire plan, straight from the ownership algebra
    smap = ShardMap(n_sites=SITES, n_hosts=HOSTS, block=SEG)
    sched = chain_segments(SITES, SEG)
    print(f"block-cyclic plan: {smap.n_blocks} blocks × {SEG} sites over "
          f"{HOSTS} hosts, {len(smap.handoffs(sched))} env handoffs")

    cluster = api.emulated_cluster(HOSTS)
    outs, stats = {}, {}

    def drive(runtime):
        config = api.SamplerConfig(backend="streamed", runtime=runtime,
                                   segment_len=SEG, shard="auto")
        with api.SamplingSession(root, config) as session:
            outs[runtime.process_index] = session.sample(N, key)
            stats[runtime.process_index] = dict(session.stats)

    threads = [threading.Thread(target=drive, args=(rt,)) for rt in cluster]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)

    for p in range(HOSTS):
        st = stats[p]
        owned = len(smap.owned_sites(p))
        print(f"host {p}: owns {owned}/{SITES} sites — store reads "
              f"{st['io_bytes']/1e6:.2f} MB, broadcast "
              f"{st['broadcast_recv_bytes']} B, env handoffs "
              f"{st['handoffs']} ({(st['handoff_send_bytes'] + st['handoff_recv_bytes'])/1e3:.1f} kB), "
              f"sample gather {st['gather_bytes']/1e3:.1f} kB")
        assert st["io_bytes"] == local_bytes * owned // SITES
        assert st["broadcast_recv_bytes"] == 0

    total_handoff = sum(st["handoff_send_bytes"] for st in stats.values())
    print(f"Γ bytes replaced by handoffs: {local_bytes*(HOSTS-1)/1e6:.2f} MB "
          f"broadcast → {total_handoff/1e6:.3f} MB env traffic")

    same = all(np.array_equal(outs[p], ref) for p in range(HOSTS))
    print("bit-identical to the local unsharded run on every host:",
          bool(same))
    assert same


if __name__ == "__main__":
    main()
