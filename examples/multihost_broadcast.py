"""Multi-host Γ broadcast (paper §3.1): one reader, N samplers.

    PYTHONPATH=src python examples/multihost_broadcast.py

The paper's scaling claim lives here: when p processes data-parallel-sample
the same chain, having every process read its own Γ from storage multiplies
the I/O bill by p — process 0 should read each segment ONCE and broadcast
it over the interconnect.  This example runs that wiring at laptop scale on
an *emulated* 2-process cluster (`api.emulated_cluster` — the exact
engine/session code path a `jax.distributed` launch takes, with an
in-process fabric standing in for the network):

* both "processes" stream the chain through
  ``SamplerConfig(backend="streamed", runtime=<cluster member>)``;
* only process 0's GammaStore counters move — process 1's segment bytes all
  arrive via ``broadcast_recv_bytes``;
* the wire carries the store's *storage format* (bf16 here — §3.3.2's
  compression halves the broadcast exactly as it halves disk reads);
* both processes emit samples bit-identical to a plain single-process
  ``runtime="local"`` run (§4.1 extended across the interconnect).
"""
import tempfile
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import mps as M
from repro.data.gamma_store import GammaStore


def main() -> None:
    sites, chi, d, n = 48, 16, 3, 512
    mps = M.gbs_like_mps(jax.random.key(0), sites, chi, d,
                         dtype=jnp.float32)
    root = tempfile.mkdtemp(prefix="fastmps_mh_demo_")
    with GammaStore(root, storage_dtype=jnp.bfloat16,
                    compute_dtype=jnp.float32) as store:
        store.write_mps(mps)
    key = jax.random.key(1)

    # reference: single-process local streaming (today's default)
    with api.SamplingSession(
            root, api.SamplerConfig(segment_len=8)) as session:
        ref = session.sample(n, key)
        local_bytes = session.stats["io_bytes"]
    print(f"local run: {ref.shape} samples, {local_bytes/1e6:.2f} MB "
          f"read from the Γ store")

    # the same walk on an emulated 2-process cluster: one driver per
    # "host", exactly like a real multi-process launch
    cluster = api.emulated_cluster(2)
    outs, stats = {}, {}

    def drive(runtime):
        config = api.SamplerConfig(backend="streamed", runtime=runtime,
                                   segment_len=8)
        with api.SamplingSession(root, config, mesh=None) as session:
            outs[runtime.process_index] = session.sample(n, key)
            stats[runtime.process_index] = dict(session.stats)

    threads = [threading.Thread(target=drive, args=(rt,)) for rt in cluster]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)

    for p in (0, 1):
        st = stats[p]
        print(f"process {p}: store reads {st['io_bytes']/1e6:.2f} MB, "
              f"broadcast sent {st['broadcast_send_bytes']/1e6:.2f} MB, "
              f"received {st['broadcast_recv_bytes']/1e6:.2f} MB")
    assert stats[0]["io_bytes"] == local_bytes      # root reads once
    assert stats[1]["io_bytes"] == 0                # peers never touch disk
    print("one reader, N samplers: only process 0 touched the GammaStore")

    same = (np.array_equal(outs[0], ref) and np.array_equal(outs[1], ref))
    print("bit-identical to the local run on every process:", bool(same))
    assert same


if __name__ == "__main__":
    main()
