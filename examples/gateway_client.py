"""Pure-stdlib client for the FastMPS sampling gateway.

No repro import, no third-party packages — ``http.client`` + the frame
protocol re-derived from its spec (8-byte big-endian length prefix; npy
block payloads), so any process with Python can consume the gateway.

Submit a job, stream its blocks, save the concatenated samples:

  python examples/gateway_client.py --url http://127.0.0.1:8752 \
      --store /tmp/gw_demo --samples 64 --seed 7 --macro-batches 4 \
      --api-key alice-key --config '{"segment_len": 4}' --out samples.npy

Or just poke the server:

  python examples/gateway_client.py --url ... --stats
"""
from __future__ import annotations

import argparse
import io
import json
import struct
import sys
import urllib.parse
from http.client import HTTPConnection

_LEN = struct.Struct(">Q")     # the gateway's frame prefix (PR 6 codec)


def _read_exact(resp, n: int) -> bytes:
    """A chunked HTTPResponse's read(n) may return short — loop it."""
    out = b""
    while len(out) < n:
        chunk = resp.read(n - len(out))
        if not chunk:
            raise ConnectionError("stream closed mid-frame")
        out += chunk
    return out


def read_frame(resp) -> bytes:
    (n,) = _LEN.unpack(_read_exact(resp, _LEN.size))
    return _read_exact(resp, n)


def _connect(url: str) -> tuple[HTTPConnection, str]:
    u = urllib.parse.urlparse(url)
    return HTTPConnection(u.hostname, u.port or 80), u.path.rstrip("/")


def _request(conn, method, path, body=None, api_key=None):
    headers = {"Content-Type": "application/json"}
    if api_key:
        headers["x-api-key"] = api_key
    conn.request(method, path,
                 None if body is None else json.dumps(body), headers)
    resp = conn.getresponse()
    payload = json.loads(resp.read() or b"{}")
    if resp.status >= 400:
        raise SystemExit(f"HTTP {resp.status}: {payload.get('error')}"
                         + (f" (Retry-After: {resp.getheader('Retry-After')})"
                            if resp.status == 429 else ""))
    return payload


def stream_blocks(conn, base: str, job_id: str, api_key=None):
    """Yield (batch_id, np-like array) per streamed block.  Loads npy
    payloads via a minimal header parse so numpy stays optional; with
    numpy installed the real ``np.load`` is used."""
    headers = {"x-api-key": api_key} if api_key else {}
    conn.request("GET", f"{base}/v1/jobs/{job_id}/stream", None, headers)
    resp = conn.getresponse()
    if resp.status != 200:
        raise SystemExit(f"HTTP {resp.status}: {resp.read()[:200]}")
    while True:
        head = json.loads(read_frame(resp))
        if head["kind"] == "block":
            yield head["batch_id"], read_frame(resp)
        elif head["kind"] == "end":
            resp.read()        # drain the chunked terminator (keep-alive)
            return
        else:
            raise SystemExit(f"server error: {head.get('error')}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--url", required=True, help="gateway base URL")
    ap.add_argument("--api-key", default=None)
    ap.add_argument("--store", help="GammaStore: a name under the "
                    "gateway's --store-root, or a server-side path in "
                    "trusted mode")
    ap.add_argument("--samples", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--macro-batches", type=int, default=1)
    ap.add_argument("--config", default="{}",
                    help="JSON SamplerConfig overrides")
    ap.add_argument("--out", default=None, help="write samples here (.npy)")
    ap.add_argument("--stats", action="store_true",
                    help="print /v1/stats and exit")
    args = ap.parse_args(argv)

    conn, base = _connect(args.url)
    if args.stats:
        print(json.dumps(_request(conn, "GET", f"{base}/v1/stats"), indent=2))
        return 0
    if not args.store:
        ap.error("--store is required to submit")

    sub = _request(conn, "POST", f"{base}/v1/jobs",
                   {"store": args.store, "n_samples": args.samples,
                    "seed": args.seed, "macro_batches": args.macro_batches,
                    "config": json.loads(args.config)},
                   api_key=args.api_key)
    print(f"job {sub['id']}: cache={sub['cache']} state={sub['state']}")

    try:
        import numpy as np
    except ImportError:
        np = None
    frames = []
    for batch_id, frame in stream_blocks(conn, base, sub["id"],
                                         api_key=args.api_key):
        print(f"  block {batch_id}: {len(frame)} bytes")
        frames.append(frame)
    status = _request(conn, "GET", f"{base}/v1/jobs/{sub['id']}",
                      api_key=args.api_key)
    print(f"job {sub['id']}: state={status['state']} "
          f"blocks={status['blocks_done']}/{status['n_batches']}")
    if np is not None:
        blocks = [np.load(io.BytesIO(f), allow_pickle=False) for f in frames]
        samples = np.concatenate(blocks, axis=0)
        print(f"samples: shape={samples.shape} dtype={samples.dtype}")
        if args.out:
            np.save(args.out, samples)
            print(f"wrote {args.out}")
    elif args.out:
        with open(args.out, "wb") as f:   # raw npy bytes of block 0 only
            f.write(frames[0])
    return 0


if __name__ == "__main__":
    sys.exit(main())
