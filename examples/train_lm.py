"""Train a ~100M-parameter dense LM end-to-end (the training driver demo).

Uses a granite-family config scaled to ~100M params and the full driver
stack: sharding policy, AdamW + cosine schedule, deterministic data stream,
atomic checkpointing with auto-resume.  A few hundred steps on CPU takes a
while — pass --steps 30 for a quick look; the defaults are the real thing.

    PYTHONPATH=src python examples/train_lm.py --steps 30
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.data.tokens import synthetic_token_stream
from repro.launch import steps as steps_mod
from repro.models.transformer import ModelConfig, init_params
from repro.optim import optimizers, schedule

# ~103M params: 12 layers, d_model 768, 12 heads, ffn 2048, vocab 32k
CFG100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, d_ff=2048, vocab=32_000, remat_policy="none",
    dtype=jnp.float32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/lm100m_ckpt")
    args = ap.parse_args()

    total, _ = CFG100M.param_count()
    print(f"model: {CFG100M.name}  params: {total / 1e6:.0f}M")

    params, _ = init_params(jax.random.key(0), CFG100M)
    opt = optimizers.adamw(schedule.cosine_schedule(
        3e-4, warmup=args.steps // 10, total=args.steps))
    opt_state = opt.init(params)
    start = 0
    if store.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start, _ = store.load_checkpoint(
            args.ckpt_dir, (params, opt_state))
        print(f"resumed from step {start}")

    step_fn = jax.jit(steps_mod.make_train_step(CFG100M, opt),
                      donate_argnums=(0, 1))
    batch_at = synthetic_token_stream(0, CFG100M.vocab, args.batch, args.seq)

    t0 = time.perf_counter()
    for step in range(start, args.steps):
        params, opt_state, m = step_fn(params, opt_state, batch_at(step % 8))
        if (step + 1) % 10 == 0 or step == start:
            tok_s = args.batch * args.seq * (step + 1 - start) / (
                time.perf_counter() - t0)
            print(f"step {step + 1:4d}  loss {float(m['loss']):7.4f}  "
                  f"{tok_s:7.0f} tok/s", flush=True)
        if (step + 1) % 50 == 0:
            store.save_checkpoint(args.ckpt_dir, step + 1,
                                  (params, opt_state))
    print("done")


if __name__ == "__main__":
    main()
