"""Conditional (clamped) sampling: exact, rejection-free conditioning.

The workloads subsystem (``repro.workloads``) lets any sampling run pin a
subset of sites to fixed outcomes.  Because every site's uniform draw is
an independent ``fold_in(base_key, site)`` (paper §4.1), forcing site i
through the normal collapse path changes *nothing* about the other
sites' draws — the clamped walk samples exactly from
``P(free sites | clamped sites)`` with zero rejected samples, and the
per-sample ``log_prob`` it returns is the exact Born weight
``ln P(clamped outcomes | sampled prefix)`` of the clamped branch.

This script shows the three things you can do with that:

1. condition a generative model on observed sites and read off the
   posterior marginals of the rest;
2. estimate the probability of the clamped event itself (``E[exp
   log_prob] = P(clamp)``) — compared against the exact joint here;
3. score fully-specified outcomes: clamping *every* site turns the
   sampler into an exact likelihood evaluator (``log_prob`` = log joint).

Run:  PYTHONPATH=src python examples/conditional_sampling.py
"""
import itertools

import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

from repro import api                              # noqa: E402
from repro.core import mps as M                    # noqa: E402

SITES, CHI, D, N = 6, 4, 3, 4000
CLAMP = {2: 1, 4: 0}                               # pin site 2 → 1, site 4 → 0


def main() -> None:
    mps = M.random_linear_mps(jax.random.key(0), SITES, CHI, D)

    # -- 1. posterior marginals of the free sites --------------------------
    config = api.SamplerConfig(clamp=CLAMP)
    with api.SamplingSession(mps, config) as session:
        samples = session.sample(N, jax.random.key(1))
        log_prob = session.stats["log_prob"]       # (N,) ln P(clamp | prefix)
    samples = np.asarray(samples)
    assert all(np.all(samples[:, s] == v) for s, v in CLAMP.items())

    # exact conditionals by brute-force joint restriction (small chain)
    joint = M.enumerate_probabilities(mps)
    outs = np.array(list(itertools.product(range(D), repeat=SITES)))
    sel = np.all([outs[:, s] == v for s, v in CLAMP.items()], axis=0)
    cond = joint[sel] / joint[sel].sum()
    outs_c = outs[sel]

    w = np.exp(np.asarray(log_prob, dtype=np.float64))
    print(f"conditioned on {CLAMP}:  (estimate vs exact)")
    for i in range(SITES):
        if i in CLAMP:
            continue
        est = [float(w[samples[:, i] == s].sum() / w.sum())
               for s in range(D)]
        exact = [float(cond[outs_c[:, i] == s].sum()) for s in range(D)]
        pairs = "  ".join(f"{e:.3f}/{x:.3f}" for e, x in zip(est, exact))
        print(f"  site {i}: {pairs}")

    # -- 2. the clamp marginal from the weights ----------------------------
    p_exact = float(joint[sel].sum())
    print(f"P(clamp): estimated {w.mean():.5f}  exact {p_exact:.5f}")

    # -- 3. full clamp = exact likelihood evaluation -----------------------
    outcome = tuple(int(x) for x in samples[0])    # score one drawn config
    config = api.SamplerConfig(clamp=dict(enumerate(outcome)))
    with api.SamplingSession(mps, config) as session:
        session.sample(1, jax.random.key(2))
        lp = float(session.stats["log_prob"][0])
    exact_lp = float(np.log(joint[np.ravel_multi_index(outcome,
                                                       (D,) * SITES)]))
    print(f"log P{outcome}: clamped walk {lp:.8f}  joint {exact_lp:.8f}")
    assert abs(lp - exact_lp) < 1e-8


if __name__ == "__main__":
    main()
