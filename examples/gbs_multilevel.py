"""Multi-level parallel GBS sampling: DP × TP on an 8-device mesh.

Demonstrates the paper's core contribution — data parallelism over samples
combined with tensor parallelism over the bond dimension — plus dynamic
bond dimensions and mid-run checkpointing.  Forces 8 host devices, so run
it as a standalone script (not under pytest):

    PYTHONPATH=src python examples/gbs_multilevel.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import dynamic_bond as DB  # noqa: E402
from repro.core import mps as M  # noqa: E402
from repro.core import parallel as PP  # noqa: E402
from repro.core import sampler as S  # noqa: E402
from repro.core.perfmodel import TPU_V5E, Workload, choose_tp_scheme  # noqa: E402


def main() -> None:
    sites, chi, d, n = 16, 64, 3, 1024
    mps = M.gbs_like_mps(jax.random.key(0), sites, chi, d)
    key = jax.random.key(1)

    # 2 data groups × 4-way tensor parallel over χ (paper Fig. 4)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)}")

    # Eq. 7 picks the TP schedule for the hardware profile
    w = Workload(n_samples=n, n_sites=sites, chi=chi, d=d, micro_batch=n // 2)
    scheme = "tp_" + choose_tp_scheme(w, TPU_V5E, p2=4)
    print(f"Eq. 7 schedule choice for v5e: {scheme}")

    out_tp = PP.multilevel_sample(mesh, mps, n, key,
                                  PP.ParallelConfig(scheme), S.SamplerConfig())
    out_dp = PP.multilevel_sample(mesh, mps, n, key,
                                  PP.ParallelConfig("dp"), S.SamplerConfig())
    print(f"TP ({scheme}) == pure DP samples: {bool(jnp.all(out_tp == out_dp))}")

    # dynamic bond dimensions (§3.4.2): the Table 1 accounting
    prof = DB.area_law_profile(sites, chi, n_photon=1.0)
    buck = DB.bucketize(prof, [16, 32, 64])
    print("Table-1 metrics:", {k: round(v, 3) for k, v in
                               DB.table1_metrics(prof, chi).items()})
    staged = DB.sample_staged(mps, buck, n, key)
    print(f"staged sampler output: {staged.shape}")

    # per-site mean photon number (the Fig. 6-style diagnostic)
    mean_photon = np.asarray(out_tp).mean(axis=0)
    print(f"mean photons/site: min {mean_photon.min():.3f} "
          f"max {mean_photon.max():.3f} (edges lower — area law)")


if __name__ == "__main__":
    main()
