"""Multi-level parallel GBS sampling: DP × TP on an 8-device mesh.

Demonstrates the paper's core contribution — data parallelism over samples
combined with tensor parallelism over the bond dimension — plus dynamic
bond dimensions, all through the one :class:`repro.api.SamplingSession`
front door.  Forces 8 host devices, so run it as a standalone script (not
under pytest):

    PYTHONPATH=src python examples/gbs_multilevel.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro import api  # noqa: E402
from repro.core import dynamic_bond as DB  # noqa: E402
from repro.core import mps as M  # noqa: E402


def main() -> None:
    sites, chi, d, n = 16, 64, 3, 1024
    mps = M.gbs_like_mps(jax.random.key(0), sites, chi, d)
    key = jax.random.key(1)

    # 2 data groups × 4-way tensor parallel over χ (paper Fig. 4)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)}")

    # scheme=AUTO lets the Eq. 7 overhead selector pick single- vs
    # double-site TP for the configured hardware profile
    with api.SamplingSession(mps, mesh=mesh) as session:
        plan = session.plan(n)
        print(f"Eq. 7 schedule choice for v5e: {plan.scheme} "
              f"(p1={plan.p1}, p2={plan.p2})")
        out_tp = session.sample(n, key)

    # every schedule draws the same randoms per site: pure DP from the same
    # seed is bit-identical (paper §4.1 seed consistency)
    with api.SamplingSession(mps, api.SamplerConfig(scheme="dp"),
                             mesh=mesh) as session:
        out_dp = session.sample(n, key)
    print(f"TP == pure DP samples: {bool(np.all(out_tp == out_dp))}")

    # dynamic bond dimensions (§3.4.2): the Table 1 accounting, then the
    # same DP×TP session with a bucketed per-site χ profile
    prof = DB.area_law_profile(sites, chi, n_photon=1.0)
    buck = DB.bucketize(prof, [16, 32, 64])
    print("Table-1 metrics:", {k: round(v, 3) for k, v in
                               DB.table1_metrics(prof, chi).items()})
    # (tp_single: any χ-stage boundary works; tp_double additionally needs
    # even-aligned stages so site pairs never straddle a χ transition)
    with api.SamplingSession(
            mps, api.SamplerConfig(scheme="tp_single",
                                   chi_profile=tuple(int(c) for c in buck)),
            mesh=mesh) as session:
        staged = session.sample(n, key)
        print(f"staged sampler output: {staged.shape} "
              f"({session.plan(n).scheme} over "
              f"{len(session.plan(n).stages)} χ-stages)")

    # per-site mean photon number (the Fig. 6-style diagnostic)
    mean_photon = np.asarray(out_tp).mean(axis=0)
    print(f"mean photons/site: min {mean_photon.min():.3f} "
          f"max {mean_photon.max():.3f} (edges lower — area law)")


if __name__ == "__main__":
    main()
