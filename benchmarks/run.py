"""Benchmark harness: one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME]
Prints ``name,us_per_call,derived`` CSV (benchmarks/common.py).
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import header

MODULES = [
    ("fig10_sweeps", "benchmarks.bench_sweeps"),
    ("fig11_ablation", "benchmarks.bench_ablation"),
    ("fig5_6_precision", "benchmarks.bench_precision"),
    ("table1_dynamic_bond", "benchmarks.bench_dynamic_bond"),
    ("fig12_scaling", "benchmarks.bench_scaling"),
    ("fig13_eq7_tensor_parallel", "benchmarks.bench_tensor_parallel"),
    ("table2_3_vs_baseline", "benchmarks.bench_vs_baseline"),
    ("roofline_site_kernel", "benchmarks.bench_roofline"),
    ("site_step_fusion", "benchmarks.bench_site_step"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    header()
    failures = []
    for name, module in MODULES:
        if args.only and args.only not in name:
            continue
        print(f"# --- {name} ({module}) ---", flush=True)
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
        except Exception:                                  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}", flush=True)
        sys.exit(1)
    print("# all benchmarks completed", flush=True)


if __name__ == "__main__":
    main()
