"""Workloads bench: clamping overhead, ingest throughput, scenario scores.

Three paper-facing numbers for the workloads subsystem (PR 10):

* **clamp overhead** — clamped vs unclamped sampling throughput on the
  same chain/seed.  The clamped walk adds one `where` + one gathered
  log per site, so the ratio should sit near 1.0; a drop means the
  conditional path stopped sharing the unclamped arithmetic.
* **ingest throughput** — BYO-MPS ingest MB/s end to end (validate →
  QR canonicalize → embed → store write + digest manifest).
* **scenario scores** — each registered scenario's score + wall time,
  so eval-harness quality rides the same BENCH.json trajectory as perf.

Usage:
  PYTHONPATH=src:. python benchmarks/bench_workloads.py [--smoke] [--json P]
"""
from __future__ import annotations

import argparse
import shutil
import tempfile
import time

import jax
import numpy as np

import common
from repro import api
from repro.core import mps as M
from repro.workloads import ingest as IG
from repro.workloads import scenarios as SC


def _throughput(mps, n: int, key, clamp=None) -> float:
    """Samples/s through the session front door (median of 3)."""
    config = api.SamplerConfig(clamp=clamp)
    with api.SamplingSession(mps, config) as session:
        def run():
            return session.sample(n, key)
        seconds = common.time_fn(run, warmup=1, iters=3)
    return n / seconds


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sites", type=int, default=0)
    ap.add_argument("--chi", type=int, default=0)
    ap.add_argument("--samples", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="BENCH trajectory file ('' disables; default: "
                         "benchmarks/BENCH.json for full runs, disabled "
                         "for --smoke)")
    args = ap.parse_args()
    json_path = (args.json if args.json is not None
                 else ("" if args.smoke else common.BENCH_JSON))

    sites = args.sites or (16 if args.smoke else 64)
    chi = args.chi or (8 if args.smoke else 32)
    n = args.samples or (256 if args.smoke else 2048)
    d = 3

    common.header()

    # -- clamp overhead ------------------------------------------------------
    mps = M.gbs_like_mps(jax.random.key(0), sites, chi, d)
    key = jax.random.key(1)
    clamp = {sites // 3: 1, (2 * sites) // 3: 0}
    free_sps = _throughput(mps, n, key)
    clamped_sps = _throughput(mps, n, key, clamp=clamp)
    overhead = free_sps / clamped_sps
    common.emit("unclamped_samples_per_s", 1.0 / free_sps, f"{free_sps:.0f}")
    common.emit("clamped_samples_per_s", 1.0 / clamped_sps,
                f"{clamped_sps:.0f}")
    common.emit("clamp_overhead_x", 0.0, f"{overhead:.3f}")

    # -- ingest throughput ---------------------------------------------------
    ing_sites = sites
    ing_chi = chi
    rng = np.random.default_rng(0)
    dims = [1] + [ing_chi] * (ing_sites - 1) + [1]
    tensors = [rng.normal(size=(dims[i], dims[i + 1], 2))
               + 1j * rng.normal(size=(dims[i], dims[i + 1], 2))
               for i in range(ing_sites)]
    in_bytes = sum(t.nbytes for t in tensors)
    root = tempfile.mkdtemp(prefix="bench_ingest_")
    try:
        t0 = time.perf_counter()
        store, report = IG.ingest_mps(tensors, root, semantics="born")
        ingest_s = time.perf_counter() - t0
        store.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)
    ingest_mb_s = in_bytes / 1e6 / ingest_s
    common.emit("ingest", ingest_s, f"{ingest_mb_s:.1f}MB/s")

    # -- scenarios -----------------------------------------------------------
    scen_cfg = SC.ScenarioConfig(
        n_samples=(500 if args.smoke else 4000), json_path="")
    scenarios = {}
    for name in SC.available_scenarios():
        result = SC.run_scenario(name, scen_cfg)
        scenarios[name] = {"passed": result.passed,
                           "score": round(result.score, 6),
                           "wall_s": round(result.wall_s, 3)}
        common.emit(f"scenario_{name}", result.wall_s,
                    f"{'PASS' if result.passed else 'FAIL'}:"
                    f"{result.score:.4g}")

    common.append_bench_record(
        json_path, "workloads",
        {"sites": sites, "chi": chi, "d": d, "n_samples": n,
         "clamp": sorted(clamp.items()), "smoke": bool(args.smoke)},
        unclamped_samples_per_s=round(free_sps, 1),
        clamped_samples_per_s=round(clamped_sps, 1),
        clamp_overhead_x=round(overhead, 4),
        ingest_mb_per_s=round(ingest_mb_s, 2),
        ingest_max_isometry_error=report.max_isometry_error,
        scenarios=scenarios)


if __name__ == "__main__":
    main()
