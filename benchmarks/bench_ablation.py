"""Fig. 11 — ablation: mixed precision / optimized expm / dynamic χ.

derived = speedup of the fully-optimized configuration over the
configuration with that one optimization removed (the paper's bar chart).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import displacement as D
from repro.core import dynamic_bond as DB
from repro.core import mps as M
from repro.core import sampler as S

CHI, SITES, D_PHYS, N = 512, 16, 3, 4096


def _chain_time(mps, cfg: S.SamplerConfig) -> float:
    state = S.init_state(mps, N, jax.random.key(1), cfg)
    fn = jax.jit(lambda m, s: S.sample_chain(m, s, cfg).samples,
                 static_argnames=())
    return time_fn(fn, mps, state)


def run(quick: bool = True) -> None:
    mps32 = M.gbs_like_mps(jax.random.key(0), SITES, CHI, D_PHYS,
                           dtype=jnp.float64).astype(jnp.float32)

    # fully optimized: bf16 GEMM + per-sample scaling + dynamic χ
    full_cfg = S.SamplerConfig(compute_dtype=jnp.bfloat16)
    prof = DB.area_law_profile(SITES, CHI, n_photon=1.0)
    buck = DB.bucketize(prof, [CHI // 4, CHI // 2, CHI])

    def staged():
        return DB.sample_staged(mps32, buck, N, jax.random.key(2), full_cfg)

    t_full = time_fn(staged)

    # − mixed precision (fp64 everything, the paper's FP64 fallback)
    mps64 = mps32.astype(jnp.float64)

    def staged64():
        return DB.sample_staged(mps64, buck, N, jax.random.key(2),
                                S.SamplerConfig())

    t_nomix = time_fn(staged64)
    emit("fig11_no_mixed_precision", t_nomix, f"{t_nomix / t_full:.2f}x")

    # − dynamic χ (uniform χ chain, optimized numerics)
    t_nodyn = _chain_time(mps32, full_cfg)
    emit("fig11_no_dynamic_bond", t_nodyn, f"{t_nodyn / t_full:.2f}x")

    # − optimized expm (exact scaling-and-squaring vs Zassenhaus), measured
    # on the displacement alone (it is additive in the GBS pipeline)
    mu = (0.3 * jax.random.normal(jax.random.key(3), (N,))
          + 0.3j * jax.random.normal(jax.random.key(4), (N,))).astype(jnp.complex128)
    t_zass = time_fn(jax.jit(lambda m: D.displacement_zassenhaus(m, 10)), mu)
    t_exact = time_fn(jax.jit(lambda m: D.displacement_exact(m, 10)), mu)
    emit("fig11_no_expm_opt_displacement_only", t_exact,
         f"{t_exact / t_zass:.2f}x")

    emit("fig11_fully_optimized", t_full, "1.00x")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
