"""Fig. 10 — time vs χ, d, micro-batch N (CPU-scaled).

The paper's three sweeps on a single A100; here one CPU device, scaled χ.
derived = GFLOP/s of the site contraction (the 2NΧ²d GEMM dominates).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import mps as M
from repro.core import sampler as S


def _one_site_time(chi: int, d: int, n: int, dtype=jnp.float32) -> float:
    mps = M.random_linear_mps(jax.random.key(0), 2, chi, d, dtype=dtype)
    cfg = S.SamplerConfig()
    state = S.init_state(mps, n, jax.random.key(1), cfg)
    fn = jax.jit(lambda m, s: S.sample_chain(m, s, cfg).samples)
    t2 = time_fn(fn, mps, state)
    return t2 / 2.0                         # per site


def run(quick: bool = True) -> None:
    # a) time vs χ (d=3, N=4096): expect quadratic growth
    for chi in (128, 256, 512, 1024):
        t = _one_site_time(chi, 3, 4096)
        gflops = 2 * 4096 * chi * chi * 3 / t / 1e9
        emit(f"fig10a_chi{chi}_d3_N4096", t, f"{gflops:.1f}GFLOP/s")

    # b) time vs d (χ=512, N=4096): linear, with a d-independent floor
    for d in (2, 3, 4, 6):
        t = _one_site_time(512, d, 4096)
        gflops = 2 * 4096 * 512 * 512 * d / t / 1e9
        emit(f"fig10b_chi512_d{d}_N4096", t, f"{gflops:.1f}GFLOP/s")

    # c) time vs micro batch N (χ=512, d=3): sub-linear until GEMM saturates
    for n in (256, 1024, 4096, 16384):
        t = _one_site_time(512, 3, n)
        per_sample = t / n * 1e9
        emit(f"fig10c_chi512_d3_N{n}", t, f"{per_sample:.1f}ns/sample")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
