"""Shared benchmark plumbing: timing + CSV emission.

Every bench prints ``name,us_per_call,derived`` rows (one per sweep point).
``derived`` is the paper-facing number (speedup, efficiency, GFLOP/s, ...).
"""
from __future__ import annotations

import time
from typing import Callable

import jax

# the MPS oracles/benches compare against float64 (the paper's reference
# precision); model benches specify their dtypes explicitly
jax.config.update("jax_enable_x64", True)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kwargs) -> float:
    """Median wall time per call in seconds (block_until_ready'd)."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str | float = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def header() -> None:
    print("name,us_per_call,derived", flush=True)


def run_child(code: str, devices: int = 8, timeout: int = 600) -> dict:
    """Run python ``code`` in a subprocess with N forced host devices.

    The child must print a single JSON object on its last stdout line.
    (The parent keeps the real 1-device view; see tests/conftest.py.)
    """
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.path.join(root, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env, text=True,
                          capture_output=True, timeout=timeout, cwd=root)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    return json.loads(proc.stdout.strip().splitlines()[-1])
