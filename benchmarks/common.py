"""Shared benchmark plumbing: timing + CSV emission + BENCH.json trajectory.

Every bench prints ``name,us_per_call,derived`` rows (one per sweep point).
``derived`` is the paper-facing number (speedup, efficiency, GFLOP/s, ...).

Benches that track a paper-facing quantity across PRs also append a JSON
record to the shared trajectory file (``benchmarks/BENCH.json``) via
:func:`append_bench_record` — broadcast I/O reduction, streaming overlap,
TP wire bytes, and the fused-site-step HBM model all live there, so the
perf history is one file.
"""
from __future__ import annotations

import datetime
import json
import os
import time
from typing import Callable, Optional

import jax

BENCH_JSON = os.path.join(os.path.dirname(__file__), "BENCH.json")

# the MPS oracles/benches compare against float64 (the paper's reference
# precision); model benches specify their dtypes explicitly
jax.config.update("jax_enable_x64", True)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kwargs) -> float:
    """Median wall time per call in seconds (block_until_ready'd)."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str | float = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def header() -> None:
    print("name,us_per_call,derived", flush=True)


def append_bench_record(json_path: Optional[str], bench: str, config: dict,
                        **payload) -> Optional[dict]:
    """Append one record to the BENCH trajectory file and return it.

    ``json_path`` of ``None``/``""`` disables the append (CI smoke runs pass
    ``--json ""`` so ephemeral runners never mutate the tracked history).
    The record carries the bench name, a UTC timestamp, the sweep config,
    and the bench-specific payload — successive PRs diff the trajectory.
    """
    record = {
        "bench": bench,
        "utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "config": config,
        **payload,
    }
    if not json_path:
        return record
    trajectory = []
    if os.path.exists(json_path):
        with open(json_path) as f:
            trajectory = json.load(f)
    trajectory.append(record)
    with open(json_path, "w") as f:
        json.dump(trajectory, f, indent=1)
    print(f"# appended to {json_path} ({len(trajectory)} records)")
    return record


def run_child(code: str, devices: int = 8, timeout: int = 600) -> dict:
    """Run python ``code`` in a subprocess with N forced host devices.

    The child must print a single JSON object on its last stdout line.
    (The parent keeps the real 1-device view; see tests/conftest.py.)
    """
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.path.join(root, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env, text=True,
                          capture_output=True, timeout=timeout, cwd=root)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    return json.loads(proc.stdout.strip().splitlines()[-1])
