"""Tables 2/3 — FastMPS data parallel vs the [19] site-bound pipeline.

Two comparisons:
  1. *measured* at container scale: both schemes on the same 8 forced host
     devices, same seeds → identical samples; derived = wall-time ratio.
     (One physical core serializes both, so this compares total work +
     scheduling overhead, which is exactly what differs between them.)
  2. *modelled* at paper scale (Eqs. 1/2 on A100 constants) for the
     Jiuzhang2/B-M288 rows; derived = predicted speedup (paper: ~10×).
"""
from __future__ import annotations

import textwrap

from benchmarks.common import emit, run_child
from repro.core import perfmodel as PM

_CHILD = textwrap.dedent("""
    import json, time
    import jax, jax.numpy as jnp
    jax.config.update("jax_enable_x64", True)
    from repro.core import mps as M, parallel as PP

    SITES, CHI, D, N = 8, 96, 3, 640
    mps = M.random_linear_mps(jax.random.key(0), SITES, CHI, D,
                              dtype=jnp.float32)
    mesh = jax.make_mesh((8,), ("data",))

    def timed(make):
        fn = jax.jit(lambda g, lam: make(M.MPS(g, lam, "linear")))
        out = fn(mps.gammas, mps.lambdas)
        jax.block_until_ready(out)
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(mps.gammas, mps.lambdas))
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[1], out

    # the internal data-plane entry points: this bench times the jitted
    # scheme programs themselves, not the repro.api session orchestration
    t_dp, s_dp = timed(lambda m: PP._multilevel_sample(
        mesh, m, N, jax.random.key(9), PP.ParallelConfig("dp")))
    # n_macro = 8 so [19]'s macro-batch partition matches DP's 8 shards —
    # then both schemes emit bit-identical samples
    t_19, s_19 = timed(lambda m: PP._baseline19_sample(
        mesh, m, N, jax.random.key(9), n_macro=8))
    print(json.dumps({"t_dp": t_dp, "t_19": t_19,
                      "same": bool(jnp.all(s_dp == s_19))}))
""")


def run(quick: bool = True) -> None:
    out = run_child(_CHILD, devices=8)
    emit("table2_measured_dp_8dev", out["t_dp"],
         f"samples_identical={out['same']}")
    emit("table2_measured_baseline19_8dev", out["t_19"],
         f"{out['t_19'] / out['t_dp']:.2f}x_slower")

    # paper-scale model rows (A100 constants).  [19] runs fp64-ish fixed-χ
    # with generic expm; FastMPS = data parallel with the overlap-sized N₁
    # (§3.1's rule) × the three multiplicative optimizations (Fig. 11):
    # TF32-tier GEMMs, dynamic χ (Table 1 comp ratio), optimized expm.
    import dataclasses
    rows = {
        "jiuzhang2": (PM.Workload(10_000_000, 144, 10_000, 4,
                                  bytes_per_elt=16), 0.2023),
        "b_m288": (PM.Workload(10_000_000, 288, 10_000, 4,
                               bytes_per_elt=16), 0.8339),
        "m8176": (PM.Workload(10_000_000, 8_176, 10_000, 3,
                              bytes_per_elt=16), 0.7961),
    }
    fp64 = dataclasses.replace(PM.A100, peak_flops=19.5e12)   # A100 fp64 TC
    for name, (w, comp_ratio) in rows.items():
        p = w.n_sites                                          # equal resources
        # [19] at its own operating point (N₁ ~ 2e4, fp64, fixed χ)
        t19 = PM.eq1_model_parallel(w, fp64)
        # scheme change alone: same fp64 numerics, N₁ sized by the overlap
        # rule for fp64 throughput (§3.1), capped at N/p
        n1_64 = min(max(w.macro_batch,
                        PM.min_macro_batch_for_overlap(w, fp64)),
                    w.n_samples // p)
        t_scheme = PM.eq2_data_parallel(
            dataclasses.replace(w, macro_batch=n1_64), fp64, p=p)
        # full FastMPS: TF32-tier GEMMs + FP16 Γ storage (4 B/complex elt,
        # §3.3.2 quarters I/O) + dynamic χ (Table 1 comp ratio)
        n1_fast = min(max(w.macro_batch,
                          PM.min_macro_batch_for_overlap(
                              w, PM.A100, storage_bytes=4)),
                      w.n_samples // p)
        t_fast = PM.eq2_data_parallel(
            dataclasses.replace(w, macro_batch=n1_fast), PM.A100, p=p,
            storage_bytes=4) * comp_ratio
        emit(f"table2_model_{name}", t_fast,
             f"scheme_only={t19 / t_scheme:.1f}x|full={t19 / t_fast:.1f}x"
             f"|N1={n1_fast}")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
