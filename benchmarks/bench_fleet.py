"""Fleet bench: persistent worker processes vs subprocess-per-batch.

What the PR's transport buys, measured:

* **baseline** — the PR 5 dispatch story: ``RemoteRuntime(persistent=
  False)`` forks one fresh interpreter per macro batch, so every batch
  pays a full jax import + cold jit cache before it computes anything.
* **fleet @ 1/2/4 workers** — ``SamplingService(pool=True)``: each lane
  owns a long-lived ``repro.runtime.transport`` worker; after the lane's
  first batch the worker is warm (cached session, warm jit cache), so a
  batch pays dispatch + compute only, and lanes scale the job table
  horizontally.

Rows (common.emit): `oneshot_batches` (the baseline), then per worker
count `fleet_burst_w{N}` (single-batch job burst, jobs/s derived) and
`fleet_ttfb_w{N}` (time-to-first-block of one multi-batch job).  Each
full run appends a `fleet` record to the BENCH trajectory
(``benchmarks/BENCH.json``); CI smoke passes ``--json ""`` so ephemeral
runners never mutate the tracked history.

Usage:
  PYTHONPATH=src python benchmarks/bench_fleet.py [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp

import common
from repro import api
from repro.core import mps as M
from repro.data.gamma_store import GammaStore


def _build_store(sites: int, chi: int, d: int) -> str:
    root = tempfile.mkdtemp(prefix="fastmps_bench_fleet_")
    mps = M.gbs_like_mps(jax.random.key(0), sites, chi, d,
                         dtype=jnp.float64)
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(mps)
    return root


def bench_oneshot_baseline(root: str, n: int, k: int) -> float:
    """PR 5: one k-batch job where every batch is a fresh subprocess
    (``persistent=False``) — interpreter + jax import + compile, k times.
    Returns wall seconds for the job."""
    rt = api.RemoteRuntime(persistent=False)
    cfg = api.SamplerConfig(backend="remote", runtime=rt)
    with api.SamplingService(workers=1) as svc:
        t0 = time.perf_counter()
        svc.submit(root, cfg, n_samples=n * k, key=jax.random.key(1),
                   macro_batches=k).result()
        return time.perf_counter() - t0


def bench_fleet(root: str, n: int, k: int, jobs: int, workers: int
                ) -> tuple[float, float, float]:
    """(burst wall seconds for `jobs` single-batch jobs, time-to-first-
    block of one k-batch job, its full wall) at `workers` worker
    processes."""
    with api.SamplingService(workers=workers, pool=True) as svc:
        # warm every lane: a k=2·w batch job spreads over the lanes, so
        # each worker pays its one-time import/compile outside the clock
        svc.submit(root, n_samples=n * 2 * workers,
                   key=jax.random.key(97),
                   macro_batches=2 * workers).result()
        t0 = time.perf_counter()
        handles = [svc.submit(root, n_samples=n, key=jax.random.key(j))
                   for j in range(jobs)]
        for h in handles:
            h.result()
        burst = time.perf_counter() - t0

        t0 = time.perf_counter()
        h = svc.submit(root, n_samples=n * k, key=jax.random.key(1),
                       macro_batches=k)
        stream = h.stream()
        next(stream)
        ttfb = time.perf_counter() - t0
        for _ in stream:
            pass
        full = time.perf_counter() - t0
    return burst, ttfb, full


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=common.BENCH_JSON,
                    help='BENCH trajectory path ("" disables the append)')
    args = ap.parse_args()

    # per-batch compute is kept modest on purpose: this bench measures the
    # DISPATCH story (cold interpreter vs warm worker), which is exactly
    # where subprocess-per-batch loses — at large χ both modes converge on
    # compute and the transport stops mattering
    sites, chi, d = (16, 8, 3) if args.smoke else (32, 24, 3)
    n = 128 if args.smoke else 1024            # samples per batch
    k = 3 if args.smoke else 6                 # batches of the ttfb job
    jobs = 3 if args.smoke else 8              # burst size
    worker_counts = [1, 2] if args.smoke else [1, 2, 4]
    root = _build_store(sites, chi, d)

    try:
        common.header()
        base_s = bench_oneshot_baseline(root, n, k)
        common.emit("oneshot_batches", base_s / k,
                    f"{k / base_s:.3f} batches/s (PR5 baseline)")
        fleet = {}
        for w in worker_counts:
            burst, ttfb, full = bench_fleet(root, n, k, jobs, w)
            fleet[w] = {"jobs_per_s": jobs / burst,
                        "time_to_first_block_s": ttfb,
                        "job_wall_s": full,
                        "batches_per_s": k / full}
            common.emit(f"fleet_burst_w{w}", burst / jobs,
                        f"{jobs / burst:.2f} jobs/s")
            common.emit(f"fleet_ttfb_w{w}", ttfb,
                        f"{(base_s / k) / ttfb:.2f}x vs oneshot batch")

        common.append_bench_record(
            args.json, "fleet",
            {"sites": sites, "chi": chi, "d": d, "n_per_batch": n,
             "macro_batches": k, "burst_jobs": jobs,
             "worker_counts": worker_counts, "smoke": bool(args.smoke)},
            oneshot_job_wall_s=base_s,
            oneshot_batches_per_s=k / base_s,
            fleet={str(w): v for w, v in fleet.items()},
            best_speedup_vs_oneshot=max(
                v["batches_per_s"] for v in fleet.values()) / (k / base_s))
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
