"""Fused site-step bench: HBM bytes and wall time, fused vs unfused.

Three measurements per shape:

* **modeled HBM bytes/site** (``perfmodel.site_hbm_bytes``): the roofline
  byte model of the hot loop with and without the fusion — the unfused
  path round-trips the unmeasured ``temp[N, χ, d]`` three times, the fused
  Pallas pipeline never writes it.  The acceptance quantity is the ratio
  (≥ 2× for every d ≥ 2 shape).
* **measured XLA bytes/site** (``hloanalysis`` over the compiled unfused
  site step) — grounds the model against what XLA actually emits.
* **wall time** of one site step, ``kernels="pallas"`` vs ``kernels="xla"``
  (compiled on TPU; interpret mode elsewhere, where the time column is
  about correctness plumbing, not speed — the bytes model is the portable
  number).

Each full run appends a record to the BENCH.json trajectory so successive
PRs track per-site bytes/FLOPs.

Usage:
  PYTHONPATH=src python benchmarks/bench_site_step.py [--smoke] [--json ...]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

try:                                     # script style (cwd = benchmarks/)
    import common
except ImportError:                      # harness style (-m benchmarks.run)
    from benchmarks import common
from repro.core import perfmodel as PM
from repro.kernels import dispatch, ref
from repro.kernels.site_impls import site_step_linear_pallas, \
    site_step_linear_xla
from repro.launch import hloanalysis as H

# paper-facing shapes: the bench_roofline pair + a mid-size cell; smoke
# shrinks to interpret-mode-friendly sizes
_SHAPES = ((5_000, 2_000, 3), (20_000, 10_000, 4), (4_096, 1_024, 4))
_SMOKE_SHAPES = ((128, 64, 3), (64, 96, 4))


def _measured_unfused_bytes(n: int, chi: int, d: int, dtype) -> float:
    """Bytes of the compiled (unfused, XLA) site step from its HLO."""
    sds = jax.ShapeDtypeStruct
    rdt = jnp.zeros((), dtype).real.dtype

    def step(env, gamma, lam, u):
        return site_step_linear_xla(env, gamma, lam, u, scaling="per_sample",
                                    compute_dtype=None)

    c = jax.jit(step).lower(
        sds((n, chi), dtype), sds((chi, chi, d), dtype), sds((chi,), dtype),
        sds((n, 1), rdt)).compile()
    return H.analyze(c.as_text()).memory_bytes


def run(quick: bool = True, json_path: str | None = None) -> None:
    shapes = _SMOKE_SHAPES if quick else _SHAPES
    elt = 8                                  # fp64 (the x64 bench default)
    rows = []
    for (n, chi, d) in shapes:
        b_unfused = PM.site_hbm_bytes(n, chi, d, elt, fused=False)
        b_fused = PM.site_hbm_bytes(n, chi, d, elt, fused=True)
        ratio = b_unfused / b_fused
        measured = _measured_unfused_bytes(n, chi, d, jnp.float64)
        flops = 2.0 * n * chi * chi * d
        common.emit(
            f"site_step_bytes_N{n}_chi{chi}_d{d}", 0.0,
            f"model_unfused={b_unfused:.3g}B|model_fused={b_fused:.3g}B"
            f"|reduction={ratio:.1f}x|hlo_unfused={measured:.3g}B")
        assert ratio >= 2.0, (n, chi, d, ratio)

        # wall time: one dispatched site step, both backends (tiny shapes
        # only off-TPU — interpret mode is a correctness vehicle, not perf)
        times = {}
        if quick or dispatch.on_tpu():
            k1, k2, k3, k4 = jax.random.split(jax.random.key(0), 4)
            env = jax.random.uniform(k1, (n, chi), dtype=jnp.float64)
            gamma = jax.random.uniform(k2, (chi, chi, d), dtype=jnp.float64)
            lam = jax.random.uniform(k3, (chi,), dtype=jnp.float64)
            u = jax.random.uniform(k4, (n, 1), dtype=jnp.float64)
            for name, fn in (("pallas", site_step_linear_pallas),
                             ("xla", site_step_linear_xla)):
                t = common.time_fn(fn, env, gamma, lam, u,
                                   scaling="per_sample", compute_dtype=None,
                                   warmup=1, iters=2)
                times[name] = t
                common.emit(f"site_step_{name}_N{n}_chi{chi}_d{d}", t,
                            f"{flops / max(t, 1e-12) / 1e9:.1f}GFLOP/s")
        rows.append({
            "n": n, "chi": chi, "d": d, "flops_per_site": flops,
            "model_bytes_unfused": b_unfused, "model_bytes_fused": b_fused,
            "byte_reduction": ratio, "hlo_bytes_unfused": float(measured),
            "wall_s": times or None,
        })

    common.append_bench_record(
        json_path, "site_step",
        {"backend": jax.default_backend(),
         "kernels": dispatch.resolve_kernels("auto"),
         "elt_bytes": elt, "smoke": bool(quick)},
        shapes=rows,
        autotuner=dispatch.autotune_cache_stats())


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None,
                    help="BENCH trajectory file ('' disables; default: "
                         "benchmarks/BENCH.json for full runs, disabled "
                         "for --smoke)")
    args = ap.parse_args()
    json_path = (args.json if args.json is not None
                 else ("" if args.smoke else common.BENCH_JSON))
    common.header()
    run(quick=args.smoke, json_path=json_path or None)
