"""Table 1 — dynamic bond dimension accounting for the paper's presets.

derived = equiv_chi/step_ratio/comp_ratio — compare with the published
Table 1 rows (values depend on the entanglement profile; we reproduce the
qualitative ordering: more squeezed photons → higher equivalent χ).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import gbs
from repro.core import dynamic_bond as DB


def run(quick: bool = True) -> None:
    rows = []
    for preset in gbs.PRESETS.values():
        # entanglement plateau scales with the actual squeezed photon count
        prof = DB.area_law_profile(preset.n_sites, preset.chi,
                                   n_photon=preset.asp / 4.0)
        m = DB.table1_metrics(prof, preset.chi)
        if preset.n_sites <= 300:          # same-scale presets only
            rows.append((preset.asp, m["equiv_chi"]))
        emit(f"table1_{preset.name}", 0.0,
             f"equiv_chi={m['equiv_chi']:.0f}"
             f"|step_ratio={m['step_ratio']:.2%}"
             f"|comp_ratio={m['comp_ratio']:.2%}")
    # the paper's qualitative law: at fixed M, equiv χ increases with ASP
    # (m8176 is excluded: with 8176 sites the edge fraction is tiny and the
    # accounting is plateau-dominated — a different regime than M≈150-300)
    rows.sort()
    eq = [r[1] for r in rows]
    mono = all(a <= b + 1e-9 for a, b in zip(eq, eq[1:]))
    emit("table1_equivchi_monotone_in_asp_sameM", 0.0, str(mono))


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
