"""Fig. 5/6 — numeric-range expansion and the underflow cliff.

derived: for each scaling mode, the site index at which the float32 chain
dies (max |env| → 0), or "alive" — plus the final inter-sample range ratio.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import mps as M
from repro.core import sampler as S

SITES, CHI, D = 200, 8, 3   # small χ widens the per-branch magnitude spread
                            # (the Fig. 5 regime: structured, sparse data)


def run(quick: bool = True) -> None:
    mps = M.random_linear_mps(jax.random.key(3), SITES, CHI, D, decay=1.2,
                              dtype=jnp.float64).astype(jnp.float32)
    for mode in ("none", "global", "per_sample"):
        cfg = S.SamplerConfig(scaling=mode)
        state = S.init_state(mps, 256, jax.random.key(0), cfg)
        fn = jax.jit(lambda m, s: S.sample_chain(m, s, cfg))
        t = time_fn(fn, mps, state, iters=1)
        res = fn(mps, state)
        max_env = np.asarray(res.site_stats[:, 0])
        dead = np.nonzero(max_env == 0.0)[0]
        status = f"dead@site{dead[0]}" if dead.size else "alive"
        emit(f"fig6_scaling_{mode}", t, status)

    # Fig. 5: per-sample max spread (orders of magnitude), measured two ways
    # in float64 so nothing underflows.
    mps64 = mps.astype(jnp.float64)
    cfg = S.SamplerConfig(scaling="per_sample")
    state = S.init_state(mps64, 256, jax.random.key(0), cfg)
    res = jax.jit(lambda m, s: S.sample_chain(m, s, cfg))(mps64, state)
    # log_scale accumulates each sample's true magnitude; spread across
    # samples = the horizontal-axis spread of Fig. 5
    lg = np.asarray(res.state.log_scale)
    emit("fig5_intersample_spread_log10", 0.0,
         f"{lg.max() - lg.min():.1f}_orders")
    # spread under a *global* scale (what a single scalar cannot contain)
    cfg_g = S.SamplerConfig(scaling="global")
    res_g = jax.jit(lambda m, s: S.sample_chain(m, s, cfg_g))(
        mps64, S.init_state(mps64, 256, jax.random.key(0), cfg_g))
    from repro.core.precision import sample_range_stats
    sm = np.asarray(sample_range_stats(res_g.state.env)["sample_max"])
    emit("fig5_globalscale_samplemax_spread", 0.0,
         f"{np.log10(sm.max() / sm.min()):.1f}_orders")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
