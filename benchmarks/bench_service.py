"""Service-layer bench: job throughput and time-to-first-block.

What the async front door buys over the blocking call:

* **jobs/s** — a burst of J same-cell jobs against one store coalesces
  onto one session (one resolved plan, one streamed engine, one jit
  cache), so per-job overhead is scheduling, not recompilation.  The
  baseline opens a fresh session per request — the pre-service serving
  story.
* **time-to-first-block** — a k-batch job streams its first macro batch
  after ~1/k of the run, while the one-shot call holds the caller for the
  whole walk.  Gang-scheduling (batch b+1's first Γ segment fetched behind
  batch b's tail compute) keeps the pipeline full in between.

Rows (common.emit): `service_burst` / `fresh_sessions` wall time with
jobs/s derived, `first_block` / `one_shot` with the latency ratio.  Each
full run appends a `service` record to the BENCH trajectory
(``benchmarks/BENCH.json``); CI smoke passes ``--json ""`` so ephemeral
runners never mutate the tracked history.

Usage:
  PYTHONPATH=src python benchmarks/bench_service.py [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp

import common
from repro import api
from repro.core import mps as M
from repro.data.gamma_store import GammaStore


def _build_store(sites: int, chi: int, d: int) -> str:
    root = tempfile.mkdtemp(prefix="fastmps_bench_service_")
    mps = M.gbs_like_mps(jax.random.key(0), sites, chi, d,
                         dtype=jnp.float64)
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(mps)
    return root


def bench_job_burst(root: str, cfg: api.SamplerConfig, jobs: int, n: int
                    ) -> tuple[float, float]:
    """J single-batch jobs through one service (coalesced) vs J fresh
    sessions (the pre-service per-request cost).  Returns (svc_s, fresh_s)."""
    with api.SamplingService(workers=1) as svc:
        # prime: the first job pays the one compilation both variants need
        svc.submit(root, cfg, n_samples=n, key=jax.random.key(99)).result()
        t0 = time.perf_counter()
        handles = [svc.submit(root, cfg, n_samples=n, key=jax.random.key(j))
                   for j in range(jobs)]
        for h in handles:
            h.result()
        svc_s = time.perf_counter() - t0
        assert svc.stats()["sessions"] == 1          # all coalesced

    t0 = time.perf_counter()
    for j in range(jobs):
        with api.SamplingSession(root, cfg) as sess:
            sess.sample(n, jax.random.key(j))
    fresh_s = time.perf_counter() - t0
    return svc_s, fresh_s


def bench_first_block(root: str, cfg: api.SamplerConfig, n: int, k: int
                      ) -> tuple[float, float, float]:
    """(time to first streamed block of a k-batch job, full job wall,
    one-shot wall for the same N)."""
    with api.SamplingService(workers=1) as svc:
        # warm serving state: one identical job pays every one-time cost
        # (compile, engine build, key-fold trace) outside the timed section
        svc.submit(root, cfg, n_samples=n, key=jax.random.key(98),
                   macro_batches=k).result()
        t0 = time.perf_counter()
        h = svc.submit(root, cfg, n_samples=n, key=jax.random.key(1),
                       macro_batches=k)
        stream = h.stream()
        next(stream)
        ttfb = time.perf_counter() - t0
        for _ in stream:
            pass
        full = time.perf_counter() - t0

    with api.SamplingSession(root, cfg) as sess:
        sess.sample(n, jax.random.key(98))           # same warm state
        t0 = time.perf_counter()
        sess.sample(n, jax.random.key(1))
        one_shot = time.perf_counter() - t0
    return ttfb, full, one_shot


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=common.BENCH_JSON,
                    help='BENCH trajectory path ("" disables the append)')
    args = ap.parse_args()

    # full scale is compute-dominated (χ²·d·N·M keeps the walk on the MXU/
    # BLAS, not on per-segment dispatch overhead) so the streamed first
    # block genuinely lands at ~1/k of the run; smoke only checks wiring
    sites, chi, d = (24, 8, 3) if args.smoke else (48, 48, 3)
    n = 256 if args.smoke else 8192
    jobs = 4 if args.smoke else 16
    k = 4 if args.smoke else 8
    root = _build_store(sites, chi, d)
    cfg = api.SamplerConfig(segment_len=max(4, sites // 4))

    try:
        common.header()
        svc_s, fresh_s = bench_job_burst(root, cfg, jobs, n)
        common.emit("service_burst", svc_s / jobs,
                    f"{jobs / svc_s:.2f} jobs/s")
        common.emit("fresh_sessions", fresh_s / jobs,
                    f"{jobs / fresh_s:.2f} jobs/s")
        ttfb, full, one_shot = bench_first_block(root, cfg, n, k)
        common.emit("first_block", ttfb, f"{one_shot / ttfb:.2f}x earlier")
        common.emit("one_shot", one_shot, "")

        common.append_bench_record(
            args.json, "service",
            {"sites": sites, "chi": chi, "d": d, "n": n, "jobs": jobs,
             "macro_batches": k, "smoke": bool(args.smoke)},
            jobs_per_s=jobs / svc_s,
            fresh_jobs_per_s=jobs / fresh_s,
            burst_speedup=fresh_s / svc_s,
            time_to_first_block_s=ttfb,
            job_wall_s=full,
            one_shot_wall_s=one_shot,
            first_block_speedup=one_shot / ttfb)
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
