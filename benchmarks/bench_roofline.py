"""§Roofline companion bench: arithmetic intensity of the fused site kernel.

Reports, for the contract+measure hot spot at paper-scale shapes, the FLOPs,
bytes and resulting v5e roofline position (compute- vs memory-bound) from
the *compiled* XLA program — the same analysis the dry-run applies to the
full production meshes (see EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.launch import hloanalysis as H
from repro.kernels import ref


def run(quick: bool = True) -> None:
    for (n, chi, d) in ((5000, 2000, 3), (20000, 10000, 4)):
        sds = jax.ShapeDtypeStruct
        c = jax.jit(ref.contract_measure_ref).lower(
            sds((n, chi), jnp.bfloat16),
            sds((chi, chi, d), jnp.bfloat16),
            sds((chi,), jnp.bfloat16)).compile()
        cost = H.analyze(c.as_text())
        rf = H.roofline(cost, 1, model_flops=2.0 * n * chi * chi * d)
        ai = cost.flops / max(cost.memory_bytes, 1)
        emit(f"roofline_site_N{n}_chi{chi}_d{d}", 0.0,
             f"AI={ai:.0f}flops/B|bound={rf.bottleneck}"
             f"|tc={rf.t_compute:.2e}s|tm={rf.t_memory:.2e}s")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
