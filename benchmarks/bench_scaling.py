"""Fig. 12 — weak/strong scaling of data-parallel sampling.

One physical CPU core hosts the forced devices, so wall-clock "speedup" is
unmeasurable here; what IS measurable — and what actually determines the
paper's ≥95 % efficiency — is the *communication structure*: DP sampling
must compile to a per-shard program with **zero collectives in the chain
loop**.  derived reports the collective wire bytes per sample (0 ⇒
perfectly scalable) plus the Eq. 2 model efficiency on v5e constants.
"""
from __future__ import annotations

import textwrap

from benchmarks.common import emit, run_child
from repro.core import perfmodel as PM

_CHILD = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    jax.config.update("jax_enable_x64", True)
    from repro.core import mps as M, parallel as PP, sampler as S
    from repro.launch import hloanalysis as H
    from repro.launch.mesh import make_host_mesh

    p = __P__
    mesh = jax.make_mesh((__P__,), ("data",))
    mps = M.random_linear_mps(jax.random.key(0), 8, 64, 3, dtype=jnp.float32)
    n = 256 * p                     # weak scaling: 256 samples per shard

    def run(g, lam, seed):
        # internal data plane: this bench lowers the scheme program for HLO
        # analysis, not the repro.api session orchestration
        return PP._multilevel_sample(mesh, M.MPS(g, lam, "linear"), n,
                                     jax.random.key(seed),
                                     PP.ParallelConfig("dp"))
    c = jax.jit(run).lower(mps.gammas, mps.lambdas, 0).compile()
    cost = H.analyze(c.as_text())
    print(json.dumps({"wire": cost.collective_wire_bytes,
                      "n_coll": sum(cost.n_collectives.values()),
                      "per_type": cost.per_collective}))
""")


def run(quick: bool = True) -> None:
    for p in (2, 4, 8):
        out = run_child(_CHILD.replace("__P__", str(p)), devices=p)
        emit(f"fig12_dp_collectives_p{p}", 0.0,
             f"wire_bytes={out['wire']:.0f}|n_coll={out['n_coll']:.0f}")

    # Eq.2-model strong-scaling efficiency on TPU v5e (paper's ≥95 % claim)
    w = PM.Workload(n_samples=10_000_000, n_sites=8176, chi=2000, d=3,
                    macro_batch=20_000, micro_batch=5_000)
    t1 = PM.eq2_data_parallel(w, PM.TPU_V5E, p=1)
    for p in (16, 256, 500):
        tp = PM.eq2_data_parallel(w, PM.TPU_V5E, p=p)
        eff = t1 / (p * tp)
        emit(f"fig12_eq2_strong_eff_p{p}", tp, f"{eff:.1%}")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
