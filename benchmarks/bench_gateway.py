"""Gateway bench: what the HTTP front door costs — and what the result
cache buys back.

Two paper-facing numbers:

* **requests/s, cold vs cache-hit** — a burst of distinct jobs (every
  request computes) vs the same burst repeated (every request streams
  cached bytes).  The ratio is the content-address dividend: restart-exact
  sampling (batch = f(seed, id)) makes results pure values, so the cache
  serves bit-identical blocks without touching a device.
* **time-to-first-block, HTTP vs in-process** — the wire tax: the same
  k-batch job through ``JobHandle.stream`` in-process and through the
  chunked-HTTP frame stream; the delta is gateway + localhost HTTP, which
  should be negligible against the macro-batch compute it fronts.

Rows (common.emit): `cold_burst` / `hit_burst` with requests/s derived,
`first_block_http` / `first_block_inproc` with the latency ratio.  Each
full run appends a `gateway` record to the BENCH trajectory
(``benchmarks/BENCH.json``); CI smoke passes ``--json ""`` so ephemeral
runners never mutate the tracked history.

Usage:
  PYTHONPATH=src python benchmarks/bench_gateway.py [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import http.client
import json
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

import common
from repro import api
from repro.core import mps as M
from repro.data.gamma_store import GammaStore
from repro.runtime import transport
from repro.serve import Gateway, ResultCache


def _build_store(sites: int, chi: int, d: int) -> str:
    root = tempfile.mkdtemp(prefix="fastmps_bench_gateway_")
    mps = M.random_linear_mps(jax.random.key(0), sites, chi, d)
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(mps)
    return root


class _Exact:
    def __init__(self, resp):
        self.resp = resp

    def read(self, n):
        out = b""
        while len(out) < n:
            chunk = self.resp.read(n - len(out))
            if not chunk:
                break
            out += chunk
        return out


def _submit(conn, store, n, seed, k):
    conn.request("POST", "/v1/jobs", json.dumps(
        {"store": store, "n_samples": n, "seed": seed, "macro_batches": k}),
        {"Content-Type": "application/json"})
    resp = conn.getresponse()
    out = json.loads(resp.read())
    assert resp.status == 201, out
    return out


def _drain_stream(conn, gid, first_block_at=None):
    conn.request("GET", f"/v1/jobs/{gid}/stream")
    resp = conn.getresponse()
    rx = _Exact(resp)
    n_blocks = 0
    while True:
        head = json.loads(transport.read_frame(rx))
        if head["kind"] == "block":
            transport.read_frame(rx)
            if n_blocks == 0 and first_block_at is not None:
                first_block_at.append(time.perf_counter())
            n_blocks += 1
        else:
            assert head["kind"] == "end", head
            break
    resp.read()
    return n_blocks


def bench_burst(gw, conn, store, jobs, n, seeds) -> float:
    t0 = time.perf_counter()
    gids = [_submit(conn, store, n, seed, 1)["id"] for seed in seeds]
    for gid in gids:
        _drain_stream(conn, gid)
    return time.perf_counter() - t0


def bench_first_block(svc, gw, conn, store, n, k, seed
                      ) -> tuple[float, float]:
    """(http_ttfb_s, inproc_ttfb_s) of the same cold k-batch job."""
    marks = []
    t0 = time.perf_counter()
    gid = _submit(conn, store, n, seed, k)["id"]
    _drain_stream(conn, gid, first_block_at=marks)
    http_ttfb = marks[0] - t0
    t0 = time.perf_counter()
    h = svc.submit(store, api.SamplerConfig(), n_samples=n,
                   key=jax.random.key(seed + 1), macro_batches=k)
    for _b, _blk in h.stream(timeout=600):
        inproc_ttfb = time.perf_counter() - t0
        break
    h.result(timeout=600)
    return http_ttfb, inproc_ttfb


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=common.BENCH_JSON,
                    help='trajectory file; "" disables the append')
    args = ap.parse_args()
    sites, chi, d = (8, 4, 3) if args.smoke else (32, 16, 3)
    jobs = 4 if args.smoke else 16
    n = 16 if args.smoke else 256
    k = 4

    store = _build_store(sites, chi, d)
    cache_dir = tempfile.mkdtemp(prefix="fastmps_bench_gwcache_")
    common.header()
    try:
        with api.SamplingService(workers=2) as svc, \
                Gateway(svc, cache=ResultCache(cache_dir=cache_dir)) as gw:
            host, port = gw._server.server_address[:2]
            conn = http.client.HTTPConnection(host, port)
            # prime the jit cache so cold measures scheduling, not XLA —
            # both variants: single-batch (burst) and k-batch (TTFB; the
            # multi-batch path jits its own pipelined walk)
            _drain_stream(conn, _submit(conn, store, n, 9999, 1)["id"])
            _drain_stream(conn, _submit(conn, store, n * k, 9998, k)["id"])

            seeds = list(range(jobs))
            cold_s = bench_burst(gw, conn, store, jobs, n, seeds)
            hit_s = bench_burst(gw, conn, store, jobs, n, seeds)
            assert gw.cache.stats()["hits"] >= jobs
            common.emit("cold_burst", cold_s / jobs,
                        f"{jobs / cold_s:.1f} req/s")
            common.emit("hit_burst", hit_s / jobs,
                        f"{jobs / hit_s:.1f} req/s")

            http_ttfb, inproc_ttfb = bench_first_block(
                svc, gw, conn, store, n * k, k, seed=777)
            common.emit("first_block_http", http_ttfb, "")
            common.emit("first_block_inproc", inproc_ttfb,
                        f"http/inproc {http_ttfb / inproc_ttfb:.2f}x")

            common.append_bench_record(
                args.json, "gateway",
                {"sites": sites, "chi": chi, "d": d, "jobs": jobs,
                 "n_samples": n, "macro_batches": k, "smoke": args.smoke},
                cold_req_s=jobs / cold_s, hit_req_s=jobs / hit_s,
                cache_speedup=cold_s / hit_s,
                ttfb_http_s=http_ttfb, ttfb_inproc_s=inproc_ttfb,
                http_overhead_x=http_ttfb / inproc_ttfb)
            conn.close()
    finally:
        shutil.rmtree(store, ignore_errors=True)
        shutil.rmtree(cache_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
