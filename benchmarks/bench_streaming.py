"""Streaming engine bench: compute/I-O overlap on chains beyond device memory.

The paper's §3.1 claim is that with a large enough macro batch, Γ I/O is
fully hidden behind contraction.  This bench builds a chain whose stacked Γ
*exceeds* a configurable device-memory budget, streams it through a
:class:`repro.api.SamplingSession` (streamed backend, double-buffered
GammaStore prefetch), and reports how much of the raw disk time was hidden
behind compute:

  io_hidden_frac = (store_io_s − io_wait_s) / store_io_s

Rows (see common.emit): total stream walltime with the derived column
carrying the paper-facing ratio.  ``--smoke`` shrinks shapes for CI.

Usage:
  PYTHONPATH=src python benchmarks/bench_streaming.py [--smoke]
"""
from __future__ import annotations

import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import common
from repro import api
from repro.core import mps as M
from repro.data.gamma_store import GammaStore


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sites", type=int, default=0)
    ap.add_argument("--chi", type=int, default=0)
    ap.add_argument("--d", type=int, default=3)
    ap.add_argument("--samples", type=int, default=0)
    ap.add_argument("--segment-len", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="BENCH trajectory file to append the record to "
                         "('' disables; default: benchmarks/BENCH.json for "
                         "full runs, disabled for --smoke so CI never "
                         "mutates the tracked history)")
    args = ap.parse_args()
    json_path = (args.json if args.json is not None
                 else ("" if args.smoke else common.BENCH_JSON))

    sites = args.sites or (32 if args.smoke else 256)
    chi = args.chi or (8 if args.smoke else 64)
    n = args.samples or (256 if args.smoke else 4096)
    d = args.d

    # budget chosen so the stacked Γ does NOT fit: it covers the resident
    # environment + micro intermediate (Eq. 3) plus a quarter of the chain —
    # the in-memory path would need all of stacked_bytes, the session holds
    # only two segment buffers.
    stacked_bytes = sites * chi * chi * d * 8            # fp64 compute
    resident = (n * chi + n * chi * d) * 8
    budget = int(resident / 0.9) + stacked_bytes // 4
    mps = M.gbs_like_mps(jax.random.key(0), sites, chi, d)

    root = tempfile.mkdtemp(prefix="bench_gamma_")
    try:
        store = GammaStore(root, storage_dtype=jnp.bfloat16,
                           compute_dtype=jnp.float64)
        store.write_mps(mps)

        config = api.SamplerConfig(
            segment_len=args.segment_len or api.AUTO,
            device_budget=budget)
        key = jax.random.key(1)
        with api.SamplingSession(store, config) as session:
            plan = session.plan(n)
            info = session.explain(n)
            print(f"# chain {sites}x{chi} d={d}: stacked Γ "
                  f"{stacked_bytes/1e6:.1f} MB, budget {budget/1e6:.1f} MB "
                  f"→ segment_len {plan.segment_len} "
                  f"({info['device_resident_bytes']/1e6:.1f} MB resident)")
            assert 2 * plan.segment_len * chi * chi * d * 8 <= stacked_bytes, \
                "bench must exercise a chain larger than its device buffers"

            common.header()
            t = common.time_fn(session.sample, n, key, warmup=1,
                               iters=2 if args.smoke else 3)
            st = session.stats
            common.emit("stream_total", t,
                        f"io_hidden_frac={st['io_hidden_frac']:.3f}")
            common.emit("stream_compute", st["compute_s"] / st["segments"],
                        "per_segment")
            common.emit("stream_io_wait", st["io_wait_s"] / st["segments"],
                        "per_segment")
            common.emit("stream_raw_disk", st["store_io_s"],
                        f"bytes={st['io_bytes']}")
            assert st["max_live_segments"] <= 2, st["max_live_segments"]

        # reference: the in-memory backend at bench scale (it still fits
        # here — at paper scale it cannot; the ratio is the honest
        # comparison)
        with api.SamplingSession(mps) as session:
            t_mem = common.time_fn(
                lambda: session.sample(n, key), warmup=1,
                iters=2 if args.smoke else 3)
        common.emit("inmem_total", t_mem,
                    f"stream_overhead={t / t_mem - 1.0:+.2%}")
        print(f"# overlap: {st['io_hidden_frac']:.1%} of "
              f"{st['store_io_s']*1e3:.1f} ms disk time hidden behind "
              f"compute (visible wait {st['io_wait_s']*1e3:.1f} ms)")
        common.append_bench_record(
            json_path, "streaming",
            {"sites": sites, "chi": chi, "d": d, "samples": n,
             "segment_len": plan.segment_len, "smoke": bool(args.smoke)},
            stream={"wall_s": t, "io_hidden_frac": st["io_hidden_frac"],
                    "io_wait_s": st["io_wait_s"],
                    "store_io_s": st["store_io_s"],
                    "io_bytes": int(st["io_bytes"])},
            inmem={"wall_s": t_mem},
            stream_overhead=t / t_mem - 1.0)
        store.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
