"""Multi-host Γ broadcast bench: 1 reader + interconnect vs N readers.

The paper's §3.1 observation: with p data-parallel processes each reading
its own Γ, storage I/O scales as p × chain-bytes and kills the revival at
scale; with process 0 reading once and broadcasting, storage stays at
1 × chain-bytes and the interconnect (far faster than disk) carries the
rest — in the §3.3.2 storage format, so bf16 stores broadcast half the
fp32 bytes.

This bench streams one chain two ways on an emulated p-process cluster
(`api.emulated_cluster` — the real engine/session wiring, in-process
fabric):

* **naive** ("N readers", today's default): p independent
  ``runtime="local"`` walks, each reading the full chain from the store;
* **broadcast** ("1 reader"): p ``runtime=<multihost member>`` walks —
  only the root touches the store.

Rows (common.emit): per-variant wall time, with the derived column carrying
per-process store bytes.  Each run also appends a JSON record to the BENCH
trajectory (``benchmarks/BENCH.json`` by default) so successive PRs can
track the I/O-reduction ratio.

Usage:
  PYTHONPATH=src python benchmarks/bench_broadcast.py [--smoke] [--procs 2]
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

import common  # noqa: F401  (enables x64 for the fp comparisons)
from repro import api
from repro.core import mps as M
from repro.data.gamma_store import GammaStore


def _walk(idx: int, source_root: str, runtime, segment_len: int, n: int,
          key, outs: dict, stats: dict) -> None:
    config = api.SamplerConfig(backend="streamed", runtime=runtime,
                               segment_len=segment_len)
    with api.SamplingSession(source_root, config) as session:
        outs[idx] = session.sample(n, key)
        stats[idx] = dict(session.stats)


def _run_cluster(source_root: str, runtimes, segment_len: int, n: int, key
                 ) -> tuple[float, dict, dict]:
    """Drive one session per runtime concurrently; returns (wall, outs,
    stats).  ``runtimes`` of [None]*p means p independent local walks (the
    naive N-readers variant)."""
    outs, stats = {}, {}
    threads = [threading.Thread(
        target=_walk,
        args=(i, source_root, rt or api.LocalRuntime(), segment_len, n, key,
              outs, stats))
        for i, rt in enumerate(runtimes)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=1200)
    wall = time.perf_counter() - t0
    assert len(outs) == len(runtimes), "a walker died"
    return wall, outs, stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--procs", type=int, default=2)
    ap.add_argument("--sites", type=int, default=0)
    ap.add_argument("--chi", type=int, default=0)
    ap.add_argument("--samples", type=int, default=0)
    ap.add_argument("--segment-len", type=int, default=0)
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(__file__), "BENCH.json"),
        help="BENCH trajectory file to append the record to ('' disables)")
    args = ap.parse_args()

    sites = args.sites or (32 if args.smoke else 192)
    chi = args.chi or (8 if args.smoke else 48)
    n = args.samples or (128 if args.smoke else 2048)
    seg = args.segment_len or max(4, sites // 8)
    p = args.procs

    mps = M.gbs_like_mps(jax.random.key(0), sites, chi, 3,
                         dtype=jnp.float32)
    root = tempfile.mkdtemp(prefix="bench_broadcast_")
    try:
        # bf16 storage: the same compression that halves disk reads halves
        # the broadcast bytes (§3.3.2 applied to the wire)
        with GammaStore(root, storage_dtype=jnp.bfloat16,
                        compute_dtype=jnp.float32) as store:
            store.write_mps(mps)
        key = jax.random.key(1)

        common.header()
        # warm the jit cache so neither variant pays compilation in its wall
        _run_cluster(root, [None], seg, n, key)

        # -- naive: every process reads its own Γ (p readers) ---------------
        wall_naive, outs_naive, stats_naive = _run_cluster(
            root, [None] * p, seg, n, key)
        naive_bytes = [stats_naive[i]["io_bytes"] for i in range(p)]
        common.emit("broadcast_naive_total", wall_naive,
                    f"store_bytes_per_proc={naive_bytes}")

        # -- paper §3.1: root reads once, broadcasts (1 reader) -------------
        wall_bc, outs_bc, stats_bc = _run_cluster(
            root, api.emulated_cluster(p, timeout=600.0), seg, n, key)
        bc_bytes = [stats_bc[i]["io_bytes"] for i in range(p)]
        wire = stats_bc[0]["broadcast_send_bytes"]
        common.emit("broadcast_root_total", wall_bc,
                    f"store_bytes_per_proc={bc_bytes}")
        common.emit("broadcast_wire", 0.0, f"bytes={wire}")

        same = all(np.array_equal(outs_bc[i], outs_naive[0])
                   for i in range(p))
        io_reduction = sum(naive_bytes) / max(1, sum(bc_bytes))
        print(f"# {p} procs, chain {sites}x{chi}: store I/O "
              f"{sum(naive_bytes)/1e6:.2f} MB -> {sum(bc_bytes)/1e6:.2f} MB "
              f"({io_reduction:.1f}x fewer store bytes), wire "
              f"{wire/1e6:.2f} MB, bit-identical={same}")
        assert same, "broadcast walk diverged from the local walk"

        common.append_bench_record(
            args.json, "broadcast",
            {"procs": p, "sites": sites, "chi": chi, "samples": n,
             "segment_len": seg, "smoke": bool(args.smoke)},
            naive={"wall_s": wall_naive,
                   "store_bytes_per_proc": naive_bytes},
            root_broadcast={"wall_s": wall_bc,
                            "store_bytes_per_proc": bc_bytes,
                            "wire_bytes": int(wire)},
            store_io_reduction=io_reduction,
            bit_identical=bool(same))
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
