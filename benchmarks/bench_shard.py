"""Chain-sharding bench: §3.1 broadcast plane vs block-cyclic Γ + env handoff.

The broadcast plane (bench_broadcast.py) already collapses *storage* I/O to
1 × chain-bytes, but the interconnect still carries every Γ segment to
every peer: wire bytes grow as O(hosts × chain).  The sharded data plane
(ROADMAP item 3, `repro.shard`) deals the chain's blocks across hosts —
each host reads only its own Γ slice and ships the tiny (N, χ) sampling
environment at ownership boundaries, plus one final sample gather: wire
bytes are O(chain boundaries), independent of host count AND of the
per-site Γ size, which is the whole game at large χ.

This bench walks one chain both ways at 1/2/4 emulated hosts and records,
per host count: walk wall time, per-host store bytes, and the wire bytes
each plane moved (broadcast segments vs env handoffs + gather).  Every
variant is asserted bit-identical to the single-host unsharded walk before
its row counts.

Usage:
  PYTHONPATH=src python benchmarks/bench_shard.py [--smoke] [--hosts 1 2 4]
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

import common  # noqa: F401  (enables x64 for the fp comparisons)
from repro import api
from repro.core import mps as M
from repro.data.gamma_store import GammaStore


def _run_cluster(source_root: str, runtimes, segment_len: int, n: int, key,
                 shard) -> tuple[float, dict, dict]:
    outs, stats, errs = {}, {}, []

    def walk(idx, runtime):
        try:
            config = api.SamplerConfig(backend="streamed", runtime=runtime,
                                       segment_len=segment_len, shard=shard)
            with api.SamplingSession(source_root, config) as session:
                outs[idx] = session.sample(n, key)
                stats[idx] = dict(session.stats)
        except Exception as e:          # noqa: BLE001 - surfaced below
            errs.append(repr(e))

    threads = [threading.Thread(target=walk, args=(i, rt))
               for i, rt in enumerate(runtimes)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=1200)
    wall = time.perf_counter() - t0
    assert not errs and len(outs) == len(runtimes), (errs, sorted(outs))
    return wall, outs, stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--hosts", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--sites", type=int, default=0)
    ap.add_argument("--chi", type=int, default=0)
    ap.add_argument("--samples", type=int, default=0)
    ap.add_argument("--segment-len", type=int, default=0)
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(__file__), "BENCH.json"),
        help="BENCH trajectory file to append the record to ('' disables)")
    args = ap.parse_args()

    sites = args.sites or (32 if args.smoke else 128)
    chi = args.chi or (16 if args.smoke else 64)
    n = args.samples or (128 if args.smoke else 1024)
    seg = args.segment_len or max(2, sites // 16)

    mps = M.gbs_like_mps(jax.random.key(0), sites, chi, 3,
                         dtype=jnp.float32)
    root = tempfile.mkdtemp(prefix="bench_shard_")
    try:
        with GammaStore(root, storage_dtype=jnp.bfloat16,
                        compute_dtype=jnp.float32) as store:
            store.write_mps(mps)
        key = jax.random.key(1)

        common.header()
        # reference + jit warm-up: single-host unsharded walk
        _, ref_outs, ref_stats = _run_cluster(
            root, [api.LocalRuntime()], seg, n, key, shard=None)
        ref = ref_outs[0]
        chain_bytes = ref_stats[0]["io_bytes"]

        rows = []
        for p in sorted(set(args.hosts)):
            cluster = (api.emulated_cluster(p, timeout=600.0)
                       if p > 1 else [api.LocalRuntime()])
            # -- broadcast plane: root reads all, peers receive all Γ -------
            wall_bc, outs_bc, st_bc = _run_cluster(
                root, cluster, seg, n, key, shard=None)
            bc_wire = sum(st_bc[i]["broadcast_send_bytes"] for i in range(p))
            assert all(np.array_equal(outs_bc[i], ref) for i in range(p))

            # -- sharded plane: block-cyclic Γ, env handoff + gather --------
            cluster = (api.emulated_cluster(p, timeout=600.0)
                       if p > 1 else [api.LocalRuntime()])
            wall_sh, outs_sh, st_sh = _run_cluster(
                root, cluster, seg, n, key, shard="auto")
            assert all(np.array_equal(outs_sh[i], ref) for i in range(p))
            sh_wire = sum(st_sh[i]["p2p_send_bytes"] for i in range(p))
            sh_store = [st_sh[i]["io_bytes"] for i in range(p)]
            assert sum(sh_store) == chain_bytes   # chain read exactly once

            common.emit(f"shard_h{p}_broadcast", wall_bc,
                        f"wire_bytes={bc_wire}")
            common.emit(f"shard_h{p}_sharded", wall_sh,
                        f"wire_bytes={sh_wire}")
            rows.append({"hosts": p,
                         "broadcast": {"wall_s": wall_bc,
                                       "wire_bytes": int(bc_wire)},
                         "sharded": {"wall_s": wall_sh,
                                     "wire_bytes": int(sh_wire),
                                     "store_bytes_per_host": sh_store}})
            print(f"# {p} hosts: wire {bc_wire/1e6:.2f} MB broadcast -> "
                  f"{sh_wire/1e6:.2f} MB sharded "
                  f"({bc_wire/max(1, sh_wire):.1f}x), per-host store "
                  f"{[f'{b/1e6:.2f}' for b in sh_store]} MB")

        # the acceptance claim: broadcast wire grows ~linearly with hosts,
        # sharded handoff wire stays O(chain) — flat in host count
        multi = [r for r in rows if r["hosts"] > 1]
        if len(multi) >= 2:
            lo, hi = multi[0], multi[-1]
            bc_growth = hi["broadcast"]["wire_bytes"] / max(
                1, lo["broadcast"]["wire_bytes"])
            sh_growth = hi["sharded"]["wire_bytes"] / max(
                1, lo["sharded"]["wire_bytes"])
            print(f"# {lo['hosts']}→{hi['hosts']} hosts: broadcast wire "
                  f"×{bc_growth:.2f}, sharded wire ×{sh_growth:.2f}")
            assert sh_growth < bc_growth, \
                "sharded wire bytes should scale sublinearly vs broadcast"

        common.append_bench_record(
            args.json, "shard",
            {"sites": sites, "chi": chi, "samples": n, "segment_len": seg,
             "hosts": sorted(set(args.hosts)), "smoke": bool(args.smoke)},
            chain_store_bytes=int(chain_bytes),
            sweep=rows)
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
