"""Fig. 13 + Eq. 7 — single- vs double-site tensor parallel overhead.

Measured: per-site collective wire bytes of each schedule from the compiled
SPMD program (the structural quantity behind the paper's bandwidth
argument); the Eq. 7 overhead model then picks the schedule per hardware.

Paper's claim to reproduce: single-site moves (N·χ)·(p−1)/p... per site
(measured env, a factor d smaller than the unmeasured (N·χ·d) the
double-site AllReduce moves every *two* sites) — so the *average volume is
equal*, and the choice is latency (count) vs bandwidth-efficiency.
"""
from __future__ import annotations

import textwrap

from benchmarks.common import BENCH_JSON, append_bench_record, emit, \
    run_child
from repro.core import perfmodel as PM

_CHILD = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    jax.config.update("jax_enable_x64", True)
    from repro.core import mps as M, parallel as PP
    from repro.launch import hloanalysis as H

    scheme = "__SCHEME__"
    p2 = __P2__
    mesh = jax.make_mesh((1, p2), ("data", "model"))
    SITES, CHI, D, N = 8, 128, 3, 512
    mps = M.random_linear_mps(jax.random.key(0), SITES, CHI, D,
                              dtype=jnp.float32)

    def run(g, lam, seed):
        # internal data plane: this bench lowers the scheme program for HLO
        # analysis, not the repro.api session orchestration
        return PP._multilevel_sample(mesh, M.MPS(g, lam, "linear"), N,
                                     jax.random.key(seed),
                                     PP.ParallelConfig(scheme))
    c = jax.jit(run).lower(mps.gammas, mps.lambdas, 0).compile()
    cost = H.analyze(c.as_text())
    print(json.dumps({
        "wire": cost.collective_wire_bytes,
        "counts": cost.n_collectives,
        "per_type": cost.per_collective,
        "sites": SITES, "n": N, "chi": CHI, "d": D,
    }))
""")


def run(quick: bool = True, json_path: str | None = BENCH_JSON) -> None:
    p2 = 4
    results = {}
    for scheme in ("tp_single", "tp_double"):
        out = run_child(_CHILD.replace("__SCHEME__", scheme)
                        .replace("__P2__", str(p2)), devices=p2)
        results[scheme] = out
        per_site = out["wire"] / out["sites"]
        counts = {k: v / out["sites"] for k, v in out["counts"].items()}
        emit(f"fig13_{scheme}_wire_per_site", 0.0,
             f"{per_site:.0f}B|" + "|".join(
                 f"{k}={v:.2f}/site" for k, v in sorted(counts.items())))

    # the paper's structural claim: double-site halves the big-collective
    # count; average volumes are comparable
    n_single = sum(results["tp_single"]["counts"].values())
    n_double = sum(results["tp_double"]["counts"].values())
    emit("fig13_collective_count_ratio", 0.0,
         f"single/double={n_single / max(n_double, 1):.2f}")

    # Eq. 7 scheme choice on published hardware profiles
    w = PM.Workload(n_samples=10_000_000, n_sites=288, chi=10_000, d=3,
                    micro_batch=20_000)
    nvlink = PM.Hardware(peak_flops=156e12, hbm_bw=2039e9,
                         allreduce_bw=401e9, reducescatter_bw=46e9)
    emit("eq7_choice_nvlink_a100", 0.0, PM.choose_tp_scheme(w, nvlink, p2=4))
    v5e = PM.TPU_V5E
    emit("eq7_choice_tpu_v5e", 0.0, PM.choose_tp_scheme(w, v5e, p2=4))
    for scheme in ("single", "double"):
        o = PM.eq7_tp_overhead(w, v5e, 4, scheme)
        emit(f"eq7_overhead_v5e_{scheme}_p4", 0.0, f"{o:.2%}")

    append_bench_record(
        json_path, "tensor_parallel",
        {"p2": p2, "sites": results["tp_single"]["sites"],
         "chi": results["tp_single"]["chi"],
         "d": results["tp_single"]["d"],
         "samples": results["tp_single"]["n"], "quick": bool(quick)},
        wire_bytes_per_site={
            s: results[s]["wire"] / results[s]["sites"]
            for s in ("tp_single", "tp_double")},
        collective_count_ratio=n_single / max(n_double, 1))


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
