"""Self-healing execution, proven by injection (PR 9 acceptance suite).

Every claim the fault taxonomy makes is exercised here with real injected
damage, never assumed:

* **verified Γ I/O** — a flipped bit / truncated site file surfaces as a
  structured :class:`CorruptSegment` BEFORE any sample is emitted, and the
  rotted file is quarantined (``*.quarantine``) so no later read can
  consume it;
* **peer repair** — on a 2-host sharded cluster, a corrupt owned site is
  re-materialized from the peer's healthy replica and the run completes
  bit-identical to the pristine single-host reference;
* **clean collective failure** — when nobody holds a healthy copy, every
  process raises the same structured fault in the same round (no hang, no
  garbage samples); the broadcast plane ships the error as a frame so
  non-root processes fail identically;
* **bounded retries + dead-letter** — a payload that deterministically
  kills its worker fails its OWN job (kind=poison) after
  ``max_batch_attempts`` hand-outs while an unrelated job on the same
  service completes bit-identically;
* **crash-loop quarantine** — a lane whose fault window is exhausted is
  quarantined with a cooldown readmit instead of hot-respawning forever;
* **durability satellites** — checkpoint leaf digests, sampler-state
  digests, result-cache corrupt-entry accounting, fault metrics.

The in-process :class:`FakePool` stands in for the persistent-process
``WorkerPool`` with the REAL ``LaneHealth`` policy and the real
``execute_payload`` worker half, so the service's fault paths run without
paying a jax import per worker process (the real-process equivalents live
in tests/test_fleet.py's slow tier).
"""
import json
import os
import shutil
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api.remote import execute_payload
from repro.api.service import SamplingService
from repro.data import gamma_store as GS
from repro.data.gamma_store import GammaStore
from repro.runtime import transport
from repro.runtime.elastic import WorkQueue
from repro.runtime.faults import (KINDS, CorruptSegment, CrashLoopLane,
                                  DeadLetter, Fault, FaultError, FaultReport,
                                  classify, dead_letter_kind)
from repro.runtime.transport import LaneHealth, TransportError, WorkerDied


# ---------------------------------------------------------------------------
# fixtures / helpers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chain(tmp_path_factory, linear_mps_10x6):
    """A pristine float64 Γ store WITH its digest manifest — tests that
    inject damage always work on a copy (see :func:`_copy_store`)."""
    root = str(tmp_path_factory.mktemp("faults_gamma"))
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(linear_mps_10x6)
        store.write_digest_manifest()
    return root


def _copy_store(src: str, dst: str) -> str:
    shutil.copytree(src, dst)
    return dst


def _flip_bytes(path: str, n: int = 8) -> None:
    """XOR ``n`` bytes in the middle of a file — simulated disk rot."""
    with open(path, "r+b") as f:
        f.seek(0, os.SEEK_END)
        mid = f.tell() // 2
        f.seek(mid)
        chunk = f.read(n)
        f.seek(mid)
        f.write(bytes(b ^ 0xFF for b in chunk))


def _site_path(root: str, i: int) -> str:
    return os.path.join(root, GS.site_filename(i))


def _baseline(root, n_samples, key, macro_batches):
    """Single-thread-lane reference every fault scenario must match."""
    with SamplingService(workers=1) as svc:
        h = svc.submit(root, n_samples=n_samples, key=key,
                       macro_batches=macro_batches)
        return h.result(timeout=300)


def _run_cluster(runtimes, make_config, sources, n, key):
    """Per-process sources (sharded repair needs per-host roots); returns
    (outs, stats, errs) keyed by process index — callers assert on errs
    instead of this helper, because several tests EXPECT every process to
    fail with the same structured fault."""
    outs, stats, errs = {}, {}, {}

    def run(rt):
        p = rt.process_index
        try:
            with api.SamplingSession(sources[p], make_config(rt)) as sess:
                outs[p] = sess.sample(n, key)
                stats[p] = dict(sess.stats)
        except BaseException as e:      # noqa: BLE001 — asserted by caller
            errs[p] = e

    threads = [threading.Thread(target=run, args=(rt,), daemon=True)
               for rt in runtimes]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert not any(t.is_alive() for t in threads), "cluster run hung"
    return outs, stats, errs


class _FakeWorker:
    def __init__(self):
        self.alive = True
        self.batches = 0
        self.dispatch_bytes = 0


class FakePool:
    """In-process WorkerPool stand-in: the real ``LaneHealth`` policy, the
    real ``execute_payload`` worker half, and a ``fail_filter(name,
    payload) -> bool`` chaos seam that kills the (fake) worker."""

    def __init__(self, health=None):
        self.workers: dict[str, _FakeWorker] = {}
        self.injectors: list = []
        self.spawned = 0
        self.reaped = 0
        self.faults = 0
        self.health = LaneHealth() if health is None else health
        self.observer = None
        self.fail_filter = None
        self._cache: dict = {}          # persistent sessions, like serve()

    def spawn(self, name):
        if name in self.workers and self.workers[name].alive:
            raise ValueError(f"worker {name!r} already running")
        w = _FakeWorker()
        self.workers[name] = w
        self.spawned += 1
        return w

    def reap(self, name, kill=False):
        if self.workers.pop(name, None) is not None:
            self.reaped += 1

    def respawn(self, name):
        delay = self.health.check_respawn(name)   # may raise CrashLoopLane
        if delay:
            time.sleep(min(delay, 0.05))
        self.reap(name, kill=True)
        return self.spawn(name)

    def call(self, name, payload):
        w = self.workers.get(name)
        if w is None:
            raise WorkerDied(f"no worker {name!r} in the pool")
        try:
            if self.fail_filter is not None and self.fail_filter(name,
                                                                 payload):
                w.alive = False
                raise WorkerDied(f"worker {name!r} killed by injected fault")
            out = execute_payload(payload, cache=self._cache)
            w.batches += 1
            self.health.record_success(name)
            return out
        except TransportError:
            self.faults += 1
            self.health.record_fault(name)
            raise

    def stats(self):
        out = {"workers": len(self.workers), "spawned": self.spawned,
               "reaped": self.reaped, "faults": self.faults,
               "batches": {n: w.batches for n, w in self.workers.items()},
               "dispatch_bytes": 0}
        out.update(self.health.stats())
        return out

    def close(self):
        self.workers.clear()
        for sess in self._cache.values():
            sess.close()
        self._cache.clear()


# ---------------------------------------------------------------------------
# taxonomy units
# ---------------------------------------------------------------------------

def test_fault_kind_closed_set():
    for k in KINDS:
        Fault(kind=k, message="ok")
    with pytest.raises(ValueError):
        Fault(kind="gremlins", message="no such kind")


def test_fault_to_dict_and_context():
    f = Fault(kind="corruption", message="m", site=3)
    d = f.to_dict()
    assert d["kind"] == "corruption" and d["site"] == 3
    assert "batch" not in d and "lane" not in d     # empty context omitted
    g = f.with_context(site=9, batch=1, lane="lane-0")
    assert g.site == 3                  # never overwrites existing context
    assert g.batch == 1 and g.lane == "lane-0"
    assert f.with_context() is f


def test_fault_report_counts_and_dict():
    r = FaultReport()
    r.add(Fault(kind="transport", message="a", batch=1))
    r.add(Fault(kind="transport", message="b", batch=1))
    r.add(Fault(kind="corruption", message="c", site=4))
    counts = r.counts()
    assert counts["transport"] == 2 and counts["corruption"] == 1
    assert counts["poison"] == 0        # every kind present, zero when clean
    d = r.to_dict()
    assert len(d["faults"]) == 3 and d["dead_letter"] is None


def test_classify_matrix():
    assert classify(WorkerDied("gone"), batch=2).kind == "transport"
    assert classify(TransportError("x exceeded the 5s deadline")
                    ).kind == "timeout"
    assert classify(TransportError("pipe broke")).kind == "transport"
    assert classify(TimeoutError("slow")).kind == "timeout"
    assert classify(MemoryError()).kind == "resource"
    assert classify(OSError("disk full")).kind == "resource"
    assert classify(ValueError("a plain job error")) is None
    # a FaultError keeps its own fault, context fills only the gaps
    inner = CorruptSegment(Fault(kind="corruption", message="rot", site=7))
    out = classify(inner, batch=3, site=99)
    assert out.kind == "corruption" and out.site == 7 and out.batch == 3


def test_dead_letter_kind_poison_signature():
    t = lambda: Fault(kind="transport", message="died", batch=0)  # noqa: E731
    assert dead_letter_kind([t(), t(), t()]) == "poison"
    assert dead_letter_kind([t(), t()]) == "poison"
    assert dead_letter_kind([t()]) == "transport"
    assert dead_letter_kind([]) == "transport"
    assert dead_letter_kind(
        [Fault(kind="timeout", message="ewma", batch=0),
         Fault(kind="timeout", message="ewma", batch=0),
         t()]) == "timeout"             # dominant kind when not crash-looping


def test_workqueue_counts_attempts():
    q = WorkQueue(2)
    assert q.attempts(0) == 0
    b = q.claim("w0", now=0.0)
    assert q.attempts(b) == 1
    q.fail("w0")
    assert q.claim("w1", now=0.0) == b          # requeued re-offers first
    assert q.attempts(b) == 2
    q.complete(b, worker="w1")
    assert q.attempts(b) == 2


# ---------------------------------------------------------------------------
# wire checksums
# ---------------------------------------------------------------------------

def test_frame_crc_mismatch_rejected_at_decode():
    import io
    buf = io.BytesIO()
    transport.write_frame(buf, b"hello fastmps frame")
    data = bytearray(buf.getvalue())
    data[-3] ^= 0x01                    # flip one body byte
    with pytest.raises(TransportError) as ei:
        transport.read_frame(io.BytesIO(bytes(data)))
    assert not isinstance(ei.value, WorkerDied)
    assert "checksum" in str(ei.value)


def test_segment_payload_crc_rejected(chain):
    with GammaStore(chain, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        payload = store.get_segment_raw(2, 2)
        GS.decode_segment(payload)              # clean payload decodes
        bad = dict(payload)
        lam = np.array(payload["lam"], copy=True)
        lam.flat[0] += 1.0                      # corrupt in flight
        bad["lam"] = lam
        with pytest.raises(CorruptSegment) as ei:
            GS.decode_segment(bad)
        assert ei.value.fault.kind == "corruption"
        assert ei.value.fault.site == 2


# ---------------------------------------------------------------------------
# verified Γ I/O: detect, quarantine
# ---------------------------------------------------------------------------

def test_bitflip_detected_and_quarantined(chain, tmp_path):
    root = _copy_store(chain, str(tmp_path / "rot"))
    _flip_bytes(_site_path(root, 3))
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        # single host, verify off: the structural npz catch still fires
        with pytest.raises(CorruptSegment) as ei:
            store.get_segment(2, 2)
        f = ei.value.fault
        assert f.kind == "corruption" and f.site == 3 and f.store == root
        assert store.quarantined_sites == 1
    assert not os.path.exists(_site_path(root, 3))
    assert os.path.exists(_site_path(root, 3) + ".quarantine")


def test_digest_mismatch_detected_when_verify_on(chain, tmp_path):
    root = _copy_store(chain, str(tmp_path / "stale"))
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64, verify=True) as store:
        g, lam = store.get(0, prefetch_next=False)   # healthy: verified read
        assert store.verified_reads >= 1
        # overwrite site 2 with a structurally VALID but different file —
        # only the manifest digest can catch this
        np.savez(_site_path(root, 2), gamma=np.zeros_like(g),
                 gshape=np.array(g.shape), lam=np.zeros_like(lam),
                 two_byte=np.array(False))
        with pytest.raises(CorruptSegment) as ei:
            store.get(2, prefetch_next=False)
        assert ei.value.fault.kind == "corruption"
        assert "digest" in ei.value.fault.message
    assert os.path.exists(_site_path(root, 2) + ".quarantine")


def test_truncated_site_detected(chain, tmp_path):
    root = _copy_store(chain, str(tmp_path / "torn"))
    path = _site_path(root, 5)
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        with pytest.raises(CorruptSegment):
            store.get(5, prefetch_next=False)
    assert os.path.exists(path + ".quarantine")


def test_corrupt_store_fails_job_with_structured_fault(chain, tmp_path):
    """End to end on one host: the service job FAILS with the taxonomy
    fault — no samples emitted, fault_report served on the handle."""
    root = _copy_store(chain, str(tmp_path / "svc_rot"))
    _flip_bytes(_site_path(root, 3))
    with SamplingService(workers=1) as svc:
        h = svc.submit(root, api.SamplerConfig(backend="streamed",
                                               segment_len=2),
                       n_samples=8, key=jax.random.key(0))
        with pytest.raises(CorruptSegment):
            h.result(timeout=120)
        assert h.status() == "failed"
        report = h.fault_report()
        assert report["counts"]["corruption"] >= 1
        assert svc.stats()["faults"]["corruption"] >= 1


# ---------------------------------------------------------------------------
# cluster planes: error frames, peer repair, aligned failure
# ---------------------------------------------------------------------------

def test_broadcast_plane_corrupt_site_fails_every_process(chain, tmp_path):
    """Non-sharded 2-host broadcast: the root detects the rot, ships the
    fault as an error FRAME, and every process raises the same structured
    CorruptSegment instead of hanging in the collective.  Site 9 sits in
    the last segment, so the failure round has no in-flight prefetch."""
    root = _copy_store(chain, str(tmp_path / "bcast_rot"))
    _flip_bytes(_site_path(root, 9))
    outs, _, errs = _run_cluster(
        api.emulated_cluster(2),
        lambda rt: api.SamplerConfig(runtime=rt, backend="streamed",
                                     segment_len=2),
        {0: root, 1: root}, 8, jax.random.key(3))
    assert not outs, "no process may emit samples from rotted bytes"
    assert set(errs) == {0, 1}
    for e in errs.values():
        assert isinstance(e, CorruptSegment)
        assert e.fault.kind == "corruption" and e.fault.site == 9


def test_sharded_peer_repair_bitidentical(chain, tmp_path):
    """The headline repair cell: 2 sharded hosts with per-host replica
    roots; host 0's copy of an owned site is rotted.  The pre-walk repair
    round re-materializes it from host 1's healthy replica over the tagged
    send/recv, and the run completes bit-identical to the pristine
    single-host reference."""
    key = jax.random.key(23)
    with api.SamplingSession(chain, api.SamplerConfig(
            backend="streamed", segment_len=2)) as sess:
        ref = sess.sample(16, key)
    r0 = _copy_store(chain, str(tmp_path / "host0"))
    r1 = _copy_store(chain, str(tmp_path / "host1"))
    with open(_site_path(chain, 4), "rb") as f:
        pristine = f.read()
    _flip_bytes(_site_path(r0, 4))      # block=2 → site 4 is host0-owned
    outs, stats, errs = _run_cluster(
        api.emulated_cluster(2),
        lambda rt: api.SamplerConfig(runtime=rt, backend="streamed",
                                     segment_len=2, shard="auto"),
        {0: r0, 1: r1}, 16, key)
    assert not errs, errs
    assert np.array_equal(outs[0], ref)
    assert np.array_equal(outs[1], ref)
    assert stats[0]["quarantined_sites"] == 1
    assert stats[0]["repaired_sites"] == 1
    assert stats[1]["repaired_sites"] == 0
    # host 0's file is byte-identical to the pristine source again and the
    # quarantined copy was cleared by the restore
    with open(_site_path(r0, 4), "rb") as f:
        assert f.read() == pristine
    assert not os.path.exists(_site_path(r0, 4) + ".quarantine")


def test_sharded_unrepairable_fails_every_process_cleanly(chain, tmp_path):
    """Shared-root sharded cluster: the only copy of an owned site is rot,
    so there is no healthy holder — EVERY process must raise the same
    structured fault in the same collective round (aligned failure, no
    hang, no samples)."""
    root = _copy_store(chain, str(tmp_path / "shard_rot"))
    _flip_bytes(_site_path(root, 4))
    outs, _, errs = _run_cluster(
        api.emulated_cluster(2),
        lambda rt: api.SamplerConfig(runtime=rt, backend="streamed",
                                     segment_len=2, shard="auto"),
        {0: root, 1: root}, 16, jax.random.key(5))
    assert not outs
    assert set(errs) == {0, 1}
    for e in errs.values():
        assert isinstance(e, CorruptSegment)
        assert e.fault.kind == "corruption" and e.fault.site == 4
        assert "no peer holds a healthy copy" in e.fault.message


# ---------------------------------------------------------------------------
# bounded retries, dead-letter, crash-loop quarantine (FakePool lanes)
# ---------------------------------------------------------------------------

def test_poison_batch_dead_letters_its_job_only(chain):
    """A payload that deterministically kills its worker dead-letters its
    JOB (kind=poison) in exactly max_batch_attempts hand-outs — and an
    unrelated job on the same service completes bit-identically to the
    thread-lane baseline.  The lane is NOT quarantined: 3 faults sit under
    the default 5-per-window crash-loop threshold."""
    key = jax.random.key(11)
    ref = _baseline(chain, 16, key, 2)
    pool = FakePool(health=LaneHealth(backoff_base=0.001))
    pool.fail_filter = (lambda name, payload:
                        (payload.get("job") or {}).get("job_id") == 0
                        and payload["job"]["batch_id"] == 1)
    try:
        with SamplingService(workers=1, pool=pool,
                             max_batch_attempts=3) as svc:
            h_poison = svc.submit(chain, n_samples=16, key=key,
                                  macro_batches=2)
            with pytest.raises(DeadLetter) as ei:
                h_poison.result(timeout=300)
            assert h_poison.status() == "failed"
            assert ei.value.fault.kind == "poison"
            assert ei.value.report.dead_letter == {
                "batch": 1, "attempts": 3, "kind": "poison"}
            report = h_poison.fault_report()
            assert report["dead_letter"]["kind"] == "poison"
            assert report["counts"]["transport"] == 3
            assert report["counts"]["poison"] == 1
            # batch 0 completed before the poison batch killed the job
            assert h_poison.progress["blocks"] == 1

            # the fleet keeps flowing: an unrelated job is bit-exact
            h_ok = svc.submit(chain, n_samples=16, key=key, macro_batches=2)
            assert np.array_equal(h_ok.result(timeout=300), ref)

            st = svc.stats()
            assert st["dead_letters"] == 1
            assert st["faults"]["poison"] == 1
            assert st["faults"]["transport"] == 3
            assert st["transport"]["lane_quarantines"] == 0
            assert st["transport"]["quarantined"] == []
    finally:
        pool.close()


def test_crash_loop_lane_quarantined_then_readmitted(chain):
    """A lane that faults on EVERY dispatch exhausts its fault window, is
    quarantined (removed + cooldown) while the healthy lane finishes the
    job bit-identically, and is readmitted under its stable name once the
    cooldown expires."""
    key = jax.random.key(17)
    ref = _baseline(chain, 32, key, 4)
    broken = {"lane-0"}
    pool = FakePool(health=LaneHealth(backoff_base=0.001,
                                      max_faults_per_window=2))
    pool.fail_filter = lambda name, payload: name in broken
    try:
        with SamplingService(workers=2, pool=pool, max_batch_attempts=50,
                             lane_quarantine_s=0.4) as svc:
            h = svc.submit(chain, n_samples=32, key=key, macro_batches=4)
            assert np.array_equal(h.result(timeout=300), ref)

            deadline = time.monotonic() + 30
            while (svc.stats()["transport"]["lane_quarantines"] < 1
                   and time.monotonic() < deadline):
                time.sleep(0.02)
            st = svc.stats()
            assert st["transport"]["lane_quarantines"] == 1
            assert st["faults"]["transport"] >= 2

            broken.clear()              # the lane's host "recovered"
            deadline = time.monotonic() + 30
            while (svc.stats()["transport"]["lane_readmits"] < 1
                   and time.monotonic() < deadline):
                time.sleep(0.02)
            st = svc.stats()
            assert st["transport"]["lane_readmits"] == 1
            assert st["transport"]["quarantined"] == []
            assert "lane-0" in svc.workers()
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# durability satellites: checkpoints, sampler state, result cache, metrics
# ---------------------------------------------------------------------------

def test_checkpoint_leaf_digest_detects_rot(tmp_path):
    from repro.checkpoint.store import load_checkpoint, save_checkpoint

    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": jnp.ones((5,), jnp.float32)}
    d = save_checkpoint(str(tmp_path), 1, tree)
    out, step, _ = load_checkpoint(str(tmp_path), tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    # .npy has no internal checksum: the manifest digest is the ONLY thing
    # standing between a flipped bit and a silent bad resume
    leaf = sorted(f for f in os.listdir(d) if f.endswith(".npy"))[0]
    _flip_bytes(os.path.join(d, leaf), n=1)
    with pytest.raises(CorruptSegment) as ei:
        load_checkpoint(str(tmp_path), tree)
    assert ei.value.fault.kind == "corruption"
    assert "digest mismatch" in ei.value.fault.message


def test_sampler_state_digest_detects_tamper(tmp_path):
    from repro.checkpoint.sampler_state import (load_sampler_state,
                                                save_sampler_state)
    from repro.core.sampler import SamplerState

    state = SamplerState(jnp.ones((4, 6)), jax.random.key(0),
                         jnp.zeros((4,)))
    samples = np.arange(8, dtype=np.int8).reshape(4, 2)
    fn = save_sampler_state(str(tmp_path), 3, state, samples)
    site, loaded, got = load_sampler_state(str(tmp_path))
    assert site == 3
    np.testing.assert_array_equal(got, samples)
    np.testing.assert_array_equal(np.asarray(loaded.env),
                                  np.asarray(state.env))
    # tamper: rewrite the npz with modified samples but the OLD digest
    with np.load(fn) as z:
        arrs = {k: z[k] for k in z.files}
    arrs["samples"] = arrs["samples"] + 1
    np.savez(fn, **arrs)
    with pytest.raises(CorruptSegment) as ei:
        load_sampler_state(str(tmp_path))
    assert ei.value.fault.kind == "corruption" and ei.value.fault.site == 3


def test_result_cache_corrupt_entry_dropped_loudly(tmp_path):
    from repro.runtime.transport import array_to_frame
    from repro.serve.cache import ResultCache

    d = str(tmp_path / "cache")
    c1 = ResultCache(cache_dir=d)
    entry, status = c1.get_or_begin("k1", 1)
    assert status == "miss"
    entry.publish(0, array_to_frame(np.arange(6, dtype=np.int8)))
    entry.finish()
    c1.seal(entry)
    # a fresh cache serves the sealed entry from disk
    assert ResultCache(cache_dir=d).get_or_begin("k1", 1)[1] == "hit"

    with open(os.path.join(d, "k1", "meta.json"), "w") as f:
        f.write("{this is not json")
    events = []
    c3 = ResultCache(cache_dir=d)
    c3.observer = lambda ev, **kw: events.append((ev, kw))
    _, s3 = c3.get_or_begin("k1", 1)
    assert s3 == "miss"                 # falls through to a clean recompute
    assert c3.corrupt_entries == 1
    assert c3.stats()["corrupt_entries"] == 1
    assert ("cache_corrupt", {"key": "k1"}) in events
    assert not os.path.exists(os.path.join(d, "k1"))


def test_fault_metrics_rendered():
    from repro.obs.metrics import MetricsRegistry, instrument_service

    reg = MetricsRegistry()
    with SamplingService(workers=0) as svc:
        obs = instrument_service(svc, reg)
        obs("fault", kind="corruption")
        obs("fault", kind="poison")
        obs("lane_quarantine", worker="lane-0")
        obs("lane_readmit", worker="lane-0")
        snap = reg.snapshot()
    faults = snap["fastmps_faults_total"]
    assert faults[("", (("kind", "corruption"),))] == 1
    assert faults[("", (("kind", "poison"),))] == 1
    assert snap["fastmps_lane_quarantines_total"][("", ())] == 1
    assert snap["fastmps_lane_readmits_total"][("", ())] == 1
    assert snap["fastmps_dead_letters"][("", ())] == 0
    assert snap["fastmps_quarantined_lanes"][("", ())] == 0
    text = reg.render()
    assert 'fastmps_faults_total{kind="corruption"}' in text


def test_lane_health_forgive_clears_window():
    h = LaneHealth(max_faults_per_window=2, backoff_base=0.001)
    h.record_fault("w")
    h.record_fault("w")
    with pytest.raises(CrashLoopLane) as ei:
        h.check_respawn("w")
    assert ei.value.fault.lane == "w"
    h.forgive("w")                      # quarantine cooldown IS the penalty
    assert h.window_faults("w") == 0
    assert h.check_respawn("w") == 0.0  # readmit respawns clean


# ---------------------------------------------------------------------------
# the operator-facing failure path (slow: one subprocess jax import)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_launch_cli_structured_failure_on_corrupt_store(chain, tmp_path):
    """``python -m repro.launch.sample`` against a rotted store exits with
    code 2 and a machine-readable fault record on stderr — "your data is
    bad", distinguishable from a driver crash."""
    root = _copy_store(chain, str(tmp_path / "cli_rot"))
    _flip_bytes(_site_path(root, 3))
    out_dir = str(tmp_path / "cli_out")
    src = os.path.dirname(os.path.dirname(os.path.abspath(api.__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.sample", "--stream",
         "--store", root, "--sites", "10", "--chi", "6", "--samples", "8",
         "--macro-batches", "1", "--segment-len", "2", "--out", out_dir],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 2, (proc.stdout, proc.stderr)
    i = proc.stderr.rindex('"fault"')
    record = json.loads(proc.stderr[proc.stderr.rindex("{", 0, i):])
    assert record["fault"]["kind"] == "corruption"
    assert record["fault"]["site"] == 3
    # no batch file was written from rotted bytes
    assert not [f for f in os.listdir(out_dir) if f.startswith("batch_")]
