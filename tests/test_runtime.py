"""Elasticity + straggler mitigation (control plane)."""
from repro.runtime.elastic import WorkQueue, partition_batches
from repro.runtime.stragglers import StragglerMitigator


def test_partition_deterministic_round_robin():
    p = partition_batches(range(7), ["a", "b", "c"])
    assert p == {"a": [0, 3, 6], "b": [1, 4], "c": [2, 5]}


def test_queue_claim_complete():
    q = WorkQueue(4)
    assert q.claim("w0", now=0.0) == 0
    assert q.claim("w1", now=0.0) == 1
    q.complete(0)
    assert q.claim("w0", now=1.0) == 2
    assert sorted(q.pending) == [1, 2, 3]
    assert not q.finished


def test_worker_failure_requeues():
    q = WorkQueue(3)
    q.claim("w0", now=0.0)
    q.claim("w1", now=0.0)
    q.fail("w0")                       # node loss
    # batch 0 is claimable again, by anyone
    assert q.claim("w2", now=1.0) == 0


def test_elastic_scale_up_and_down():
    q = WorkQueue(6)
    b0 = q.claim("w0", now=0.0)
    q.complete(b0)
    q.add_worker("w1")                 # scale up mid-run
    assert q.claim("w1", now=1.0) is not None
    q.remove_worker("w1")              # scale down: w1's batch requeued
    claims = []
    while (b := q.claim("w0", now=2.0)) is not None:
        claims.append(b)
        q.complete(b)
    assert q.finished


def test_idempotent_batches_after_restart():
    """Completed batches are never re-handed-out; pending ones are."""
    q = WorkQueue(5)
    for _ in range(2):
        b = q.claim("w0", now=0.0)
        q.complete(b)
    done = [b for b, r in q.records.items() if r.done]
    q.fail("w0")
    rest = []
    while (b := q.claim("w1", now=1.0)) is not None:
        rest.append(b)
        q.complete(b)
    assert sorted(done + rest) == [0, 1, 2, 3, 4]
    assert len(done + rest) == 5       # nothing recomputed


def test_straggler_steal():
    q = WorkQueue(3)
    sm = StragglerMitigator(q, k=2.0)
    b = q.claim("slow", now=0.0)
    sm.observe_completion(1.0)         # EWMA = 1.0 → deadline = 2.0
    assert sm.deadline == 2.0
    # not late yet
    assert sm.maybe_steal("idle", now=1.5) is None
    # now late → duplicate issued to the idle worker
    stolen = sm.maybe_steal("idle", now=3.5)
    assert stolen == b
    assert sm.duplicates == 1
    # first completion wins; queue converges
    q.complete(stolen)
    assert b not in q.pending


def test_straggler_no_deadline_before_observations():
    q = WorkQueue(1)
    sm = StragglerMitigator(q)
    q.claim("w", now=0.0)
    assert sm.maybe_steal("idle", now=100.0) is None
