"""Property-based tests (hypothesis) for the system's invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dynamic_bond as DB
from repro.core import precision
from repro.core.sampler import draw_from_probs
from repro.optim import compression as C

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


@hypothesis.given(
    probs=hnp.arrays(np.float64, hnp.array_shapes(min_dims=2, max_dims=2,
                                                  min_side=1, max_side=16),
                     elements=st.floats(0, 1e6, allow_nan=False)),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_draw_in_range(probs, seed):
    out = draw_from_probs(jnp.asarray(probs), jax.random.key(seed))
    d = probs.shape[1]
    assert out.shape == (probs.shape[0],)
    assert np.all((np.asarray(out) >= 0) & (np.asarray(out) < d))


@hypothesis.given(
    scale_exp=st.lists(st.floats(-30, 30), min_size=1, max_size=8),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_draw_invariant_under_row_scaling(scale_exp, seed):
    """Alg.1 normalisation ⇒ the draw depends only on the *relative* probs
    per row — the foundation of per-sample scaling (§3.3)."""
    n = len(scale_exp)
    probs = np.asarray(jax.random.uniform(jax.random.key(1), (n, 4),
                                          dtype=jnp.float64)) + 1e-3
    scaled = probs * (10.0 ** np.asarray(scale_exp))[:, None]
    a = draw_from_probs(jnp.asarray(probs), jax.random.key(seed))
    b = draw_from_probs(jnp.asarray(scaled), jax.random.key(seed))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@hypothesis.given(
    env=hnp.arrays(np.float64,
                   hnp.array_shapes(min_dims=2, max_dims=2, min_side=1,
                                    max_side=32),
                   elements=st.one_of(
                       st.just(0.0),
                       st.floats(1e-100, 1e100),
                       st.floats(-1e100, -1e-100))),
    mode=st.sampled_from(["none", "global", "per_sample"]),
)
def test_rescale_invariants(env, mode):
    out, lg = precision.rescale(jnp.asarray(env), mode)
    assert out.shape == env.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    if mode == "per_sample":
        m = np.max(np.abs(np.asarray(out)), axis=1)
        nz = np.max(np.abs(env), axis=1) > 0
        np.testing.assert_allclose(m[nz], 1.0, rtol=1e-12)
    # rescale must be exactly invertible through the log factor
    if mode != "none":
        back = np.asarray(out) * (10.0 ** np.asarray(lg))[:, None]
        ok = np.isfinite(back)
        np.testing.assert_allclose(back[ok], env[ok], rtol=1e-9, atol=1e-300)


@hypothesis.given(
    x=hnp.arrays(np.float32,
                 st.integers(1, 2000),
                 elements=st.floats(-1e4, 1e4, allow_nan=False, width=32)),
)
def test_int8_compression_bound(x):
    q, scale = C.int8_compress(jnp.asarray(x))
    y = np.asarray(C.int8_decompress(q, scale, x.shape, jnp.float32))
    pad = (-x.size) % C.BLOCK
    bound = np.repeat(np.asarray(scale) / 2, C.BLOCK)[: x.size] + 1e-6
    assert np.all(np.abs(y - x) <= bound)


@hypothesis.given(
    n=st.integers(3, 200),
    chi_max=st.integers(2, 512),
    photons=st.floats(0.05, 4.0),
)
def test_area_law_profile_properties(n, chi_max, photons):
    prof = DB.area_law_profile(n, chi_max, photons)
    assert prof.min() >= 1 and prof.max() <= chi_max
    mid = (n - 1) // 2          # bond i splits i+1 | n-1-i sites
    assert prof[0] <= prof[mid] and prof[-1] <= prof[mid]   # edge ≤ centre
    m = DB.table1_metrics(prof, chi_max)
    assert 0 < m["comp_ratio"] <= 1.0
    assert m["equiv_chi"] <= chi_max


@hypothesis.given(
    buckets=st.lists(st.integers(1, 100), min_size=1, max_size=5, unique=True),
    data=st.data(),
)
def test_bucketize_dominates(buckets, data):
    n = data.draw(st.integers(1, 50))
    prof = np.asarray(data.draw(st.lists(
        st.integers(1, max(buckets)), min_size=n, max_size=n)))
    buck = DB.bucketize(prof, buckets)
    assert np.all(buck >= prof)
    assert set(np.unique(buck)) <= set(buckets)


# ---------------------------------------------------------------------------
# WorkQueue invariants under arbitrary interleavings (fleet control plane)
# ---------------------------------------------------------------------------
# The op vocabulary and the invariant checker live in tests/chaos.py
# (run_queue_script), shared with the seeded-random storms in
# tests/test_fleet.py so the same engine runs with and without hypothesis.

_worker_ix = st.integers(0, 3)
_queue_op = st.one_of(
    st.tuples(st.just("add"), _worker_ix),
    st.tuples(st.just("remove"), _worker_ix),
    st.tuples(st.just("claim"), _worker_ix),
    st.tuples(st.just("complete"), _worker_ix),
    st.tuples(st.just("reclaim"), st.integers(0, 4)),
    st.tuples(st.just("tick")),
)


@hypothesis.given(n_batches=st.integers(1, 12),
                  ops=st.lists(_queue_op, max_size=150))
def test_workqueue_never_loses_never_double_counts(n_batches, ops):
    """Any interleaving of add/remove/claim/complete/reclaim_stale leaves
    every batch completable exactly once: no batch is ever lost, no
    completion is ever double-counted, and requeued work re-offers FIFO
    before fresh work (checked op-by-op inside the script runner)."""
    from chaos import run_queue_script

    out = run_queue_script(n_batches, ops)
    assert len(out["counted"]) == n_batches
    assert all(v == 1 for v in out["counted"].values())


@hypothesis.given(
    durations=st.lists(st.floats(1e-3, 1e3, allow_nan=False,
                                 allow_infinity=False),
                       min_size=1, max_size=32),
    k=st.floats(0.1, 10.0), alpha=st.floats(0.01, 1.0),
)
def test_straggler_ewma_bounded_by_observations(durations, k, alpha):
    """The EWMA (and so the reclaim deadline) always stays inside the
    [min, max] envelope of observed batch times, scaled by k — the
    deadline can never run away from the data."""
    from repro.runtime.elastic import WorkQueue
    from repro.runtime.stragglers import StragglerMitigator

    m = StragglerMitigator(WorkQueue(1), k=k, ewma_alpha=alpha)
    for d in durations:
        m.observe_completion(d)
    assert min(durations) <= m._ewma <= max(durations)
    assert m.deadline == pytest.approx(k * m._ewma)


# ---------------------------------------------------------------------------
# Chain-shard ownership algebra (repro.shard, ROADMAP item 3)
# ---------------------------------------------------------------------------

@hypothesis.given(
    n_sites=st.integers(1, 96), n_hosts=st.integers(1, 8),
    block=st.integers(1, 24),
)
def test_shard_ownership_partitions_chain(n_sites, n_hosts, block):
    """For ANY (n_sites, hosts, block), the hosts' owned-site sets
    partition the chain — every site is computed exactly once, the
    load-balance invariant the whole sharded walk rests on."""
    from repro.shard import ShardMap

    sm = ShardMap(n_sites=n_sites, n_hosts=n_hosts, block=block)
    owned = [sm.owned_sites(h) for h in range(n_hosts)]
    assert sorted(i for sites in owned for i in sites) == list(range(n_sites))
    for h, sites in enumerate(owned):
        assert all(sm.owner(i) == h for i in sites)
        # block-cyclic: a host's sites come in runs of ≤ block consecutive
        runs, prev = 1, None
        for i in sites:
            runs = runs + 1 if prev is not None and i == prev + 1 else 1
            assert runs <= block
            prev = i


@hypothesis.given(
    segment_len=st.integers(1, 8), mult=st.integers(1, 4),
    n_sites=st.integers(1, 96), n_hosts=st.integers(1, 6),
)
def test_shard_handoffs_follow_chain_order(segment_len, mult, n_sites,
                                           n_hosts):
    """With the shard block a whole number of segments (the plan-time
    alignment rule), every scheduled segment has exactly one owner and the
    handoff sequence marches left→right: boundaries strictly increase,
    each transfer's src is the owner on the left of the boundary and its
    dst the owner on the right."""
    from repro.shard import ShardMap, chain_segments

    sm = ShardMap(n_sites=n_sites, n_hosts=n_hosts,
                  block=segment_len * mult)
    sched = chain_segments(n_sites, segment_len)
    assert [i for s, e, _ in sched for i in range(s, e)] == \
        list(range(n_sites))
    owners = sm.owners_for(sched)           # raises if any segment straddles
    hs = sm.handoffs(sched)
    assert len(hs) == sum(1 for a, b in zip(owners, owners[1:]) if a != b)
    prev_b = -1
    for b, src, dst in hs:
        assert b > prev_b
        prev_b = b
        assert src != dst
        assert sm.owner(b - 1) == src and sm.owner(b) == dst


@hypothesis.given(
    n_sites=st.integers(1, 64), segment_len=st.integers(1, 8),
    breaks=st.lists(st.integers(1, 63), max_size=4), seed=st.integers(0, 99),
)
def test_shard_chain_segments_cover_stages_exactly(n_sites, segment_len,
                                                   breaks, seed):
    """chain_segments tiles [0, n_sites) exactly once for any χ-stage
    split, and no segment crosses a stage boundary — the schedule shape
    the engine and the planner's shard proof must share."""
    from repro.shard import chain_segments

    cuts = sorted({b for b in breaks if b < n_sites})
    edges = [0] + cuts + [n_sites]
    rng = np.random.default_rng(seed)
    stages = [(a, b, int(rng.integers(2, 9)))
              for a, b in zip(edges, edges[1:])]
    sched = chain_segments(n_sites, segment_len, stages)
    assert [i for s, e, _ in sched for i in range(s, e)] == \
        list(range(n_sites))
    for s, e, chi in sched:
        assert e - s <= segment_len
        assert any(a <= s and e <= b and chi == c for a, b, c in stages)
