"""Chain-sharded data plane (ROADMAP item 3): block-cyclic Γ distribution
with pipelined env handoff.

Layers under test, bottom-up: the pure ownership algebra (``ShardMap``),
the enforcing store view (``ShardedGammaStore`` — a foreign Γ read raises,
it never silently falls back), the slice-with-manifest digest story
(``materialize_shard``), plan-time resolution (``SamplerConfig.shard``),
the perfmodel wire accounting, and the acceptance contract itself: an
emulated multi-host sharded walk is bit-identical to the single-host
unsharded run for the same seed, with per-engine counters proving every
host read only the Γ blocks it owns and only the tiny (N, χ) environment
crossed the interconnect.  The 4-host {seq, dp} × {static, dynamic-χ}
matrix and the SIGKILL chaos resume run in subprocesses (slow-marked, 8
forced host devices) alongside tests/test_api.py's matrix.

Hypothesis property tests for the shard algebra live in
tests/test_property.py (the module that already guards on hypothesis
being installed).
"""
import json
import os
import signal
import subprocess
import sys
import textwrap
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import mps as M
from repro.core import sampler as S
from repro.core.perfmodel import Workload, shard_wire_bytes
from repro.data.gamma_store import MANIFEST_NAME, GammaStore
from repro.shard import (ShardMap, ShardViolation, ShardedGammaStore,
                         chain_segments, materialize_shard)


@pytest.fixture(scope="module")
def chain(tmp_path_factory, linear_mps_10x6):
    root = str(tmp_path_factory.mktemp("shard_gamma"))
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(linear_mps_10x6)
    return root, linear_mps_10x6


# ---------------------------------------------------------------------------
# ShardMap — the pure ownership algebra
# ---------------------------------------------------------------------------

def test_owner_is_block_cyclic():
    sm = ShardMap(n_sites=10, n_hosts=3, block=2)
    assert [sm.owner(i) for i in range(10)] == [0, 0, 1, 1, 2, 2, 0, 0, 1, 1]
    assert sm.n_blocks == 5
    with pytest.raises(IndexError):
        sm.owner(10)
    with pytest.raises(IndexError):
        sm.owner(-1)


def test_owned_sites_partition_sweep():
    """Seeded sweep: for any (n_sites, hosts, block), the hosts' owned-site
    sets partition the chain — every site computed exactly once."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = int(rng.integers(1, 64))
        h = int(rng.integers(1, 8))
        b = int(rng.integers(1, 16))
        sm = ShardMap(n_sites=n, n_hosts=h, block=b)
        seen = []
        for host in range(h):
            owned = sm.owned_sites(host)
            assert all(sm.owns(host, i) for i in owned)
            seen += owned
        assert sorted(seen) == list(range(n))


def test_segment_owner_and_straddle():
    sm = ShardMap(n_sites=10, n_hosts=2, block=2)
    assert sm.segment_owner(0, 2) == 0
    assert sm.segment_owner(2, 4) == 1
    assert sm.segment_owner(4, 5) == 0          # sub-block segment is fine
    with pytest.raises(ValueError, match="straddles"):
        sm.segment_owner(1, 3)
    # one host: nothing can straddle
    assert ShardMap(n_sites=10, n_hosts=1, block=2).segment_owner(1, 9) == 0
    with pytest.raises(IndexError):
        sm.segment_owner(8, 11)


def test_handoffs_follow_chain_order():
    sm = ShardMap(n_sites=10, n_hosts=3, block=2)
    sched = chain_segments(10, 2)
    assert sm.owners_for(sched) == [0, 1, 2, 0, 1]
    hs = sm.handoffs(sched)
    assert hs == [(2, 0, 1), (4, 1, 2), (6, 2, 0), (8, 0, 1)]
    boundaries = [b for b, _, _ in hs]
    assert boundaries == sorted(boundaries)
    for b, src, dst in hs:
        assert sm.owner(b - 1) == src and sm.owner(b) == dst


def test_chain_segments_respects_stages():
    # χ-stage boundaries clip segments exactly as the engine's schedule does
    stages = [(0, 3, 4), (3, 8, 8), (8, 10, 4)]
    segs = chain_segments(10, 2, stages)
    assert segs == [(0, 2, 4), (2, 3, 4), (3, 5, 8), (5, 7, 8),
                    (7, 8, 8), (8, 10, 4)]
    covered = [i for s, e, _ in segs for i in range(s, e)]
    assert covered == list(range(10))
    assert chain_segments(6, 10) == [(0, 6, None)]


def test_shardmap_validation():
    for bad in (dict(n_sites=0, n_hosts=1, block=1),
                dict(n_sites=4, n_hosts=0, block=1),
                dict(n_sites=4, n_hosts=1, block=0)):
        with pytest.raises(ValueError):
            ShardMap(**bad)


# ---------------------------------------------------------------------------
# ShardedGammaStore — ownership enforcement + slice digests
# ---------------------------------------------------------------------------

def test_foreign_read_raises_prefetch_is_advisory(chain):
    root, _ = chain
    sm = ShardMap(n_sites=10, n_hosts=2, block=2)
    with ShardedGammaStore(root, sm, host=0, storage_dtype=jnp.float64,
                           compute_dtype=jnp.float64) as view:
        assert view.n_sites == 10              # global chain, not file count
        g, lam = view.get(0, prefetch_next=False)
        assert g.shape == (6, 6, 3)
        with pytest.raises(ShardViolation, match="owned by host 1"):
            view.get(2, prefetch_next=False)
        with pytest.raises(ShardViolation):
            view.get_segment(2, 2, prefetch_next_segment=False)
        # blanket prefetch over a boundary is skipped, not fatal
        view.prefetch(3)
        view.prefetch_segment(0, 4)
        g2, _ = view.get(1, prefetch_next=False)   # still healthy after
        assert g2.shape == (6, 6, 3)
        with pytest.raises(ShardViolation, match="write"):
            view.put(2, np.zeros((6, 6, 3)), np.zeros(6))


def test_meta_redirects_and_empty_host_raises(chain, tmp_path):
    root, _ = chain
    sm = ShardMap(n_sites=10, n_hosts=2, block=2)
    with ShardedGammaStore(root, sm, host=1, storage_dtype=jnp.float64,
                           compute_dtype=jnp.float64) as view:
        assert view.meta(0) == view.meta(2)    # foreign probe → owned shape
    lonely = ShardMap(n_sites=2, n_hosts=4, block=2)   # hosts 2,3 own nothing
    with ShardedGammaStore(str(tmp_path), lonely, host=3) as view:
        with pytest.raises(ShardViolation, match="owns no sites"):
            view.meta(0)


def test_materialized_slice_reproduces_global_digest(chain, tmp_path):
    """Acceptance (satellite 2): each host's slice holds only its owned
    files + the manifest, yet ``digest()`` answers with the WHOLE store's
    Merkle root — the key the gateway's ResultCache addresses results by."""
    root, _ = chain
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as whole:
        global_digest = whole.digest()
    sm = ShardMap(n_sites=10, n_hosts=3, block=2)
    for host in range(3):
        dst = str(tmp_path / f"slice{host}")
        materialize_shard(root, dst, sm, host)
        files = sorted(f for f in os.listdir(dst) if f.endswith(".npz"))
        assert len(files) == len(sm.owned_sites(host))   # capacity scales
        assert os.path.exists(os.path.join(dst, MANIFEST_NAME))
        with ShardedGammaStore(dst, sm, host, storage_dtype=jnp.float64,
                               compute_dtype=jnp.float64) as view:
            assert view.digest() == global_digest
            # and the slice actually serves its own sites
            s0 = sm.owned_sites(host)[0]
            g, _ = view.get(s0, prefetch_next=False)
            assert g.shape == (6, 6, 3)


def test_shared_root_digest_without_manifest(chain):
    # shared-filesystem deployment: foreign files are present, no manifest
    # was ever written — digest() hashes them directly (metadata read)
    root, _ = chain
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as whole:
        global_digest = whole.digest()
    sm = ShardMap(n_sites=10, n_hosts=2, block=4)
    with ShardedGammaStore(root, sm, host=1, storage_dtype=jnp.float64,
                           compute_dtype=jnp.float64) as view:
        assert view.digest() == global_digest


def test_sliced_digest_missing_manifest_raises(chain, tmp_path):
    root, _ = chain
    sm = ShardMap(n_sites=10, n_hosts=2, block=2)
    dst = str(tmp_path / "bare")
    materialize_shard(root, dst, sm, host=0)
    os.remove(os.path.join(dst, MANIFEST_NAME))
    with ShardedGammaStore(dst, sm, host=0, storage_dtype=jnp.float64,
                           compute_dtype=jnp.float64) as view:
        with pytest.raises(FileNotFoundError, match=MANIFEST_NAME):
            view.digest()


def test_put_changes_merkle_digest(tmp_path, linear_mps_10x6):
    root = str(tmp_path / "mut")
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as st:
        st.write_mps(linear_mps_10x6)
        d0 = st.digest()
        assert st.digest() == d0                       # cached, stable
        g, lam = st.get(3, prefetch_next=False)
        st.put(3, np.asarray(g) * 2.0, np.asarray(lam))
        assert st.digest() != d0                       # put invalidates
        leaves = st.site_digests()
        assert set(leaves) == {f"site_{i:06d}.npz" for i in range(10)}


# ---------------------------------------------------------------------------
# Plan-time resolution (SamplerConfig.shard → SessionPlan.shard_block)
# ---------------------------------------------------------------------------

def test_shard_resolution_validation(chain, linear_mps_10x6):
    root, _ = chain
    with api.SamplingSession(linear_mps_10x6,
                             api.SamplerConfig(backend="inmem",
                                               shard="auto")) as sess:
        with pytest.raises(ValueError, match="streamed"):
            sess.plan(8)
    with api.SamplingSession(root, api.SamplerConfig(
            backend="streamed", segment_len=4, shard=2)) as sess:
        with pytest.raises(ValueError, match="whole number of segments"):
            sess.plan(8)


def test_shard_auto_single_host_bitidentical(chain):
    """H=1 is the degenerate shard: same plan fields, same walk, same
    bits — which is also what a remote worker receiving a sharded config
    runs."""
    root, mps = chain
    key = jax.random.key(11)
    ref = np.asarray(S.sample(mps, 24, key))
    with api.SamplingSession(root, api.SamplerConfig(
            backend="streamed", segment_len=2, shard="auto")) as sess:
        plan = sess.plan(24)
        assert plan.shard_block == 2               # AUTO → segment_len
        out = sess.sample(24, key)
        info = sess.explain(24)
    assert np.array_equal(out, ref)
    assert info["shard"]["hosts"] == 1
    assert info["shard"]["sharded_bytes"] == 0     # nothing crosses a wire


def test_remote_backend_carries_shard_config(chain):
    # the serialized config rides to the loopback worker, which resolves
    # the degenerate 1-host shard against its own runtime
    root, mps = chain
    key = jax.random.key(13)
    ref = np.asarray(S.sample(mps, 16, key))
    with api.SamplingSession(root, api.SamplerConfig(
            backend="remote", segment_len=2, shard="auto")) as sess:
        plan = sess.plan(16)
        assert plan.backend == "remote" and plan.shard_block is None
        out = sess.sample(16, key)
    assert np.array_equal(out, ref)


def test_shard_wire_bytes_model():
    w = Workload(n_samples=1000, n_sites=100, chi=512, d=3)
    one = shard_wire_bytes(w, 1, block=10)
    assert one["broadcast_bytes"] == 0 and one["sharded_bytes"] == 0
    four = shard_wire_bytes(w, 4, block=10)
    eight = shard_wire_bytes(w, 8, block=10)
    # broadcast grows with host count; the sharded plane's handoff term
    # depends only on chain boundaries — O(chain), not O(hosts × chain)
    assert eight["broadcast_bytes"] == 7 * four["broadcast_bytes"] / 3
    assert four["handoff_bytes"] == eight["handoff_bytes"]
    assert four["handoff_bytes"] == 9 * 1000 * 512 * 8
    # large-χ regime: Γ broadcast dwarfs env handoff + sample gather
    assert four["sharded_bytes"] < four["broadcast_bytes"]


# ---------------------------------------------------------------------------
# Emulated cluster: sharded walk ≡ single-host unsharded walk
# ---------------------------------------------------------------------------

def _run_cluster(runtimes, make_config, source, n, key, resume=False):
    outs, stats, errs = {}, {}, []

    def run(rt):
        try:
            with api.SamplingSession(source, make_config(rt)) as sess:
                outs[rt.process_index] = sess.sample(n, key, resume=resume)
                stats[rt.process_index] = dict(sess.stats)
        except Exception as e:          # pragma: no cover - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=run, args=(rt,)) for rt in runtimes]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert not errs, errs
    return outs, stats


def test_sharded_2host_bitidentical_with_owned_only_io(chain):
    """The acceptance cell, fast shape: 2 emulated hosts, block-cyclic Γ,
    bit-identical to the unsharded local run — and the counters prove the
    data-plane claim: zero broadcast bytes, per-host store I/O exactly
    proportional to owned sites, only tiny env handoffs on the wire."""
    root, mps = chain
    key = jax.random.key(23)
    with api.SamplingSession(
            root, api.SamplerConfig(backend="streamed",
                                    segment_len=2)) as sess:
        ref = sess.sample(16, key)
        local_bytes = sess.stats["io_bytes"]
    assert np.array_equal(ref, np.asarray(S.sample(mps, 16, key)))

    outs, stats = _run_cluster(
        api.emulated_cluster(2),
        lambda rt: api.SamplerConfig(runtime=rt, backend="streamed",
                                     segment_len=2, shard="auto"),
        root, 16, key)
    assert np.array_equal(outs[0], ref)
    assert np.array_equal(outs[1], ref)
    # block=2, 10 sites → host0 owns {0,1,4,5,8,9}, host1 owns {2,3,6,7}
    assert stats[0]["io_bytes"] == local_bytes * 6 // 10
    assert stats[1]["io_bytes"] == local_bytes * 4 // 10
    assert stats[0]["io_bytes"] + stats[1]["io_bytes"] == local_bytes
    for p in (0, 1):
        assert stats[p]["broadcast_send_bytes"] == 0
        assert stats[p]["broadcast_recv_bytes"] == 0
        # 4 ownership boundaries, every one touches both hosts (send|recv)
        assert stats[p]["handoffs"] == 4
        assert stats[p]["handoff_send_bytes"] > 0
        assert stats[p]["handoff_recv_bytes"] > 0
        # the wire carried envs + the final sample gather — never Γ blocks
        wire = stats[p]["p2p_recv_bytes"]
        assert 0 < wire < local_bytes
    assert stats[0]["owned_segments"] == 3
    assert stats[1]["owned_segments"] == 2


def test_sharded_2host_dynamic_chi_bitidentical(chain):
    root, _ = chain
    key = jax.random.key(29)
    prof = (4, 4, 6, 6, 6, 6, 6, 6, 4, 4)
    with api.SamplingSession(root, api.SamplerConfig(
            backend="streamed", segment_len=2,
            chi_profile=prof)) as sess:
        ref = sess.sample(16, key)
    outs, stats = _run_cluster(
        api.emulated_cluster(2),
        lambda rt: api.SamplerConfig(runtime=rt, backend="streamed",
                                     segment_len=2, chi_profile=prof,
                                     shard="auto"),
        root, 16, key)
    assert np.array_equal(outs[0], ref)
    assert np.array_equal(outs[1], ref)
    assert stats[0]["broadcast_recv_bytes"] == 0
    assert stats[1]["broadcast_recv_bytes"] == 0


def test_shard_misaligned_chi_stage_rejected(chain):
    # a χ stage that splits a block mid-way yields a straddling segment —
    # caught at plan time by the proof against the REAL schedule
    root, _ = chain
    prof = (4,) * 3 + (6,) * 7                 # stage break at site 3
    with api.SamplingSession(root, api.SamplerConfig(
            backend="streamed", segment_len=2, chi_profile=prof,
            runtime=api.emulated_cluster(2)[0], shard=4)) as sess:
        with pytest.raises(ValueError, match="straddles"):
            sess.plan(16)


# ---------------------------------------------------------------------------
# Cluster-synchronized resume (satellite 1)
# ---------------------------------------------------------------------------

def test_broadcast_resume_agrees_on_min_boundary(chain, tmp_path):
    """Two processes stopped at DIFFERENT boundaries (site 6 vs site 4):
    the old engine refused multi-process resume outright; now the cluster
    agrees on min(newest) = 4 and both walk from there in lockstep,
    bit-identical to the uninterrupted run."""
    root, _ = chain
    key = jax.random.key(31)
    with api.SamplingSession(root, api.SamplerConfig(
            backend="streamed", segment_len=2)) as sess:
        ref = sess.sample(16, key)

    dirs = [str(tmp_path / "ck0"), str(tmp_path / "ck1")]
    for d, stop in zip(dirs, (3, 2)):          # newest site 6 vs site 4
        with api.SamplingSession(root, api.SamplerConfig(
                backend="streamed", segment_len=2, checkpoint_dir=d,
                checkpoint_every=1)) as sess:
            sess.sample(16, key, stop_after_segments=stop)

    outs, _ = _run_cluster(
        api.emulated_cluster(2),
        lambda rt: api.SamplerConfig(runtime=rt, backend="streamed",
                                     segment_len=2, checkpoint_every=1,
                                     checkpoint_dir=dirs[rt.process_index]),
        root, 16, key, resume=True)
    assert np.array_equal(outs[0], ref)
    assert np.array_equal(outs[1], ref)


def test_sharded_resume_from_agreed_boundary(chain, tmp_path):
    """Sharded crash consistency: truncate the two hosts' checkpoint dirs
    to different prefixes (an unclean stop), resume — the cluster agrees
    on the min boundary, reloads durable blocks below it, and the rest of
    the walk (including re-handoffs) reproduces the reference exactly."""
    root, _ = chain
    key = jax.random.key(37)
    with api.SamplingSession(root, api.SamplerConfig(
            backend="streamed", segment_len=2)) as sess:
        ref = sess.sample(16, key)

    dirs = [str(tmp_path / "sh0"), str(tmp_path / "sh1")]
    mk = lambda rt: api.SamplerConfig(   # noqa: E731
        runtime=rt, backend="streamed", segment_len=2, shard="auto",
        checkpoint_every=1, checkpoint_dir=dirs[rt.process_index])
    outs, _ = _run_cluster(api.emulated_cluster(2), mk, root, 16, key)
    assert np.array_equal(outs[0], ref)

    # unclean stop: host0 durable through site 4, host1 through site 6
    for d, keep_to in zip(dirs, (4, 6)):
        for f in os.listdir(d):
            site = int(f.split("_")[1].split(".")[0])
            if site > keep_to:
                os.remove(os.path.join(d, f))

    outs, stats = _run_cluster(api.emulated_cluster(2), mk, root, 16, key,
                               resume=True)
    assert np.array_equal(outs[0], ref)
    assert np.array_equal(outs[1], ref)
    # agreed boundary 4 → segments (4,6),(8,10) recompute on host0,
    # (6,8) on host1; blocks below 4 came off disk
    assert stats[0]["owned_segments"] == 2
    assert stats[1]["owned_segments"] == 1


def test_sharded_rejects_stop_after_segments(chain):
    root, _ = chain
    runtimes = api.emulated_cluster(2)
    errs = []

    def run(rt):
        try:
            with api.SamplingSession(root, api.SamplerConfig(
                    runtime=rt, backend="streamed", segment_len=2,
                    shard="auto")) as sess:
                sess.sample(16, jax.random.key(1), stop_after_segments=1)
        except ValueError as e:
            errs.append(str(e))

    threads = [threading.Thread(target=run, args=(rt,)) for rt in runtimes]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert len(errs) == 2 and all("kill" in e for e in errs)


# ---------------------------------------------------------------------------
# 4-host {seq, dp} × {static, dynamic-χ} matrix (subprocess, 8 devices)
# ---------------------------------------------------------------------------

_SHARD_CHILD = textwrap.dedent("""
    import json, os, tempfile, threading
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.core import mps as M
    from repro.data.gamma_store import GammaStore
    from repro.launch.mesh import make_host_mesh

    m = M.random_linear_mps(jax.random.key(0), 8, 8, 3)
    key = jax.random.key(7)
    root = tempfile.mkdtemp()
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as st:
        st.write_mps(m)
    prof = (4, 4, 8, 8, 8, 8, 4, 4)

    out = {}
    for scheme in ("seq", "dp"):
        mesh = make_host_mesh(model=1) if scheme == "dp" else None
        for kind, chi_profile in (("static", None), ("dynamic", prof)):
            cfg = dict(backend="streamed", scheme=scheme, segment_len=2,
                       chi_profile=chi_profile)
            with api.SamplingSession(root, api.SamplerConfig(**cfg),
                                     mesh=mesh) as sess:
                ref = sess.sample(64, key)
                local_bytes = sess.stats["io_bytes"]

            res, stats, errs = {}, {}, []

            def run(rt):
                try:
                    c = api.SamplerConfig(runtime=rt, shard="auto", **cfg)
                    with api.SamplingSession(root, c, mesh=mesh) as sess:
                        res[rt.process_index] = sess.sample(64, key)
                        stats[rt.process_index] = dict(sess.stats)
                except Exception as e:
                    errs.append(repr(e))

            ts = [threading.Thread(target=run, args=(rt,))
                  for rt in api.emulated_cluster(4, timeout=300.0)]
            for t in ts:
                t.start()
            for t in ts:
                t.join(timeout=500)
            cell = f"{scheme}_{kind}"
            out[cell + "_errs"] = errs
            out[cell + "_identical"] = bool(all(
                np.array_equal(res.get(p), ref) for p in range(4)))
            # owned-only Γ I/O: 8 sites / block 2 / 4 hosts → one block
            # each; zero broadcast; sum of reads covers the chain once
            out[cell + "_owned_io"] = bool(
                all(stats[p]["io_bytes"] == local_bytes // 4
                    and stats[p]["broadcast_recv_bytes"] == 0
                    and stats[p]["broadcast_send_bytes"] == 0
                    and stats[p]["owned_segments"] == 1
                    for p in range(4))
                and sum(stats[p]["io_bytes"] for p in range(4))
                == local_bytes)
            # the wire carried envs + sample gather, not Γ: each host's
            # p2p traffic stays well under its share of the Γ bytes
            out[cell + "_wire_o_chain"] = bool(all(
                0 < stats[p]["p2p_recv_bytes"] < local_bytes
                for p in range(4)))
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def shard_matrix_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SHARD_CHILD], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("cell", [
    f"{s}_{k}_{w}" for s in ("seq", "dp") for k in ("static", "dynamic")
    for w in ("identical", "owned_io", "wire_o_chain")])
def test_shard_matrix_4host(shard_matrix_results, cell):
    """Acceptance: emulated 4-host sharded run ≡ single-host unsharded
    across {seq, dp} × {static, dynamic-χ}, with counters proving no host
    read or received a foreign Γ segment."""
    scheme_kind = cell.rsplit("_", 1)[0] if cell.endswith("identical") \
        else cell[: cell.index("_", cell.index("_") + 1)]
    assert shard_matrix_results[scheme_kind + "_errs"] == []
    assert shard_matrix_results[cell]


# ---------------------------------------------------------------------------
# SIGKILL chaos: reclaimed sharded walk is bit-identical (satellite 3)
# ---------------------------------------------------------------------------

_CHAOS_COMMON = textwrap.dedent("""
    import os, sys, threading
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.core import mps as M
    from repro.data.gamma_store import GammaStore

    root, ck0, ck1 = sys.argv[1], sys.argv[2], sys.argv[3]
    m = M.random_linear_mps(jax.random.key(0), 12, 6, 3)
    key = jax.random.key(41)
    if not os.path.exists(os.path.join(root, "site_000000.npz")):
        with GammaStore(root, storage_dtype=jnp.float64,
                        compute_dtype=jnp.float64) as st:
            st.write_mps(m)

    def run_cluster(resume):
        outs, errs = {}, []
        dirs = [ck0, ck1]

        def run(rt):
            try:
                cfg = api.SamplerConfig(
                    runtime=rt, backend="streamed", segment_len=2,
                    shard="auto", checkpoint_every=1,
                    checkpoint_dir=dirs[rt.process_index])
                with api.SamplingSession(root, cfg) as sess:
                    outs[rt.process_index] = sess.sample(32, key,
                                                         resume=resume)
            except Exception as e:
                errs.append(repr(e))
        ts = [threading.Thread(target=run, args=(rt,))
              for rt in api.emulated_cluster(2, timeout=120.0)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=300)
        assert not errs, errs
        return outs
""")

_CHAOS_KILL = _CHAOS_COMMON + textwrap.dedent("""
    import signal, time
    from repro.engine import streaming

    # slow each segment down so the SIGKILL provably lands mid-walk
    _orig = streaming.StreamingEngine._run_segment

    def _slow(self, *a, **k):
        time.sleep(0.25)
        return _orig(self, *a, **k)
    streaming.StreamingEngine._run_segment = _slow

    def watchdog():
        while True:
            done = [f for d in (ck0, ck1) if os.path.isdir(d)
                    for f in os.listdir(d) if f.startswith("site_")]
            if len(done) >= 3:                 # mid-walk, both hosts live
                os.kill(os.getpid(), signal.SIGKILL)
            time.sleep(0.01)
    threading.Thread(target=watchdog, daemon=True).start()
    run_cluster(resume=False)
    print("SURVIVED")                          # must be unreachable
""")

_CHAOS_RESUME = _CHAOS_COMMON + textwrap.dedent("""
    import json
    from repro.core import sampler as S
    ref = np.asarray(S.sample(m, 32, key))
    outs = run_cluster(resume=True)
    print(json.dumps({
        "match0": bool(np.array_equal(outs[0], ref)),
        "match1": bool(np.array_equal(outs[1], ref)),
    }))
""")


@pytest.mark.slow
def test_sharded_sigkill_resume_bitidentical(tmp_path):
    """Chaos acceptance: SIGKILL the whole emulated cluster mid-walk (both
    hosts' checkpoints at whatever boundary they reached), then resume —
    the cluster-min agreement reclaims the walk and the samples are
    bit-identical to an uninterrupted single-host run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    args = [str(tmp_path / "store"), str(tmp_path / "ck0"),
            str(tmp_path / "ck1")]
    proc = subprocess.run([sys.executable, "-c", _CHAOS_KILL] + args,
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == -signal.SIGKILL, (proc.returncode,
                                                proc.stdout, proc.stderr)
    assert "SURVIVED" not in proc.stdout
    # the kill landed mid-walk: some but not all boundaries are durable
    ck_files = [f for d in args[1:] for f in os.listdir(d)
                if f.startswith("site_")]
    assert ck_files, "kill fired before any checkpoint was written"

    proc = subprocess.run([sys.executable, "-c", _CHAOS_RESUME] + args,
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["match0"] and out["match1"]
