"""Workloads subsystem: clamped sampling, BYO-MPS ingest, scenarios.

The tentpole contracts under test:

* **conditioning is exact and rejection-free** — a clamped walk forces
  outcomes through the normal collapse path and returns the Born weight
  of the clamped branch as per-sample ``log_prob``; self-normalized
  weighted frequencies reproduce the conditionals of the exact joint,
  and a fully-clamped walk's ``log_prob`` IS the log joint;
* **clamping perturbs nothing it doesn't touch** — per-site draws are
  independent ``fold_in(base, i)`` uniforms, so sites before the clamp
  are bit-identical to the unclamped run, an empty clamp IS the
  unclamped config, and {inmem, streamed} × {seq, dp} agree bit-exactly
  on clamped output;
* **ingest only accepts what it can sample correctly** — structural
  violations and non-canonical Born chains raise :class:`IngestError`;
  the canonicalizing path preserves the state exactly.
"""
import itertools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.core import clamped as CL
from repro.core import mps as M
from repro.core import sampler as S
from repro.data.gamma_store import GammaStore
from repro.workloads import clamp as WC
from repro.workloads import ingest as IG
from repro.workloads import scenarios as SC


# ---------------------------------------------------------------------------
# clamp spec: normalization / validation / CLI parsing
# ---------------------------------------------------------------------------

def test_normalize_clamp_forms():
    canon = ((2, 1), (4, 0))
    assert WC.normalize_clamp({4: 0, 2: 1}) == canon
    assert WC.normalize_clamp([[4, 0], [2, 1]]) == canon
    assert WC.normalize_clamp({"2": 1, "4": 0}) == canon   # JSON string keys
    assert WC.normalize_clamp(canon) == canon
    assert WC.normalize_clamp(None) is None
    assert WC.normalize_clamp({}) is None                  # empty == absent
    per_sample = WC.normalize_clamp({1: [0, 1, 0]})
    assert per_sample == ((1, (0, 1, 0)),)


@pytest.mark.parametrize("bad", [
    {2: 1, "2": 0},          # duplicate site
    {-1: 0},                 # negative site
    {1: -2},                 # negative outcome
    {1: ()},                 # empty per-sample sequence
    {1.5: 0},                # non-integer site
    {"abc": 0},              # unparseable site
    "2=1",                   # a raw string is not a clamp spec
])
def test_normalize_clamp_rejects(bad):
    with pytest.raises(ValueError):
        WC.normalize_clamp(bad)


def test_validate_clamp_ranges():
    clamp = WC.normalize_clamp({2: 1})
    WC.validate_clamp(clamp, n_sites=6, d=3)
    with pytest.raises(ValueError):
        WC.validate_clamp(clamp, n_sites=2, d=3)           # site out of range
    with pytest.raises(ValueError):
        WC.validate_clamp(clamp, n_sites=6, d=1)           # outcome >= d
    per = WC.normalize_clamp({0: (0, 1, 2)})
    WC.validate_clamp(per, n_sites=6, d=3, n_samples=3)
    with pytest.raises(ValueError):
        WC.validate_clamp(per, n_sites=6, d=3, n_samples=4)  # length mismatch


def test_segment_clamp_arrays():
    cmap = WC.clamp_map(WC.normalize_clamp({2: 1, 5: np.array([0, 2])}))
    mask, vals = WC.segment_clamp_arrays(cmap, 2, 3, 2)    # sites [2, 5)
    assert mask.tolist() == [True, False, False]
    assert vals[0].tolist() == [1, 1]
    mask2, vals2 = WC.segment_clamp_arrays(cmap, 5, 2, 2)  # sites [5, 7)
    assert mask2.tolist() == [True, False]
    assert vals2[0].tolist() == [0, 2]


def test_parse_clamp_arg():
    assert WC.parse_clamp_arg("2=1,4=0") == {2: 1, 4: 0}
    with pytest.raises(ValueError):
        WC.parse_clamp_arg("2")


# ---------------------------------------------------------------------------
# clamped walk vs the exact oracle (core level)
# ---------------------------------------------------------------------------

def _conditional_oracle(mps, clamp_site, clamp_val):
    """Exact conditionals P(site i = s | clamp) by joint restriction."""
    d, sites = mps.phys_dim, mps.n_sites
    joint = M.enumerate_probabilities(mps)
    outs = np.array(list(itertools.product(range(d), repeat=sites)))
    sel = outs[:, clamp_site] == clamp_val
    cond = joint[sel] / joint[sel].sum()
    return outs[sel], cond, float(joint[sel].sum())


@pytest.mark.parametrize("mps_fixture", ["linear_mps_small", "born_mps_6x4"])
def test_clamped_marginals_match_joint_restriction(request, mps_fixture):
    mps = request.getfixturevalue(mps_fixture)
    d, n = mps.phys_dim, 4000
    clamp_site, clamp_val = 2, 1
    clamp = WC.normalize_clamp({clamp_site: clamp_val})
    cmap = WC.clamp_map(clamp)
    mask, vals = WC.segment_clamp_arrays(cmap, 0, mps.n_sites, n)
    cfg = S.SamplerConfig(semantics=mps.semantics)
    samples, lp = CL.sample_clamped(mps, n, jax.random.key(7), cfg,
                                    mask, vals)
    samples, lp = np.asarray(samples), np.asarray(lp, dtype=np.float64)
    assert np.all(samples[:, clamp_site] == clamp_val)
    outs_c, cond, p_branch = _conditional_oracle(mps, clamp_site, clamp_val)
    w = np.exp(lp)
    for i in range(mps.n_sites):
        if i == clamp_site:
            continue
        for s in range(d):
            est = w[samples[:, i] == s].sum() / w.sum()
            exact = cond[outs_c[:, i] == s].sum()
            assert abs(est - exact) < 0.06, (i, s, est, exact)
    # E[w] = P(clamp): w varies only through the sampled prefix
    assert abs(w.mean() - p_branch) < 0.02


def test_fully_clamped_log_prob_is_log_joint(linear_mps_small):
    mps = linear_mps_small
    d, sites = mps.phys_dim, mps.n_sites
    outcome = (1, 0, 2, 1, 0, 1)
    clamp = WC.normalize_clamp(dict(enumerate(outcome)))
    mask, vals = WC.segment_clamp_arrays(WC.clamp_map(clamp), 0, sites, 8)
    _, lp = CL.sample_clamped(mps, 8, jax.random.key(0), S.SamplerConfig(),
                              mask, vals)
    joint = M.enumerate_probabilities(mps)
    expect = np.log(joint[np.ravel_multi_index(outcome, (d,) * sites)])
    np.testing.assert_allclose(np.asarray(lp), expect, rtol=1e-10)


def test_clamp_leaves_untouched_draws_bit_identical(linear_mps_small):
    """Per-site uniforms are independent fold_ins, so forcing site 2
    cannot change any site before it — same draws, same outcomes."""
    mps, n = linear_mps_small, 64
    key = jax.random.key(5)
    base = np.asarray(S.sample(mps, n, key))
    mask, vals = WC.segment_clamp_arrays(
        WC.clamp_map(WC.normalize_clamp({2: 1})), 0, mps.n_sites, n)
    clamped, lp = CL.sample_clamped(mps, n, key, S.SamplerConfig(),
                                    mask, vals)
    clamped = np.asarray(clamped)
    assert np.array_equal(clamped[:, :2], base[:, :2])
    # rows where the free walk already drew 1 at site 2 are untouched
    hit = base[:, 2] == 1
    assert hit.any()
    assert np.array_equal(clamped[hit], base[hit])
    assert np.all(np.asarray(lp) < 0)


def test_unmasked_clamped_chain_is_the_sampler(linear_mps_small):
    mps, n = linear_mps_small, 32
    key = jax.random.key(9)
    mask = np.zeros(mps.n_sites, dtype=bool)
    vals = np.zeros((mps.n_sites, n), dtype=np.int32)
    out, lp = CL.sample_clamped(mps, n, key, S.SamplerConfig(), mask, vals)
    assert np.array_equal(np.asarray(out), np.asarray(S.sample(mps, n, key)))
    assert np.all(np.asarray(lp) == 0.0)


def test_per_sample_clamp_arrays(linear_mps_small):
    mps, n = linear_mps_small, 6
    forced = np.array([0, 1, 2, 0, 1, 2], dtype=np.int32)
    clamp = WC.normalize_clamp({3: forced})
    WC.validate_clamp(clamp, n_sites=mps.n_sites, d=mps.phys_dim,
                      n_samples=n)
    mask, vals = WC.segment_clamp_arrays(WC.clamp_map(clamp), 0,
                                         mps.n_sites, n)
    out, lp = CL.sample_clamped(mps, n, jax.random.key(1), S.SamplerConfig(),
                                mask, vals)
    assert np.array_equal(np.asarray(out)[:, 3], forced)
    assert np.all(np.isfinite(np.asarray(lp)))


# ---------------------------------------------------------------------------
# session level: {inmem, streamed} × {seq, dp} agreement
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chain(tmp_path_factory, linear_mps_10x6):
    root = str(tmp_path_factory.mktemp("workloads_gamma"))
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(linear_mps_10x6)
        store.write_digest_manifest()
    return root, linear_mps_10x6


def _session_sample(source, cfg_kwargs, n, key, mesh=None):
    with api.SamplingSession(source, api.SamplerConfig(**cfg_kwargs),
                             mesh=mesh) as sess:
        out = sess.sample(n, key)
        return np.asarray(out), dict(sess.stats)


@pytest.mark.parametrize("scheme", ["seq", "dp"])
def test_empty_clamp_is_the_unclamped_config(chain, scheme):
    root, mps = chain
    n, key = 24, jax.random.key(3)
    mesh = jax.make_mesh((1,), ("data",)) if scheme == "dp" else None
    for source in (mps, root):
        base, _ = _session_sample(source, {"scheme": scheme}, n, key, mesh)
        empty, st = _session_sample(source, {"scheme": scheme, "clamp": {}},
                                    n, key, mesh)
        assert np.array_equal(base, empty)
        assert "log_prob" not in st        # the unclamped path really ran


@pytest.mark.parametrize("scheme", ["seq", "dp"])
def test_clamped_streamed_matches_clamped_inmem(chain, scheme):
    root, mps = chain
    n, key, clamp = 24, jax.random.key(3), {2: 1, 7: 0}
    mesh = jax.make_mesh((1,), ("data",)) if scheme == "dp" else None
    inmem, st_i = _session_sample(mps, {"scheme": scheme, "clamp": clamp},
                                  n, key, mesh)
    # open the store at full precision: a bare root string resolves to the
    # float32 compute default, which would quantize the weights
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        streamed, st_s = _session_sample(
            store, {"scheme": scheme, "clamp": clamp, "segment_len": 3},
            n, key, mesh)
    assert np.array_equal(inmem, streamed)
    np.testing.assert_array_equal(st_i["log_prob"], st_s["log_prob"])
    assert np.all(inmem[:, 2] == 1) and np.all(inmem[:, 7] == 0)
    assert st_i["log_prob"].shape == (n,)


def test_clamp_refuses_checkpoint_resume(chain, tmp_path):
    root, _ = chain
    cfg = api.SamplerConfig(clamp={2: 1}, segment_len=3,
                            checkpoint_dir=str(tmp_path / "ck"))
    with api.SamplingSession(root, cfg) as sess:
        with pytest.raises(ValueError, match="clamped walks do not"):
            sess.sample(8, jax.random.key(0))


def test_clamp_out_of_range_rejected_at_plan(linear_mps_small):
    with api.SamplingSession(linear_mps_small,
                             api.SamplerConfig(clamp={99: 0})) as sess:
        with pytest.raises(ValueError, match="site"):
            sess.sample(8, jax.random.key(0))


# ---------------------------------------------------------------------------
# remote payload round trip
# ---------------------------------------------------------------------------

def test_clamp_survives_remote_config_round_trip():
    import json

    from repro.api.remote import config_from_dict, config_to_dict
    cfg = api.SamplerConfig(clamp={4: (0, 1, 0), 2: 1})
    wire = json.loads(json.dumps(config_to_dict(cfg)))
    back = config_from_dict(wire)
    assert back.clamp == cfg.clamp == ((2, 1), (4, (0, 1, 0)))


def test_malformed_clamp_rejected_at_config():
    with pytest.raises(ValueError):
        api.SamplerConfig(clamp={"abc": 0})
    with pytest.raises(ValueError):
        api.SamplerConfig(clamp=[[2, 1], [2, 0]])


# ---------------------------------------------------------------------------
# BYO-MPS ingest
# ---------------------------------------------------------------------------

def _ragged_born(seed=0, dims=(1, 2, 3, 2, 1), d=2):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=(dims[i], dims[i + 1], d))
            + 1j * rng.normal(size=(dims[i], dims[i + 1], d))
            for i in range(len(dims) - 1)]


def _statevec(tensors, d):
    M_ = len(tensors)
    out = np.zeros((d,) * M_, dtype=complex)
    for s in itertools.product(range(d), repeat=M_):
        m = np.eye(1)
        for i, si in enumerate(s):
            m = m @ tensors[i][:, :, si]
        out[s] = m[0, 0]
    return out.reshape(-1)


def test_ingest_canonicalization_preserves_the_state():
    tensors = _ragged_born()
    mps, report = IG.build_mps(tensors, semantics="born")
    assert report.canonicalized and report.max_isometry_error < 1e-12
    psi = _statevec(tensors, 2)
    p_true = np.abs(psi) ** 2
    p_true /= p_true.sum()
    np.testing.assert_allclose(M.enumerate_probabilities(mps), p_true,
                               atol=1e-10)


def test_ingest_rejects_noncanonical_without_canonicalize():
    with pytest.raises(IG.IngestError, match="canonicalize=True"):
        IG.build_mps(_ragged_born(), semantics="born", canonicalize=False)


@pytest.mark.parametrize("mutate,msg", [
    (lambda t: t[:-1], "boundary"),                       # right bond != 1
    (lambda t: t[:1] + [t[1][:, :, :1]] + t[2:], "physical dimension"),
    (lambda t: t[:1] + [np.zeros((3, 2, 2))] + t[2:], "bond mismatch"),
    (lambda t: [], "empty"),
])
def test_ingest_structural_rejection(mutate, msg):
    with pytest.raises(IG.IngestError, match=msg):
        IG.build_mps(mutate(_ragged_born()), semantics="born")


def test_ingest_linear_rejects_negativity():
    rng = np.random.default_rng(1)
    tensors = [np.abs(rng.normal(size=s))
               for s in [(1, 2, 3), (2, 2, 3), (2, 1, 3)]]
    IG.build_mps(tensors, semantics="linear")              # clean passes
    tensors[1][0, 0, 0] = -0.5
    with pytest.raises(IG.IngestError, match="non-negative"):
        IG.build_mps(tensors, semantics="linear")


def test_ingest_npz_and_store_round_trip(tmp_path):
    tensors = _ragged_born(seed=3)
    npz = tmp_path / "external_mps.npz"
    np.savez(npz, *tensors)
    store, report = IG.ingest_mps(
        str(npz), str(tmp_path / "store"), semantics="born",
        storage_dtype=jnp.complex128, compute_dtype=jnp.complex128)
    with store:
        assert store.n_sites == report.n_sites == len(tensors)
        assert report.digest == store.digest()             # manifest written
        mps, _ = IG.build_mps(tensors, semantics="born")
        for i in range(store.n_sites):
            g, lam = store.get(i, prefetch_next=False)
            np.testing.assert_array_equal(g, np.asarray(mps.gammas[i]))
            np.testing.assert_array_equal(lam, np.asarray(mps.lambdas[i]))
        # the ingested store is sample-ready through the public session
        with api.SamplingSession(store, api.SamplerConfig(
                semantics="born")) as sess:
            out = sess.sample(16, jax.random.key(0))
        assert out.shape == (16, len(tensors))


# ---------------------------------------------------------------------------
# scenario registry
# ---------------------------------------------------------------------------

def test_scenario_registry_catalogue():
    names = SC.available_scenarios()
    for expected in ("gbs", "conditional_marginals",
                     "mnist_classify_generate"):
        assert expected in names and names[expected]
    with pytest.raises(KeyError, match="unknown scenario"):
        SC.run_scenario("no_such_scenario")


def test_conditional_marginals_scenario_passes():
    result = SC.run_scenario("conditional_marginals",
                             SC.ScenarioConfig(n_samples=2000, json_path=""))
    assert result.passed, result
    assert result.score < result.threshold
    assert result.metrics["branch_err"] < 5e-3


def test_scenario_record_schema(tmp_path):
    import json
    path = str(tmp_path / "traj.json")
    result = SC.run_scenario("mnist_classify_generate",
                             SC.ScenarioConfig(n_samples=400,
                                               json_path=path))
    assert result.passed
    with open(path) as f:
        rows = json.load(f)
    assert rows[-1]["bench"] == "scenario"
    assert rows[-1]["config"]["scenario"] == "mnist_classify_generate"
    assert {"passed", "score", "threshold", "wall_s", "utc"} <= set(rows[-1])
