"""Unified `SamplingSession` API: one front door, bit-identical everywhere.

The facade's contract (paper §4.1 composed over every level): for one seed,
every supported cell of {inmem, streamed, remote} × {local, multihost,
remote runtime} × {seq, dp, tp_single, tp_double} × {static, dynamic-χ} ×
{whole-batch, micro-batched} emits bit-identical samples, and a killed
streamed run resumes exactly.  Single-device cells run in-process; the
DP/TP matrix runs in a subprocess with 8 forced host devices (the main
pytest process must keep the real device view); the multi-process runtime
cells emulate a 2-process cluster (`api.emulated_cluster`) with one driver
thread per "process", slow-marked alongside the subprocess remote dispatch.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.core import dynamic_bond as DB
from repro.core import mps as M
from repro.core import sampler as S
from repro.data.gamma_store import GammaStore


# ---------------------------------------------------------------------------
# Single-device cells (seq scheme): facade vs the legacy references
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chain(tmp_path_factory, linear_mps_10x6):
    root = str(tmp_path_factory.mktemp("api_gamma"))
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(linear_mps_10x6)
    return root, linear_mps_10x6


def test_inmem_seq_matches_legacy_sampler(linear_mps_10x6):
    mps = linear_mps_10x6
    key = jax.random.key(3)
    with api.SamplingSession(mps) as sess:
        out = sess.sample(24, key)
    assert np.array_equal(out, np.asarray(S.sample(mps, 24, key)))


def test_streamed_seq_matches_legacy_sampler(chain):
    root, mps = chain
    key = jax.random.key(3)
    cfg = api.SamplerConfig(segment_len=4)
    with api.SamplingSession(root, cfg) as sess:
        assert sess.plan(24).backend == "streamed"   # auto from the store
        out = sess.sample(24, key)
        assert sess.stats["max_live_segments"] <= 2
    assert np.array_equal(out, np.asarray(S.sample(mps, 24, key)))


def test_session_from_mps_materializes_identity_store(linear_mps_10x6):
    """backend="streamed" over an MPS source: the session writes a store in
    the MPS's own dtype, so no storage rounding breaks bit-identity."""
    mps = linear_mps_10x6
    key = jax.random.key(5)
    cfg = api.SamplerConfig(backend="streamed", segment_len=5)
    with api.SamplingSession(mps, cfg) as sess:
        out = sess.sample(16, key)
    assert np.array_equal(out, np.asarray(S.sample(mps, 16, key)))


def test_micro_batch_both_backends(chain):
    root, mps = chain
    key = jax.random.key(9)
    ref = np.asarray(S.sample_batched(mps, 24, key, micro_batch=8))
    with api.SamplingSession(mps, api.SamplerConfig(micro_batch=8)) as sess:
        assert np.array_equal(sess.sample(24, key), ref)
    cfg = api.SamplerConfig(micro_batch=8, segment_len=4)
    with api.SamplingSession(root, cfg) as sess:
        assert np.array_equal(sess.sample(24, key), ref)


def test_dynamic_chi_both_backends(chain):
    root, mps = chain
    key = jax.random.key(11)
    prof = DB.bucketize(DB.area_law_profile(10, 6), [4, 6])
    ref = np.asarray(DB.sample_staged(mps, prof, 24, key))
    cfg = api.SamplerConfig(chi_profile=tuple(int(c) for c in prof))
    with api.SamplingSession(mps, cfg) as sess:
        plan = sess.plan(24)
        assert plan.stages is not None and len(plan.stages) >= 2
        assert np.array_equal(sess.sample(24, key), ref)
    cfg = api.SamplerConfig(chi_profile=tuple(int(c) for c in prof),
                            segment_len=3)
    with api.SamplingSession(root, cfg) as sess:
        assert np.array_equal(sess.sample(24, key), ref)


def test_streamed_kill_and_resume(chain, tmp_path):
    root, mps = chain
    key = jax.random.key(13)
    ref = np.asarray(S.sample(mps, 16, key))
    cfg = api.SamplerConfig(segment_len=4, checkpoint_every=1,
                            checkpoint_dir=str(tmp_path))
    with api.SamplingSession(root, cfg) as sess:
        part = sess.sample(16, key, stop_after_segments=2)
        assert part.shape == (16, 8)
        assert np.array_equal(part, ref[:, :8])
        out = sess.sample(16, key, resume=True)
        assert sess.stats["segments"] == 1           # only the remaining work
    assert np.array_equal(out, ref)


def test_run_queue_macro_batches(chain):
    """Macro batches through the facade: batch = f(seed, id), results
    owner/order-independent (runtime/elastic.py contract)."""
    from repro.runtime.elastic import WorkQueue
    root, mps = chain
    base = jax.random.key(21)
    with api.SamplingSession(root, api.SamplerConfig(segment_len=5)) as sess:
        q = WorkQueue(3)
        outs = sess.run_queue(q, 8, base)
        assert q.finished
    for b in range(3):
        ref = np.asarray(S.sample(mps, 8, jax.random.fold_in(base, b)))
        assert np.array_equal(outs[b], ref)


def test_born_semantics_both_backends(tmp_path, born_mps_6x4):
    mps = born_mps_6x4
    key = jax.random.key(2)
    ref = np.asarray(S.sample(mps, 16, key,
                              S.SamplerConfig(semantics="born")))
    with api.SamplingSession(mps) as sess:
        assert sess.plan(16).semantics == "born"     # auto from the MPS
        assert np.array_equal(sess.sample(16, key), ref)
    with GammaStore(str(tmp_path), storage_dtype=jnp.complex128,
                    compute_dtype=jnp.complex128) as store:
        store.write_mps(mps)
        cfg = api.SamplerConfig(semantics="born", segment_len=4)
        with api.SamplingSession(store, cfg) as sess:
            assert np.array_equal(sess.sample(16, key), ref)


# ---------------------------------------------------------------------------
# Planning, registry, lifecycle, deprecation
# ---------------------------------------------------------------------------

def test_plan_and_explain(chain):
    root, _ = chain
    with api.SamplingSession(root) as sess:
        plan = sess.plan(24)
        assert plan.backend == "streamed" and plan.scheme == "seq"
        assert plan.segment_len and plan.segment_len >= 1
        info = sess.explain(24)
        assert info["backend"] == "streamed"
        assert info["chi_buckets"] == [6]
        assert "io_overlapped" in info and "segment_len" in info


def test_backend_registry():
    assert set(api.available_backends()) >= {"inmem", "streamed"}
    assert api.get_backend("inmem").name == "inmem"
    with pytest.raises(ValueError, match="no backend"):
        api.get_backend("nope")

    @api.register_backend("_test_backend")
    class _TB(api.Backend):
        name = "_test_backend"

        def sample(self, req):
            return np.zeros((req.n_samples, 1), np.int32)

    try:
        assert "_test_backend" in api.available_backends()
    finally:
        from repro.api import backends as B
        B._REGISTRY.pop("_test_backend", None)


def test_resolution_errors(linear_mps_10x6):
    mps = linear_mps_10x6
    with api.SamplingSession(mps, api.SamplerConfig(scheme="dp")) as sess:
        with pytest.raises(ValueError, match="needs a mesh"):
            sess.plan(8)
    with api.SamplingSession(mps, api.SamplerConfig(micro_batch=7)) as sess:
        with pytest.raises(ValueError, match="micro_batch"):
            sess.plan(24)
    bad_prof = (6,) * 9                              # covers 9 of 10 sites
    with api.SamplingSession(
            mps, api.SamplerConfig(chi_profile=bad_prof)) as sess:
        with pytest.raises(ValueError, match="chi_profile"):
            sess.plan(8)
    with api.SamplingSession(mps) as sess:
        with pytest.raises(ValueError, match="resume"):
            sess.sample(8, jax.random.key(0), resume=True)


def test_micro_batch_plus_dynamic_chi_inmem_seq(chain):
    """PR 2's last routing gap is closed: micro batching and dynamic χ
    compose directly on the in-memory seq path (no silent reroute to the
    streamed backend), bit-identical to the streamed cell and to the
    sample_batched key schedule."""
    root, mps = chain
    key = jax.random.key(15)
    prof = DB.bucketize(DB.area_law_profile(10, 6), [4, 6])
    cfgi = api.SamplerConfig(chi_profile=tuple(int(c) for c in prof),
                             micro_batch=8)
    with api.SamplingSession(mps, cfgi) as sess:
        plan = sess.plan(24)
        assert plan.backend == "inmem" and plan.scheme == "seq"
        assert plan.micro_batch == 8 and plan.stages is not None
        out = sess.sample(24, key)
    assert np.array_equal(
        out, np.asarray(DB.sample_staged_batched(mps, prof, 24, key, 8)))
    cfgs = api.SamplerConfig(chi_profile=tuple(int(c) for c in prof),
                             micro_batch=8, segment_len=3)
    with api.SamplingSession(root, cfgs) as sess:
        assert np.array_equal(sess.sample(24, key), out)
    # AUTO micro now resolves to a real chunk size on this path too
    cfga = api.SamplerConfig(micro_batch=api.AUTO,
                             chi_profile=tuple(int(c) for c in prof),
                             device_budget=2e4)
    with api.SamplingSession(mps, cfga) as sess:
        assert sess.plan(24).micro_batch is not None


def test_gamma_store_context_manager(tmp_path, linear_mps_10x6):
    with GammaStore(str(tmp_path), storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(linear_mps_10x6)
        assert store.n_sites == 10
    assert not store._thread.is_alive()              # prefetch thread joined


def test_legacy_entry_points_removed():
    """The ROADMAP scheduled the deprecation-shimmed entry points for
    removal one release after the PR 2 facade — they are gone; the
    session is the only front door (internal segment-runner callables
    remain, underscore-prefixed)."""
    import repro.engine as engine
    from repro.core import parallel as PP
    for name in ("multilevel_sample", "dp_sample", "baseline19_sample"):
        assert not hasattr(PP, name), name
    assert not hasattr(engine, "stream_sample")
    assert not hasattr(engine.streaming, "stream_sample")
    # the internal data plane the backends route through is still there
    assert callable(PP._multilevel_sample) and callable(PP.sample_segment)


def test_parallel_log_scale_parity(linear_mps_10x6):
    """Satellite: the DP segment runner carries the same per-sample
    log_scale diagnostic as the in-memory chain scan."""
    from repro.core import parallel as PP
    mps = linear_mps_10x6
    key = jax.random.key(4)
    # dp hands shard i the key split(key, p1)[i]; p1 = 1 here
    state = S.init_state(mps, 8, jax.random.split(key, 1)[0])
    res = S.sample_chain(mps, state, S.SamplerConfig())
    mesh = jax.make_mesh((1,), ("data",))
    env = PP.segment_env_init(8, mps.chi, mps.gammas.dtype)
    _, _, ls = PP.sample_segment(mesh, mps, env, key, 0,
                                 PP.ParallelConfig("dp"), S.SamplerConfig())
    np.testing.assert_allclose(np.asarray(ls),
                               np.asarray(res.state.log_scale), rtol=1e-12)


# ---------------------------------------------------------------------------
# The full DP/TP matrix (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

_CHILD = textwrap.dedent("""
    import json, os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.core import dynamic_bond as DB, mps as M, parallel as PP
    from repro.core import sampler as S
    from repro.data.gamma_store import GammaStore
    from repro.launch.mesh import make_host_mesh

    m = M.random_linear_mps(jax.random.key(0), 8, 8, 3)
    mesh = make_host_mesh(model=4)             # 2 data x 4 model
    key = jax.random.key(7)

    # the internal segment-runner data plane is the static reference
    ref = np.asarray(PP._multilevel_sample(mesh, m, 64, key,
                                           PP.ParallelConfig("dp")))

    root = tempfile.mkdtemp()
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as st:
        st.write_mps(m)

    # dynamic-χ reference: per-shard staged chains (even-aligned stages so
    # tp_double's site pairs never straddle a χ transition)
    prof = np.array([4, 4, 8, 8, 8, 8, 4, 4])
    sk = jax.random.split(key, 2)
    ref_dyn = np.concatenate([np.asarray(DB.sample_staged(m, prof, 32, sk[i]))
                              for i in range(2)], 0)
    ref_mb = np.concatenate([np.asarray(S.sample_batched(m, 32, sk[i], 8))
                             for i in range(2)], 0)

    out = {}
    for backend, src in (("inmem", m), ("streamed", root)):
        for scheme in ("dp", "tp_single", "tp_double"):
            cfg = api.SamplerConfig(backend=backend, scheme=scheme,
                                    segment_len=2)
            with api.SamplingSession(src, cfg, mesh=mesh) as sess:
                out[f"{backend}_{scheme}_static"] = bool(
                    np.array_equal(sess.sample(64, key), ref))
            cfgd = api.SamplerConfig(backend=backend, scheme=scheme,
                                     segment_len=2,
                                     chi_profile=tuple(int(c) for c in prof))
            with api.SamplingSession(src, cfgd, mesh=mesh) as sess:
                out[f"{backend}_{scheme}_dynamic"] = bool(
                    np.array_equal(sess.sample(64, key), ref_dyn))
        # micro batching N2 under a parallel scheme (per data shard)
        cfgm = api.SamplerConfig(backend=backend, scheme="tp_single",
                                 segment_len=4, micro_batch=8)
        with api.SamplingSession(src, cfgm, mesh=mesh) as sess:
            out[f"{backend}_tp_single_micro"] = bool(
                np.array_equal(sess.sample(64, key), ref_mb))

    # log_scale diagnostic parity: the TP segment runners accumulate the
    # same per-sample rescale log as the DP path (satellite)
    envd = PP.segment_env_init(64, 8, m.gammas.dtype)
    _, _, lsd = PP.sample_segment(mesh, m, envd, key, 0,
                                  PP.ParallelConfig("dp"), S.SamplerConfig())
    _, _, ls1 = PP.sample_segment(mesh, m, envd, key, 0,
                                  PP.ParallelConfig("tp_single"),
                                  S.SamplerConfig())
    _, _, ls2 = PP.sample_segment(mesh, m, envd, key, 0,
                                  PP.ParallelConfig("tp_double"),
                                  S.SamplerConfig())
    out["log_scale_tp_parity"] = bool(
        np.allclose(lsd, ls1, rtol=1e-12)
        and np.allclose(lsd, ls2, rtol=1e-12))

    # multi-pod mesh: "pod" folds into data parallel — the resolved
    # ParallelConfig.data_axes must cover every non-model axis
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    with api.SamplingSession(m, api.SamplerConfig(scheme="dp"),
                             mesh=mesh3) as sess:
        assert sess.plan(64).p1 == 4
        out3 = sess.sample(64, key)
    sk4 = jax.random.split(key, 4)
    ref3 = np.concatenate([np.asarray(S.sample(m, 16, sk4[i]))
                           for i in range(4)], 0)
    out["multipod_dp"] = bool(np.array_equal(out3, ref3))

    # plan-time validation: fixed-χ TP divisibility surfaces pre-compile
    m_bad = M.random_linear_mps(jax.random.key(1), 6, 6, 3)
    try:
        with api.SamplingSession(m_bad, api.SamplerConfig(scheme="tp_single"),
                                 mesh=mesh) as sess:
            sess.plan(64)
        out["tp_chi_plan_error"] = False
    except ValueError:
        out["tp_chi_plan_error"] = True

    # kill-and-resume through the facade: streamed dp, dynamic chi
    ck = tempfile.mkdtemp()
    cfg = api.SamplerConfig(backend="streamed", scheme="dp", segment_len=2,
                            chi_profile=tuple(int(c) for c in prof),
                            checkpoint_dir=ck, checkpoint_every=1)
    with api.SamplingSession(root, cfg, mesh=mesh) as sess:
        sess.sample(64, key, stop_after_segments=2)
    with api.SamplingSession(root, cfg, mesh=mesh) as sess:
        out["resume_dynamic_dp"] = bool(
            np.array_equal(sess.sample(64, key, resume=True), ref_dyn))
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def matrix_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("cell", [
    f"{b}_{s}_{m}"
    for b in ("inmem", "streamed")
    for s in ("dp", "tp_single", "tp_double")
    for m in ("static", "dynamic")
] + ["inmem_tp_single_micro", "streamed_tp_single_micro",
     "resume_dynamic_dp", "log_scale_tp_parity",
     "multipod_dp", "tp_chi_plan_error"])
def test_cross_backend_matrix(matrix_results, cell):
    """One seed ⇒ bit-identical samples in every supported cell of
    {inmem, streamed} × {dp, tp_single, tp_double} × {static, dynamic-χ},
    micro-batched DP/TP, and a kill-and-resume — all through the facade."""
    assert matrix_results[cell]


# ---------------------------------------------------------------------------
# Cluster runtime × data plane (ClusterRuntime layer)
# ---------------------------------------------------------------------------

def _run_emulated_cluster(runtimes, make_config, source, n, key, mesh=None):
    """Drive one session per runtime instance concurrently (each 'process'
    on its own thread, the way a real multi-process launch runs one driver
    per host); returns ({process: samples}, {process: stats})."""
    import threading

    outs, stats, errs = {}, {}, []

    def run(rt):
        try:
            with api.SamplingSession(source, make_config(rt),
                                     mesh=mesh) as sess:
                outs[rt.process_index] = sess.sample(n, key)
                stats[rt.process_index] = dict(sess.stats)
        except Exception as e:          # pragma: no cover - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=run, args=(rt,)) for rt in runtimes]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert not errs, errs
    return outs, stats


def test_multihost_streamed_bitidentical_to_local(chain):
    """Acceptance cell: runtime='multihost' (fake 2-process cluster) ×
    backend='streamed' emits bit-identical samples to runtime='local' for
    the same seed, with the GammaStore read-counters showing exactly one
    process reading each segment."""
    root, mps = chain
    key = jax.random.key(23)
    with api.SamplingSession(
            root, api.SamplerConfig(segment_len=4)) as sess:
        ref = sess.sample(16, key)
        local_bytes = sess.stats["io_bytes"]
    assert np.array_equal(ref, np.asarray(S.sample(mps, 16, key)))

    runtimes = api.emulated_cluster(2)
    outs, stats = _run_emulated_cluster(
        runtimes,
        lambda rt: api.SamplerConfig(runtime=rt, backend="streamed",
                                     segment_len=4),
        root, 16, key)
    assert np.array_equal(outs[0], ref)
    assert np.array_equal(outs[1], ref)
    # one reader: the root's per-engine store-I/O delta covers the chain
    # exactly once; the peer never touches the store payload
    assert stats[0]["io_bytes"] == local_bytes
    assert stats[1]["io_bytes"] == 0
    assert stats[0]["broadcast_send_bytes"] == local_bytes
    assert stats[1]["broadcast_recv_bytes"] == local_bytes


def test_remote_backend_loopback_dispatch(chain):
    """backend='remote' on the local runtime: the request crosses the
    serialization boundary (config → JSON payload → worker session) and
    comes back bit-identical — the dispatch path, minus the subprocess."""
    root, mps = chain
    key = jax.random.key(29)
    ref = np.asarray(S.sample(mps, 16, key))
    cfg = api.SamplerConfig(backend="remote", segment_len=4)
    with api.SamplingSession(root, cfg) as sess:
        plan = sess.plan(16)
        assert plan.backend == "remote" and plan.runtime == "local"
        out = sess.sample(16, key)
        assert sess.stats["runtime_dispatch_bytes"] > 0
    assert np.array_equal(out, ref)


@pytest.mark.slow
def test_remote_runtime_subprocess_dispatch(chain):
    """runtime='remote': the serialized SamplerConfig is dispatched to a
    fresh worker interpreter (python -m repro.api.remote) — full process
    isolation, bit-identical samples back."""
    root, mps = chain
    key = jax.random.key(31)
    ref = np.asarray(S.sample(mps, 16, key))
    cfg = api.SamplerConfig(runtime="remote", segment_len=4)
    with api.SamplingSession(root, cfg) as sess:
        plan = sess.plan(16)
        assert plan.backend == "remote" and plan.runtime == "remote"
        out = sess.sample(16, key)
        counters = sess.runtime.io_counters()
        assert counters["dispatches"] == 1 and counters["dispatch_bytes"] > 0
    assert np.array_equal(out, ref)


def test_wire_payload_roundtrip_is_lossless(chain):
    """The jax.distributed broadcast frames the segment payload as
    (length, uint8 npz blob) — the round-trip must reproduce the raw
    storage bytes exactly (any loss here would break the §4.1 bit-identity
    of a real multi-host run)."""
    from repro.api.runtime import payload_from_bytes, payload_to_bytes
    from repro.data.gamma_store import decode_segment

    root, mps = chain
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        payload = store.get_segment_raw(2, 5)
        back = payload_from_bytes(payload_to_bytes(payload))
        assert back["start"] == payload["start"]
        np.testing.assert_array_equal(back["gamma"], payload["gamma"])
        np.testing.assert_array_equal(back["lam"], payload["lam"])
        g0, l0 = decode_segment(payload)
        g1, l1 = decode_segment(back)
        np.testing.assert_array_equal(g0, g1)
        np.testing.assert_array_equal(l0, l1)
    # bf16 storage survives the uint16 view framing too
    with GammaStore(str(root) + "_bf16") as bstore:
        bstore.write_mps(mps)
        payload = bstore.get_segment_raw(0, 3)
        back = payload_from_bytes(payload_to_bytes(payload))
        assert np.dtype(back["storage_dtype"]) == np.dtype(jnp.bfloat16)
        g0, l0 = decode_segment(payload)
        g1, l1 = decode_segment(back)
        np.testing.assert_array_equal(g0, g1)
        np.testing.assert_array_equal(l0, l1)


def test_runtime_registry_and_cell_validation(chain, linear_mps_10x6):
    root, _ = chain
    assert set(api.available_runtimes()) >= {"local", "multihost", "remote"}
    assert api.resolve_runtime(api.AUTO).name == "local"
    assert api.resolve_runtime("local").process_count == 1
    with pytest.raises(ValueError, match="no runtime"):
        api.resolve_runtime("nope")
    # multihost needs the streamed data plane (the broadcast is a segment
    # concern) — surfaced at plan time, before any compilation
    rt = api.emulated_cluster(2)[0]
    cfg = api.SamplerConfig(runtime=rt, backend="inmem")
    with api.SamplingSession(linear_mps_10x6, cfg) as sess:
        with pytest.raises(ValueError, match="streamed"):
            sess.plan(8)
    # a remote runtime only dispatches — local data planes are rejected
    cfg = api.SamplerConfig(runtime="remote", backend="streamed")
    with api.SamplingSession(root, cfg) as sess:
        with pytest.raises(ValueError, match="remote"):
            sess.plan(8)
    # remote resolves placement on the worker: no local mesh / dp scheme
    cfg = api.SamplerConfig(backend="remote", scheme="dp")
    with api.SamplingSession(root, cfg) as sess:
        with pytest.raises(ValueError, match="worker"):
            sess.plan(8)
    # checkpointing does not ship across the dispatch boundary — rejected
    # at plan time, not silently dropped
    cfg = api.SamplerConfig(backend="remote", checkpoint_dir="/tmp/nope")
    with api.SamplingSession(root, cfg) as sess:
        with pytest.raises(ValueError, match="checkpoint"):
            sess.plan(8)
    # single-process 'multihost' by name points at emulated_cluster
    with pytest.raises(ValueError, match="emulated_cluster"):
        api.resolve_runtime("multihost")


_RUNTIME_CHILD = textwrap.dedent("""
    import json, os, tempfile, threading
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.core import mps as M
    from repro.data.gamma_store import GammaStore
    from repro.launch.mesh import make_host_mesh

    m = M.random_linear_mps(jax.random.key(0), 8, 8, 3)
    key = jax.random.key(7)
    root = tempfile.mkdtemp()
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as st:
        st.write_mps(m)

    out = {}
    for scheme, model in (("dp", 1), ("tp_single", 4)):
        mesh = make_host_mesh(model=model)
        cfg = api.SamplerConfig(backend="streamed", scheme=scheme,
                                segment_len=2)
        with api.SamplingSession(root, cfg, mesh=mesh) as sess:
            ref = sess.sample(64, key)
            local_bytes = sess.stats["io_bytes"]

        runtimes = api.emulated_cluster(2, timeout=300.0)
        res, stats, errs = {}, {}, []

        def run(rt):
            try:
                c = api.SamplerConfig(runtime=rt, backend="streamed",
                                      scheme=scheme, segment_len=2)
                with api.SamplingSession(root, c, mesh=mesh) as sess:
                    res[rt.process_index] = sess.sample(64, key)
                    stats[rt.process_index] = dict(sess.stats)
            except Exception as e:
                errs.append(repr(e))

        ts = [threading.Thread(target=run, args=(rt,)) for rt in runtimes]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=500)
        out[scheme + "_errs"] = errs
        out[scheme + "_root"] = bool(np.array_equal(res.get(0), ref))
        out[scheme + "_peer"] = bool(np.array_equal(res.get(1), ref))
        out[scheme + "_one_reader"] = bool(
            stats[0]["io_bytes"] == local_bytes
            and stats[1]["io_bytes"] == 0
            and stats[1]["broadcast_recv_bytes"] == local_bytes)
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def runtime_matrix_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _RUNTIME_CHILD], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("cell", [
    f"{s}_{w}" for s in ("dp", "tp_single")
    for w in ("root", "peer", "one_reader")])
def test_runtime_matrix_multihost_dp_tp(runtime_matrix_results, cell):
    """The {local, multihost} × streamed × {dp, tp_single} matrix on 8
    forced host devices with a fake 2-process runtime: every process emits
    the local run's exact samples and only the root reads the store."""
    scheme = cell.rsplit("_", 1)[0] if not cell.endswith("one_reader") \
        else cell[: -len("_one_reader")]
    assert runtime_matrix_results[scheme + "_errs"] == []
    assert runtime_matrix_results[cell]
