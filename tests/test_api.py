"""Unified `SamplingSession` API: one front door, bit-identical everywhere.

The facade's contract (paper §4.1 composed over every level): for one seed,
every supported cell of {inmem, streamed} × {seq, dp, tp_single, tp_double}
× {static, dynamic-χ} × {whole-batch, micro-batched} emits bit-identical
samples, and a killed streamed run resumes exactly.  Single-device cells
run in-process; the DP/TP matrix runs in a subprocess with 8 forced host
devices (the main pytest process must keep the real device view).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.core import dynamic_bond as DB
from repro.core import mps as M
from repro.core import sampler as S
from repro.data.gamma_store import GammaStore


# ---------------------------------------------------------------------------
# Single-device cells (seq scheme): facade vs the legacy references
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chain(tmp_path_factory, linear_mps_10x6):
    root = str(tmp_path_factory.mktemp("api_gamma"))
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(linear_mps_10x6)
    return root, linear_mps_10x6


def test_inmem_seq_matches_legacy_sampler(linear_mps_10x6):
    mps = linear_mps_10x6
    key = jax.random.key(3)
    with api.SamplingSession(mps) as sess:
        out = sess.sample(24, key)
    assert np.array_equal(out, np.asarray(S.sample(mps, 24, key)))


def test_streamed_seq_matches_legacy_sampler(chain):
    root, mps = chain
    key = jax.random.key(3)
    cfg = api.SamplerConfig(segment_len=4)
    with api.SamplingSession(root, cfg) as sess:
        assert sess.plan(24).backend == "streamed"   # auto from the store
        out = sess.sample(24, key)
        assert sess.stats["max_live_segments"] <= 2
    assert np.array_equal(out, np.asarray(S.sample(mps, 24, key)))


def test_session_from_mps_materializes_identity_store(linear_mps_10x6):
    """backend="streamed" over an MPS source: the session writes a store in
    the MPS's own dtype, so no storage rounding breaks bit-identity."""
    mps = linear_mps_10x6
    key = jax.random.key(5)
    cfg = api.SamplerConfig(backend="streamed", segment_len=5)
    with api.SamplingSession(mps, cfg) as sess:
        out = sess.sample(16, key)
    assert np.array_equal(out, np.asarray(S.sample(mps, 16, key)))


def test_micro_batch_both_backends(chain):
    root, mps = chain
    key = jax.random.key(9)
    ref = np.asarray(S.sample_batched(mps, 24, key, micro_batch=8))
    with api.SamplingSession(mps, api.SamplerConfig(micro_batch=8)) as sess:
        assert np.array_equal(sess.sample(24, key), ref)
    cfg = api.SamplerConfig(micro_batch=8, segment_len=4)
    with api.SamplingSession(root, cfg) as sess:
        assert np.array_equal(sess.sample(24, key), ref)


def test_dynamic_chi_both_backends(chain):
    root, mps = chain
    key = jax.random.key(11)
    prof = DB.bucketize(DB.area_law_profile(10, 6), [4, 6])
    ref = np.asarray(DB.sample_staged(mps, prof, 24, key))
    cfg = api.SamplerConfig(chi_profile=tuple(int(c) for c in prof))
    with api.SamplingSession(mps, cfg) as sess:
        plan = sess.plan(24)
        assert plan.stages is not None and len(plan.stages) >= 2
        assert np.array_equal(sess.sample(24, key), ref)
    cfg = api.SamplerConfig(chi_profile=tuple(int(c) for c in prof),
                            segment_len=3)
    with api.SamplingSession(root, cfg) as sess:
        assert np.array_equal(sess.sample(24, key), ref)


def test_streamed_kill_and_resume(chain, tmp_path):
    root, mps = chain
    key = jax.random.key(13)
    ref = np.asarray(S.sample(mps, 16, key))
    cfg = api.SamplerConfig(segment_len=4, checkpoint_every=1,
                            checkpoint_dir=str(tmp_path))
    with api.SamplingSession(root, cfg) as sess:
        part = sess.sample(16, key, stop_after_segments=2)
        assert part.shape == (16, 8)
        assert np.array_equal(part, ref[:, :8])
        out = sess.sample(16, key, resume=True)
        assert sess.stats["segments"] == 1           # only the remaining work
    assert np.array_equal(out, ref)


def test_run_queue_macro_batches(chain):
    """Macro batches through the facade: batch = f(seed, id), results
    owner/order-independent (runtime/elastic.py contract)."""
    from repro.runtime.elastic import WorkQueue
    root, mps = chain
    base = jax.random.key(21)
    with api.SamplingSession(root, api.SamplerConfig(segment_len=5)) as sess:
        q = WorkQueue(3)
        outs = sess.run_queue(q, 8, base)
        assert q.finished
    for b in range(3):
        ref = np.asarray(S.sample(mps, 8, jax.random.fold_in(base, b)))
        assert np.array_equal(outs[b], ref)


def test_born_semantics_both_backends(tmp_path, born_mps_6x4):
    mps = born_mps_6x4
    key = jax.random.key(2)
    ref = np.asarray(S.sample(mps, 16, key,
                              S.SamplerConfig(semantics="born")))
    with api.SamplingSession(mps) as sess:
        assert sess.plan(16).semantics == "born"     # auto from the MPS
        assert np.array_equal(sess.sample(16, key), ref)
    with GammaStore(str(tmp_path), storage_dtype=jnp.complex128,
                    compute_dtype=jnp.complex128) as store:
        store.write_mps(mps)
        cfg = api.SamplerConfig(semantics="born", segment_len=4)
        with api.SamplingSession(store, cfg) as sess:
            assert np.array_equal(sess.sample(16, key), ref)


# ---------------------------------------------------------------------------
# Planning, registry, lifecycle, deprecation
# ---------------------------------------------------------------------------

def test_plan_and_explain(chain):
    root, _ = chain
    with api.SamplingSession(root) as sess:
        plan = sess.plan(24)
        assert plan.backend == "streamed" and plan.scheme == "seq"
        assert plan.segment_len and plan.segment_len >= 1
        info = sess.explain(24)
        assert info["backend"] == "streamed"
        assert info["chi_buckets"] == [6]
        assert "io_overlapped" in info and "segment_len" in info


def test_backend_registry():
    assert set(api.available_backends()) >= {"inmem", "streamed"}
    assert api.get_backend("inmem").name == "inmem"
    with pytest.raises(ValueError, match="no backend"):
        api.get_backend("nope")

    @api.register_backend("_test_backend")
    class _TB(api.Backend):
        name = "_test_backend"

        def sample(self, req):
            return np.zeros((req.n_samples, 1), np.int32)

    try:
        assert "_test_backend" in api.available_backends()
    finally:
        from repro.api import backends as B
        B._REGISTRY.pop("_test_backend", None)


def test_resolution_errors(linear_mps_10x6):
    mps = linear_mps_10x6
    with api.SamplingSession(mps, api.SamplerConfig(scheme="dp")) as sess:
        with pytest.raises(ValueError, match="needs a mesh"):
            sess.plan(8)
    with api.SamplingSession(mps, api.SamplerConfig(micro_batch=7)) as sess:
        with pytest.raises(ValueError, match="micro_batch"):
            sess.plan(24)
    bad_prof = (6,) * 9                              # covers 9 of 10 sites
    with api.SamplingSession(
            mps, api.SamplerConfig(chi_profile=bad_prof)) as sess:
        with pytest.raises(ValueError, match="chi_profile"):
            sess.plan(8)
    with api.SamplingSession(mps) as sess:
        with pytest.raises(ValueError, match="resume"):
            sess.sample(8, jax.random.key(0), resume=True)


def test_auto_micro_degrades_on_unsupported_combination(linear_mps_10x6):
    """AUTO fields must resolve to supported values: micro_batch=AUTO on the
    seq+dynamic-χ in-memory path degrades to None instead of raising."""
    prof = tuple(int(c) for c in DB.bucketize(DB.area_law_profile(10, 6),
                                              [4, 6]))
    cfg = api.SamplerConfig(micro_batch=api.AUTO, chi_profile=prof,
                            device_budget=2e4)
    with api.SamplingSession(linear_mps_10x6, cfg) as sess:
        plan = sess.plan(24)
        assert plan.scheme == "seq" and plan.micro_batch is None


def test_gamma_store_context_manager(tmp_path, linear_mps_10x6):
    with GammaStore(str(tmp_path), storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(linear_mps_10x6)
        assert store.n_sites == 10
    assert not store._thread.is_alive()              # prefetch thread joined


def test_legacy_entry_points_warn(chain):
    root, mps = chain
    from repro.core import parallel as PP
    from repro.engine import StreamPlan, stream_sample
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.warns(DeprecationWarning, match="repro.api"):
        PP.multilevel_sample(mesh, mps, 8, jax.random.key(0))
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        with pytest.warns(DeprecationWarning, match="repro.api"):
            stream_sample(store, 8, jax.random.key(0),
                          plan=StreamPlan(segment_len=5))


def test_parallel_log_scale_parity(linear_mps_10x6):
    """Satellite: the DP segment runner carries the same per-sample
    log_scale diagnostic as the in-memory chain scan."""
    from repro.core import parallel as PP
    mps = linear_mps_10x6
    key = jax.random.key(4)
    # dp hands shard i the key split(key, p1)[i]; p1 = 1 here
    state = S.init_state(mps, 8, jax.random.split(key, 1)[0])
    res = S.sample_chain(mps, state, S.SamplerConfig())
    mesh = jax.make_mesh((1,), ("data",))
    env = PP.segment_env_init(8, mps.chi, mps.gammas.dtype)
    _, _, ls = PP.sample_segment(mesh, mps, env, key, 0,
                                 PP.ParallelConfig("dp"), S.SamplerConfig())
    np.testing.assert_allclose(np.asarray(ls),
                               np.asarray(res.state.log_scale), rtol=1e-12)


# ---------------------------------------------------------------------------
# The full DP/TP matrix (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

_CHILD = textwrap.dedent("""
    import json, os, tempfile, warnings
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.core import dynamic_bond as DB, mps as M, parallel as PP
    from repro.core import sampler as S
    from repro.data.gamma_store import GammaStore
    from repro.launch.mesh import make_host_mesh

    m = M.random_linear_mps(jax.random.key(0), 8, 8, 3)
    mesh = make_host_mesh(model=4)             # 2 data x 4 model
    key = jax.random.key(7)

    # the pre-existing legacy path is the static reference
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ref = np.asarray(PP.multilevel_sample(mesh, m, 64, key,
                                              PP.ParallelConfig("dp")))

    root = tempfile.mkdtemp()
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as st:
        st.write_mps(m)

    # dynamic-χ reference: per-shard staged chains (even-aligned stages so
    # tp_double's site pairs never straddle a χ transition)
    prof = np.array([4, 4, 8, 8, 8, 8, 4, 4])
    sk = jax.random.split(key, 2)
    ref_dyn = np.concatenate([np.asarray(DB.sample_staged(m, prof, 32, sk[i]))
                              for i in range(2)], 0)
    ref_mb = np.concatenate([np.asarray(S.sample_batched(m, 32, sk[i], 8))
                             for i in range(2)], 0)

    out = {}
    for backend, src in (("inmem", m), ("streamed", root)):
        for scheme in ("dp", "tp_single", "tp_double"):
            cfg = api.SamplerConfig(backend=backend, scheme=scheme,
                                    segment_len=2)
            with api.SamplingSession(src, cfg, mesh=mesh) as sess:
                out[f"{backend}_{scheme}_static"] = bool(
                    np.array_equal(sess.sample(64, key), ref))
            cfgd = api.SamplerConfig(backend=backend, scheme=scheme,
                                     segment_len=2,
                                     chi_profile=tuple(int(c) for c in prof))
            with api.SamplingSession(src, cfgd, mesh=mesh) as sess:
                out[f"{backend}_{scheme}_dynamic"] = bool(
                    np.array_equal(sess.sample(64, key), ref_dyn))
        # micro batching N2 under a parallel scheme (per data shard)
        cfgm = api.SamplerConfig(backend=backend, scheme="tp_single",
                                 segment_len=4, micro_batch=8)
        with api.SamplingSession(src, cfgm, mesh=mesh) as sess:
            out[f"{backend}_tp_single_micro"] = bool(
                np.array_equal(sess.sample(64, key), ref_mb))

    # log_scale diagnostic parity: the TP segment runners accumulate the
    # same per-sample rescale log as the DP path (satellite)
    envd = PP.segment_env_init(64, 8, m.gammas.dtype)
    _, _, lsd = PP.sample_segment(mesh, m, envd, key, 0,
                                  PP.ParallelConfig("dp"), S.SamplerConfig())
    _, _, ls1 = PP.sample_segment(mesh, m, envd, key, 0,
                                  PP.ParallelConfig("tp_single"),
                                  S.SamplerConfig())
    _, _, ls2 = PP.sample_segment(mesh, m, envd, key, 0,
                                  PP.ParallelConfig("tp_double"),
                                  S.SamplerConfig())
    out["log_scale_tp_parity"] = bool(
        np.allclose(lsd, ls1, rtol=1e-12)
        and np.allclose(lsd, ls2, rtol=1e-12))

    # multi-pod mesh: "pod" folds into data parallel — the resolved
    # ParallelConfig.data_axes must cover every non-model axis
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    with api.SamplingSession(m, api.SamplerConfig(scheme="dp"),
                             mesh=mesh3) as sess:
        assert sess.plan(64).p1 == 4
        out3 = sess.sample(64, key)
    sk4 = jax.random.split(key, 4)
    ref3 = np.concatenate([np.asarray(S.sample(m, 16, sk4[i]))
                           for i in range(4)], 0)
    out["multipod_dp"] = bool(np.array_equal(out3, ref3))

    # plan-time validation: fixed-χ TP divisibility surfaces pre-compile
    m_bad = M.random_linear_mps(jax.random.key(1), 6, 6, 3)
    try:
        with api.SamplingSession(m_bad, api.SamplerConfig(scheme="tp_single"),
                                 mesh=mesh) as sess:
            sess.plan(64)
        out["tp_chi_plan_error"] = False
    except ValueError:
        out["tp_chi_plan_error"] = True

    # kill-and-resume through the facade: streamed dp, dynamic chi
    ck = tempfile.mkdtemp()
    cfg = api.SamplerConfig(backend="streamed", scheme="dp", segment_len=2,
                            chi_profile=tuple(int(c) for c in prof),
                            checkpoint_dir=ck, checkpoint_every=1)
    with api.SamplingSession(root, cfg, mesh=mesh) as sess:
        sess.sample(64, key, stop_after_segments=2)
    with api.SamplingSession(root, cfg, mesh=mesh) as sess:
        out["resume_dynamic_dp"] = bool(
            np.array_equal(sess.sample(64, key, resume=True), ref_dyn))
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def matrix_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("cell", [
    f"{b}_{s}_{m}"
    for b in ("inmem", "streamed")
    for s in ("dp", "tp_single", "tp_double")
    for m in ("static", "dynamic")
] + ["inmem_tp_single_micro", "streamed_tp_single_micro",
     "resume_dynamic_dp", "log_scale_tp_parity",
     "multipod_dp", "tp_chi_plan_error"])
def test_cross_backend_matrix(matrix_results, cell):
    """One seed ⇒ bit-identical samples in every supported cell of
    {inmem, streamed} × {dp, tp_single, tp_double} × {static, dynamic-χ},
    micro-batched DP/TP, and a kill-and-resume — all through the facade."""
    assert matrix_results[cell]
