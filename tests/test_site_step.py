"""Fused site-step pipeline + kernel dispatch layer.

Three layers of coverage:

* the fused Pallas kernels vs the pure-jnp oracle (interpret mode) across
  linear/born semantics and *awkward* shapes — non-power-of-two and
  non-multiple-of-tile χ, which the old ``test_kernels`` sweeps never hit;
* the dispatch registry + autotuner (heuristic table, cache behaviour,
  VMEM-model shrinking, graceful fallback for cells with no Pallas impl);
* the §4.1 seed contract across the dispatch boundary: ``kernels="pallas"``
  ≡ ``kernels="xla"`` bit-for-bit across seq/dp/tp_single/tp_double ×
  static/dynamic-χ (multi-device cells in a forced-8-device subprocess).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dynamic_bond as DB
from repro.core import mps as M
from repro.core import sampler as S
from repro.kernels import dispatch, ref
from repro.kernels.site_step import measure_probs, site_step_born, \
    site_step_linear


# ---------------------------------------------------------------------------
# kernel vs oracle — interpret mode, awkward shapes included
# ---------------------------------------------------------------------------

# (n, chi, d): 96 = 3·32 non-power-of-two; 24/12 non-multiples of any MXU
# tile; 7 prime (blocks degrade to the whole dimension)
_SHAPES = [(8, 16, 2), (16, 96, 3), (32, 24, 4), (8, 12, 3), (16, 7, 2)]


def _operands(n, chi, d, dtype=jnp.float64, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.key(seed), 4)
    env = jax.random.uniform(k1, (n, chi), dtype=dtype)
    gamma = jax.random.uniform(k2, (chi, chi, d), dtype=dtype)
    lam = jax.random.uniform(k3, (chi,), dtype=dtype)
    u = jax.random.uniform(k4, (n,), dtype=dtype)
    return env, gamma, lam, u


def _blocks(n, chi):
    cfg = dispatch._heuristic("site_step", n, chi, chi, 3, 8, 1)
    return dict(bn=min(cfg.bn, 8), br=cfg.br, bl=cfg.bl)


@pytest.mark.parametrize("n,chi,d", _SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_site_step_linear_vs_ref(n, chi, d, dtype):
    env, gamma, lam, u = _operands(n, chi, d, dtype)
    e_r, s_r, dl_r = ref.site_step_ref(env, gamma, lam, u, "linear")
    e_k, s_k, dl_k = site_step_linear(env, gamma, lam, u, interpret=True,
                                      **_blocks(n, chi))
    tol = 1e-4 if dtype == jnp.float32 else 1e-9
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_r), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(dl_k), np.asarray(dl_r), rtol=tol,
                               atol=tol)


@pytest.mark.parametrize("n,chi,d", _SHAPES)
def test_site_step_born_vs_ref(n, chi, d):
    k1, k2, k3, k4, k5 = jax.random.split(jax.random.key(1), 5)
    env = (jax.random.normal(k1, (n, chi), dtype=jnp.float64)
           + 1j * jax.random.normal(k5, (n, chi), dtype=jnp.float64))
    gamma = (jax.random.normal(k2, (chi, chi, d), dtype=jnp.float64)
             + 1j * jax.random.normal(k3, (chi, chi, d), dtype=jnp.float64))
    lam = jax.random.uniform(k3, (chi,), dtype=jnp.float64) + 0.5
    u = jax.random.uniform(k4, (n,), dtype=jnp.float64)
    e_r, s_r, dl_r = ref.site_step_ref(env, gamma, lam, u, "born")
    e_k, s_k, dl_k = site_step_born(env, gamma, lam, u, interpret=True,
                                    **_blocks(n, chi))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_r), rtol=1e-9,
                               atol=1e-9)
    np.testing.assert_allclose(np.asarray(dl_k), np.asarray(dl_r), rtol=1e-9,
                               atol=1e-9)


def test_site_step_bf16_compute_dtype():
    """The §3.3 MXU tier: bf16 GEMM inputs, fp32 accumulate, inside the
    fused kernel — matches the XLA mixed-precision site step closely."""
    env, gamma, lam, u = _operands(16, 32, 3, jnp.float32, seed=3)
    e_k, s_k, _ = site_step_linear(env, gamma, lam, u, bn=8, br=16, bl=16,
                                   compute_dtype=jnp.bfloat16,
                                   interpret=True)
    e_r, s_r, _ = ref.site_step_ref(env, gamma, lam, u, "linear")
    assert e_k.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_r), atol=3e-2)


def test_measure_probs_vs_ref():
    for (n, L, d) in [(16, 32, 3), (8, 24, 4), (32, 7, 2)]:
        k1, k2 = jax.random.split(jax.random.key(4))
        env = jax.random.uniform(k1, (n, L), dtype=jnp.float64)
        w = jax.random.uniform(k2, (L, d), dtype=jnp.float64)
        cfg = dispatch._heuristic("measure", n, L, L, d, 8, 1)
        out = measure_probs(env, w, bn=cfg.bn, bl=cfg.bl, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(env @ w),
                                   rtol=1e-12)


def test_scaling_none_and_global_reject():
    env, gamma, lam, u = _operands(8, 16, 2)
    e_k, _, dl_k = site_step_linear(env, gamma, lam, u, bn=8, br=16, bl=16,
                                    scaling="none", interpret=True)
    e_r, _, dl_r = ref.site_step_ref(env, gamma, lam, u, scaling="none")
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_r), rtol=1e-9)
    np.testing.assert_array_equal(np.asarray(dl_k), 0.0)
    with pytest.raises(ValueError, match="scaling"):
        site_step_linear(env, gamma, lam, u, scaling="global",
                         interpret=True)


# ---------------------------------------------------------------------------
# dispatch registry + autotuner
# ---------------------------------------------------------------------------

def test_registry_resolution_and_fallback():
    # every stage has an xla cell for linear
    for stage in dispatch.STAGES:
        assert dispatch.get_site_op(stage, "linear", "xla")
    # born split-K TP cells have no Pallas kernel → silent xla fallback
    # (|Σ·|² ≠ Σ|·|²: fusing the measure into the split-K GEMM is invalid)
    assert (dispatch.get_site_op("contract_measure", "born", "pallas")
            is dispatch.get_site_op("contract_measure", "born", "xla"))
    # born site_step DOES have a Pallas cell
    assert (dispatch.get_site_op("site_step", "born", "pallas")
            is not dispatch.get_site_op("site_step", "born", "xla"))
    with pytest.raises(ValueError, match="kernels must be one of"):
        dispatch.resolve_kernels("cuda")
    assert dispatch.resolve_kernels("auto") in ("pallas", "xla")


def test_autotuner_heuristic_divides_and_caches():
    dispatch.clear_autotune_cache()
    cfg = dispatch.autotune("site_step", n=96, chi_l=24, chi_r=24, d=3,
                            dtype=jnp.float64)
    assert 96 % cfg.bn == 0 and 24 % cfg.br == 0 and 24 % cfg.bl == 0
    stats0 = dispatch.autotune_cache_stats()
    assert stats0["entries"] == 1 and stats0["misses"] == 1
    cfg2 = dispatch.autotune("site_step", n=96, chi_l=24, chi_r=24, d=3,
                             dtype=jnp.float64)
    assert cfg2 == cfg
    assert dispatch.autotune_cache_stats()["hits"] == 1
    # prime χ degrades to whole-dimension blocks, still legal
    cfg3 = dispatch.autotune("site_step", n=8, chi_l=7, chi_r=7, d=2,
                             dtype=jnp.float64)
    assert 7 % cfg3.br == 0 and 7 % cfg3.bl == 0


def test_autotuner_vmem_model_shrinks_bn():
    """At large χ the resident temp slab dominates — BN must shrink until
    the working-set model fits the VMEM budget."""
    dispatch.clear_autotune_cache()
    cfg = dispatch.autotune("site_step", n=4096, chi_l=8192, chi_r=8192,
                            d=4, dtype=jnp.float32)
    bytes_ = dispatch._working_set_bytes("site_step", cfg, 8192, 4, 4, 1)
    assert bytes_ <= dispatch._VMEM_BUDGET_BYTES
    assert cfg.bn < 256                 # it had to shrink


def test_warm_site_step_seeds_cache():
    dispatch.clear_autotune_cache()
    from repro.kernels.site_impls import warm_site_step
    warm_site_step(64, 16, 3, jnp.float64, semantics="linear")
    assert dispatch.autotune_cache_stats()["entries"] == 1
    # the traced lookup that follows is a pure cache hit
    dispatch.autotune("site_step", n=64, chi_l=16, chi_r=16, d=3,
                      dtype=jnp.float64)
    assert dispatch.autotune_cache_stats()["hits"] == 1


# ---------------------------------------------------------------------------
# seed-bit-identity: kernels="pallas" ≡ kernels="xla" (§4.1 across the
# kernel boundary) — seq / dynamic-χ in-process, DP/TP in a subprocess
# ---------------------------------------------------------------------------

def test_seq_pallas_equals_xla(linear_mps_10x6):
    key = jax.random.key(11)
    a = S.sample(linear_mps_10x6, 48, key, S.SamplerConfig(kernels="xla"))
    b = S.sample(linear_mps_10x6, 48, key, S.SamplerConfig(kernels="pallas"))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_seq_born_pallas_equals_xla(born_mps_6x4):
    key = jax.random.key(12)
    cfg = dict(semantics="born")
    a = S.sample(born_mps_6x4, 32, key, S.SamplerConfig(kernels="xla", **cfg))
    b = S.sample(born_mps_6x4, 32, key,
                 S.SamplerConfig(kernels="pallas", **cfg))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dynamic_chi_pallas_equals_xla(linear_mps_10x6):
    """Staged (dynamic-χ) walks hit several kernel shapes in one chain —
    every bucket goes through the same dispatch."""
    prof = DB.bucketize(DB.area_law_profile(10, 6, n_photon=1.0),
                        [2, 3, 6])
    key = jax.random.key(13)
    a = DB.sample_staged(linear_mps_10x6, prof, 32, key,
                         S.SamplerConfig(kernels="xla"))
    b = DB.sample_staged(linear_mps_10x6, prof, 32, key,
                         S.SamplerConfig(kernels="pallas"))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_session_resolves_kernels(linear_mps_10x6):
    from repro import api
    with api.SamplingSession(linear_mps_10x6) as session:
        plan = session.plan(16)
        assert plan.kernels in ("pallas", "xla")      # AUTO resolved
        assert plan.sampler_config.kernels == plan.kernels
        assert session.explain(16)["kernels"] == plan.kernels
    cfg = api.SamplerConfig(kernels="pallas")
    with api.SamplingSession(linear_mps_10x6, cfg) as session:
        key = jax.random.key(3)
        out = session.sample(16, key)
    with api.SamplingSession(linear_mps_10x6,
                             api.SamplerConfig(kernels="xla")) as session:
        ref_out = session.sample(16, jax.random.key(3))
    np.testing.assert_array_equal(out, ref_out)


_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from repro.core import dynamic_bond as DB
    from repro.core import mps as M, parallel as PP, sampler as S
    from repro.launch.mesh import make_host_mesh
    from repro import api

    out = {}
    m = M.random_linear_mps(jax.random.key(0), n_sites=6, chi=8, d=3)
    mb = M.random_born_mps(jax.random.key(2), 4, 8, 2)
    mesh = make_host_mesh(model=4)           # 2 data x 4 model
    key = jax.random.key(7)

    for scheme in ("dp", "tp_single", "tp_double"):
        pcs = [(scheme, PP.ParallelConfig(scheme))]
        if scheme == "tp_single":
            pcs.append((scheme + "_mf",
                        PP.ParallelConfig(scheme, measure_first=True)))
        if scheme in ("tp_single", "tp_double"):
            # §3.3.2-on-the-wire cast: the one cell where measure-of-psum vs
            # psum-of-partial-measures could diverge if mishandled
            pcs.append((scheme + "_wire",
                        PP.ParallelConfig(scheme, wire_dtype=jnp.bfloat16)))
        for tag, pc in pcs:
            x = PP._multilevel_sample(mesh, m, 64, key, pc,
                                      S.SamplerConfig(kernels="xla"))
            p = PP._multilevel_sample(mesh, m, 64, key, pc,
                                      S.SamplerConfig(kernels="pallas"))
            out[tag] = bool(jnp.all(x == p))
            xb = PP._multilevel_sample(mesh, mb, 32, key, pc,
                S.SamplerConfig(semantics="born", kernels="xla"))
            pb = PP._multilevel_sample(mesh, mb, 32, key, pc,
                S.SamplerConfig(semantics="born", kernels="pallas"))
            out["born_" + tag] = bool(jnp.all(xb == pb))

    # dynamic-χ under DP/TP through the session front door (stage
    # boundaries even so the profile also composes with tp_double)
    prof = (4, 4, 8, 8, 4, 4)
    for scheme in ("dp", "tp_single", "tp_double"):
        res = {}
        for kern in ("xla", "pallas"):
            cfg = api.SamplerConfig(scheme=scheme, kernels=kern,
                                    chi_profile=prof)
            with api.SamplingSession(m, cfg, mesh=mesh) as session:
                res[kern] = session.sample(64, key)
        out["dyn_" + scheme] = bool(
            np.array_equal(res["xla"], res["pallas"]))
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def kernel_matrix_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("cell", [
    "dp", "tp_single", "tp_single_mf", "tp_single_wire", "tp_double",
    "tp_double_wire",
    "born_dp", "born_tp_single", "born_tp_single_mf", "born_tp_single_wire",
    "born_tp_double", "born_tp_double_wire",
    "dyn_dp", "dyn_tp_single", "dyn_tp_double",
])
def test_kernel_bitidentity_matrix(kernel_matrix_results, cell):
    """kernels="pallas" ≡ kernels="xla" per seed, every schedule cell."""
    assert kernel_matrix_results[cell], (cell, kernel_matrix_results)
