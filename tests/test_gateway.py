"""Gateway e2e: the HTTP front door over a real socket.

Every test talks to a live ``ThreadingHTTPServer`` through
``http.client`` — no handler mocking — because the claims under test are
wire-level: streamed bytes bit-identical to the in-process service,
quota 429s with Retry-After, one execution per content-address no matter
how many requests ask, and a Prometheus scrape that reflects it all.

THE acceptance test (``test_acceptance_two_tenants_one_execution``): two
tenants submit the same job over HTTP → it executes once; the streamed
bytes are bit-identical to an in-process ``SamplingService`` run; a third
over-quota request gets 429; ``GET /metrics`` exposes nonzero
queue/admission/cache counters.
"""
import http.client
import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from chaos import DropResult
from repro import api
from repro.api.service import SamplingService, batch_key
from repro.data.gamma_store import GammaStore
from repro.obs import MetricsRegistry, instrument_service
from repro.runtime import transport
from repro.serve import (Gateway, QuotaExceeded, ResultCache, Tenant,
                         TenantTable, cache_key)
from repro.serve.cache import Entry


@pytest.fixture(scope="module")
def chain(tmp_path_factory, linear_mps_10x6):
    root = str(tmp_path_factory.mktemp("gw_gamma"))
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(linear_mps_10x6)
    return root


# ---------------------------------------------------------------------------
# a minimal real-socket client
# ---------------------------------------------------------------------------

class _Exact:
    """read-exactly adapter: a chunked HTTPResponse's read(n) may return
    short across chunk boundaries; the frame codec needs exact reads."""

    def __init__(self, resp):
        self.resp = resp

    def read(self, n):
        out = b""
        while len(out) < n:
            chunk = self.resp.read(n - len(out))
            if not chunk:
                break
            out += chunk
        return out


class Client:
    def __init__(self, gw, api_key=None):
        host, port = gw._server.server_address[:2]
        self.conn = http.client.HTTPConnection(host, port)
        self.api_key = api_key

    def _headers(self):
        return {"x-api-key": self.api_key} if self.api_key else {}

    def request(self, method, path, body=None):
        self.conn.request(method, path,
                          None if body is None else json.dumps(body),
                          self._headers())
        resp = self.conn.getresponse()
        return resp.status, dict(resp.getheaders()), \
            json.loads(resp.read() or b"{}")

    def submit(self, store, n_samples, seed, macro_batches=1, config=None,
               **extra):
        body = {"store": store, "n_samples": n_samples, "seed": seed,
                "macro_batches": macro_batches, **extra}
        if config is not None:
            body["config"] = config
        return self.request("POST", "/v1/jobs", body)

    def stream_frames(self, gid):
        """[(batch_id, npy frame bytes), ...] + the terminal header."""
        self.conn.request("GET", f"/v1/jobs/{gid}/stream", None,
                          self._headers())
        resp = self.conn.getresponse()
        assert resp.status == 200
        rx = _Exact(resp)
        frames, terminal = [], None
        while terminal is None:
            head = json.loads(transport.read_frame(rx))
            if head["kind"] == "block":
                frames.append((head["batch_id"], transport.read_frame(rx)))
            else:
                terminal = head
        resp.read()                        # drain the chunked terminator
        return frames, terminal

    def stream_samples(self, gid):
        frames, terminal = self.stream_frames(gid)
        assert terminal["kind"] == "end", terminal
        return np.concatenate(
            [transport.array_from_frame(f) for _, f in frames], axis=0)

    def close(self):
        self.conn.close()


def _inprocess_frames(root, n_samples, key, macro_batches):
    """What the gateway MUST put on the wire: the in-process service's
    blocks through the same frame serializer."""
    with SamplingService(workers=1) as svc:
        h = svc.submit(root, n_samples=n_samples, key=key,
                       macro_batches=macro_batches)
        return [(b, transport.array_to_frame(blk))
                for b, blk in h.stream(timeout=300)]


# ---------------------------------------------------------------------------
# submit / stream / status / cancel / validation
# ---------------------------------------------------------------------------

def test_submit_stream_status_and_validation(chain):
    with SamplingService(workers=2) as svc, Gateway(svc) as gw:
        c = Client(gw)
        code, _, sub = c.submit(chain, 16, seed=3, macro_batches=4)
        assert code == 201 and sub["cache"] == "miss"
        samples = c.stream_samples(sub["id"])
        ref_frames = _inprocess_frames(chain, 16, jax.random.key(3), 4)
        ref = np.concatenate(
            [transport.array_from_frame(f) for _, f in ref_frames], axis=0)
        assert np.array_equal(samples, ref)

        code, _, st = c.request("GET", f"/v1/jobs/{sub['id']}")
        assert code == 200 and st["state"] == "done"
        assert st["blocks_done"] == 4 and st["progress"]["done"] == 4

        # the error surface: 404, unknown fields, bad splits, bad JSON
        code, _, err = c.request("GET", "/v1/jobs/j999")
        assert code == 404 and "no such job" in err["error"]
        code, _, err = c.submit(chain, 16, seed=0, bogus=1)
        assert code == 400 and "bogus" in err["error"]
        code, _, err = c.submit(chain, 16, seed=0,
                                config={"made_up_knob": 2})
        assert code == 400 and "made_up_knob" in err["error"]
        code, _, err = c.submit(chain, 10, seed=0, macro_batches=4)
        assert code == 400 and "divide" in err["error"]
        code, _, err = c.submit(chain, 16, seed=0,
                                config={"runtime": "local"})
        assert code == 400 and "server-side" in err["error"]
        code, _, err = c.submit("/nonexistent/store", 16, seed=0)
        assert code == 400 and "store" in err["error"]
        c.conn.request("POST", "/v1/jobs", b"not json{")
        resp = c.conn.getresponse()
        assert resp.status == 400
        resp.read()
        c.close()


def test_cancel_running_job_streams_error_frame(chain):
    with SamplingService(workers=1) as svc, Gateway(svc) as gw:
        release = threading.Event()
        svc.batch_hook = lambda job, b, w: release.wait(timeout=60)
        c = Client(gw)
        code, _, sub = c.submit(chain, 16, seed=9, macro_batches=4)
        assert code == 201
        code, _, out = c.request("DELETE", f"/v1/jobs/{sub['id']}")
        assert code == 200 and out["cancelled"] is True
        release.set()
        frames, terminal = c.stream_frames(sub["id"])
        assert terminal["kind"] == "error"
        code, _, st = c.request("GET", f"/v1/jobs/{sub['id']}")
        assert st["state"] == "cancelled"
        c.close()


# ---------------------------------------------------------------------------
# authorization: job routes are tenant-scoped
# ---------------------------------------------------------------------------

def test_job_routes_are_tenant_scoped(chain):
    """A job id is not a capability: another tenant's GET/stream/DELETE
    answers 404 (indistinguishable from absent), a keyless request 401,
    and a foreign DELETE must NOT cancel the owner's execution."""
    table = TenantTable([Tenant(name="alice", api_key="alice-key"),
                         Tenant(name="mallory", api_key="mallory-key")])
    with SamplingService(workers=1) as svc, \
            Gateway(svc, tenants=table) as gw:
        release = threading.Event()
        svc.batch_hook = lambda job, b, w: release.wait(timeout=60)
        alice = Client(gw, api_key="alice-key")
        mallory = Client(gw, api_key="mallory-key")
        nokey = Client(gw)
        code, _, sub = alice.submit(chain, 8, seed=31)
        assert code == 201
        gid = sub["id"]
        assert len(gid) > 16          # unguessable token, not a sequence

        for method, path in [("GET", f"/v1/jobs/{gid}"),
                             ("GET", f"/v1/jobs/{gid}/stream"),
                             ("DELETE", f"/v1/jobs/{gid}")]:
            code, _, err = mallory.request(method, path)
            assert code == 404, (method, path, err)
            code, _, err = nokey.request(method, path)
            assert code == 401, (method, path, err)

        # mallory's DELETEs changed nothing: alice's job still runs,
        # drains, and streams to completion
        code, _, st = alice.request("GET", f"/v1/jobs/{gid}")
        assert code == 200 and st["state"] in ("pending", "running")
        release.set()
        assert alice.stream_samples(gid).shape == (8, 10)
        for c in (alice, mallory, nokey):
            c.close()


def test_store_root_confines_client_paths(chain, tmp_path):
    """With --store-root, the store field is a name under the root:
    absolute paths and ``..`` escapes are 400s, never touched."""
    root = os.path.dirname(chain)
    with SamplingService(workers=1) as svc, \
            Gateway(svc, store_root=root) as gw:
        c = Client(gw)
        code, _, err = c.submit(chain, 8, seed=0)       # absolute path
        assert code == 400 and "absolute" in err["error"]
        code, _, err = c.submit("../" + os.path.basename(root) + "/"
                                + os.path.basename(chain), 8, seed=0)
        assert code == 400 and "escapes" in err["error"]
        code, _, err = c.submit("../../../../etc", 8, seed=0)
        assert code == 400 and "escapes" in err["error"]
        code, _, sub = c.submit(os.path.basename(chain), 8, seed=0)
        assert code == 201
        assert c.stream_samples(sub["id"]).shape == (8, 10)
        c.close()


def test_store_digest_cache_catches_same_size_rewrite(chain, tmp_path):
    """The digest cache's signature must see an atomic same-size rewrite
    (st_ino/st_mtime_ns, not coarse mtime+size) — a stale store digest
    would serve a stale cached result."""
    import shutil as _sh
    root = str(tmp_path / "copy")
    _sh.copytree(chain, root)
    with SamplingService(workers=1) as svc, Gateway(svc) as gw:
        d1, _ = gw._store_identity(root)
        assert gw._store_identity(root) == (d1, 10)      # cached path
        site = sorted(f for f in os.listdir(root)
                      if f.startswith("site_"))[0]
        p = os.path.join(root, site)
        st = os.stat(p)
        raw = bytearray(open(p, "rb").read())
        raw[-1] ^= 0xFF                                  # same size, new bytes
        tmp = p + ".new"
        with open(tmp, "wb") as f:
            f.write(raw)
        os.utime(tmp, ns=(st.st_atime_ns, st.st_mtime_ns))   # same mtime_ns
        os.replace(tmp, p)
        d2, _ = gw._store_identity(root)
        assert d2 != d1


# ---------------------------------------------------------------------------
# quotas / tenancy
# ---------------------------------------------------------------------------

def test_quota_exhaustion_429_and_recovery(chain):
    table = TenantTable([Tenant(name="t", api_key="tk", max_active_jobs=1)])
    with SamplingService(workers=1) as svc, \
            Gateway(svc, tenants=table) as gw:
        release = threading.Event()
        svc.batch_hook = lambda job, b, w: release.wait(timeout=60)
        c = Client(gw, api_key="tk")
        code, _, first = c.submit(chain, 8, seed=1)
        assert code == 201
        # second DISTINCT job while the first executes: over quota
        code, headers, err = c.submit(chain, 8, seed=2)
        assert code == 429
        assert int(headers["Retry-After"]) >= 1
        assert "admission" in err          # service backpressure snapshot
        # unknown key → 401 (table is closed once tenants exist)
        bad = Client(gw, api_key="wrong")
        code, _, _ = bad.submit(chain, 8, seed=3)
        assert code == 401
        bad.close()
        # recovery: drain the first job, the slot frees, resubmit lands
        # (the quota releases on the owner pump's epilogue — a hair after
        # the last block reaches the stream — so poll briefly)
        release.set()
        assert c.stream_samples(first["id"]).shape == (8, 10)
        deadline = time.monotonic() + 30
        while True:
            code, _, third = c.submit(chain, 8, seed=2)
            if code == 201 or time.monotonic() > deadline:
                break
            time.sleep(0.05)
        assert code == 201
        c.stream_samples(third["id"])
        c.close()
    assert table.stats()["rejected"] == 1


def test_fair_share_priority_decays_with_active_jobs():
    table = TenantTable([Tenant(name="a", api_key="ak", priority=10)])
    t = table.resolve("ak")
    assert table.begin_job(t, 100) == 10       # idle tenant: base priority
    assert table.begin_job(t, 100) == 9        # each active job demotes
    assert table.begin_job(t, 100) == 8
    table.end_job(t, 100)
    assert table.begin_job(t, 100) == 8
    with pytest.raises(QuotaExceeded):
        table.begin_job(Tenant(name="q", api_key="q", max_active_bytes=10),
                        100)


# ---------------------------------------------------------------------------
# the result cache: hits, in-flight dedup, disk, LRU
# ---------------------------------------------------------------------------

def test_cache_hit_serves_bit_identical_bytes_one_execution(chain, tmp_path):
    cache = ResultCache(cache_dir=str(tmp_path / "cache"))
    with SamplingService(workers=2) as svc, \
            Gateway(svc, cache=cache) as gw:
        c = Client(gw)
        code, _, first = c.submit(chain, 16, seed=5, macro_batches=2)
        assert first["cache"] == "miss"
        frames1, t1 = c.stream_frames(first["id"])
        code, _, second = c.submit(chain, 16, seed=5, macro_batches=2)
        assert second["cache"] == "hit"
        frames2, t2 = c.stream_frames(second["id"])
        assert frames1 == frames2              # the exact same bytes
        assert svc.stats()["jobs"]["done"] == 1    # ONE execution
        c.close()
    # disk round-trip: a fresh gateway + fresh service, same cache dir —
    # the hit comes off disk, no execution at all
    cache2 = ResultCache(cache_dir=str(tmp_path / "cache"))
    with SamplingService(workers=1) as svc2, \
            Gateway(svc2, cache=cache2) as gw2:
        c2 = Client(gw2)
        code, _, again = c2.submit(chain, 16, seed=5, macro_batches=2)
        assert again["cache"] == "hit"
        frames3, _ = c2.stream_frames(again["id"])
        assert frames3 == frames1
        assert svc2.stats()["jobs"]["done"] == 0
        c2.close()


def test_inflight_dedup_second_request_attaches(chain):
    with SamplingService(workers=1) as svc, Gateway(svc) as gw:
        release = threading.Event()
        svc.batch_hook = lambda job, b, w: release.wait(timeout=60)
        c1, c2 = Client(gw), Client(gw)
        code, _, first = c1.submit(chain, 16, seed=11, macro_batches=4)
        assert first["cache"] == "miss"
        code, _, second = c2.submit(chain, 16, seed=11, macro_batches=4)
        assert second["cache"] == "attach"     # dedup while RUNNING
        release.set()
        s2 = c2.stream_samples(second["id"])   # attacher first: it streams
        s1 = c1.stream_samples(first["id"])    # the owner's blocks live
        assert np.array_equal(s1, s2)
        assert svc.stats()["jobs"]["done"] == 1
        assert gw.cache.stats()["attaches"] == 1
        c1.close()
        c2.close()


def test_cache_lru_evicts_under_byte_budget(tmp_path):
    cache = ResultCache(cache_dir=str(tmp_path / "lru"), max_bytes=3000)
    filler = np.zeros((16, 16), np.float32)        # ~1 KiB per entry
    for i in range(5):
        e, status = cache.get_or_begin(f"key-{i:02d}", 1)
        assert status == "miss"
        e.publish(0, transport.array_to_frame(filler))
        e.finish()
        cache.seal(e)
        time.sleep(0.01)                           # distinct LRU mtimes
    st = cache.stats()
    assert st["evictions"] >= 2
    assert st["disk_bytes"] <= 3000
    # the survivors are the most recently used
    surviving = {k for k, _, _ in cache._disk_entries()}
    assert "key-04" in surviving and "key-00" not in surviving


def test_cache_memory_is_bounded_and_disk_backed(tmp_path):
    """Sealing never grows the in-memory table past max_memory_entries;
    an evicted entry re-serves from disk (still a hit, same bytes)."""
    cache = ResultCache(cache_dir=str(tmp_path / "mem"),
                        max_memory_entries=2)
    frames = {}
    for i in range(5):
        e, status = cache.get_or_begin(f"key-{i:02d}", 1)
        assert status == "miss"
        frame = transport.array_to_frame(
            np.full((4, 4), i, dtype=np.float32))
        e.publish(0, frame)
        e.finish()
        cache.seal(e)
        frames[f"key-{i:02d}"] = frame
        assert cache.stats()["entries"] <= 2
    # the oldest key was evicted from memory but survives on disk
    e, status = cache.get_or_begin("key-00", 1)
    assert status == "hit"
    assert e.blocks[0] == frames["key-00"]


def test_cache_memory_only_mode_is_bounded():
    """Without a disk store an evicted finished entry becomes a miss —
    bounded memory beats an unbounded byte leak."""
    cache = ResultCache(max_memory_entries=1)
    for i in range(3):
        e, status = cache.get_or_begin(f"k{i}", 1)
        assert status == "miss"
        e.publish(0, b"frame")
        e.finish()
        cache.seal(e)
    assert cache.stats()["entries"] == 1
    _, status = cache.get_or_begin("k2", 1)     # the survivor (most recent)
    assert status == "hit"
    _, status = cache.get_or_begin("k0", 1)     # evicted: recompute
    assert status == "miss"


def test_cache_running_entries_never_memory_evicted():
    cache = ResultCache(max_memory_entries=1)
    running = [cache.get_or_begin(f"r{i}", 1)[0] for i in range(4)]
    done, _ = cache.get_or_begin("d", 1)
    done.finish()
    cache.seal(done)
    # all four RUNNING entries still attachable (dedup contract intact)
    for i in range(4):
        e, status = cache.get_or_begin(f"r{i}", 1)
        assert status == "attach" and e is running[i]


def test_gateway_record_table_is_bounded(chain):
    with SamplingService(workers=1) as svc, \
            Gateway(svc, max_records=2) as gw:
        c = Client(gw)
        for seed in range(4):
            code, _, sub = c.submit(chain, 8, seed=seed)
            assert code == 201
            c.stream_samples(sub["id"])         # drain → terminal record
        assert len(gw._records) <= 2
        # the latest job's record survived the purges
        code, _, st = c.request("GET", f"/v1/jobs/{sub['id']}")
        assert code == 200 and st["state"] == "done"
        c.close()


def test_cache_key_separates_every_input():
    base = ("store", "cfg", 0, 64, 4)
    keys = {cache_key(*base),
            cache_key("store2", "cfg", 0, 64, 4),
            cache_key("store", "cfg2", 0, 64, 4),
            cache_key("store", "cfg", 1, 64, 4),
            cache_key("store", "cfg", 0, 128, 4),
            cache_key("store", "cfg", 0, 64, 2)}
    assert len(keys) == 6


def test_failed_entry_does_not_poison_the_key():
    cache = ResultCache()
    e, status = cache.get_or_begin("k", 1)
    assert status == "miss"
    e.finish(error="boom")
    cache.seal(e)
    with pytest.raises(RuntimeError, match="boom"):
        list(e.stream())
    e2, status = cache.get_or_begin("k", 1)
    assert status == "miss" and e2 is not e


# ---------------------------------------------------------------------------
# THE acceptance test
# ---------------------------------------------------------------------------

def test_acceptance_two_tenants_one_execution(chain, tmp_path):
    table = TenantTable([
        Tenant(name="alice", api_key="alice-key", priority=5),
        Tenant(name="bob", api_key="bob-key", priority=5),
        Tenant(name="carol", api_key="carol-key", max_active_jobs=1)])
    registry = MetricsRegistry()
    cache = ResultCache(cache_dir=str(tmp_path / "cache"))
    with SamplingService(workers=1) as svc, \
            Gateway(svc, tenants=table, cache=cache,
                    registry=registry) as gw:
        instrument_service(svc, registry)
        release = threading.Event()
        svc.batch_hook = lambda job, b, w: release.wait(timeout=120)

        alice = Client(gw, api_key="alice-key")
        bob = Client(gw, api_key="bob-key")
        carol = Client(gw, api_key="carol-key")

        # two tenants, the same job: one miss, one attach — one execution
        code, _, a = alice.submit(chain, 16, seed=21, macro_batches=4)
        assert code == 201 and a["cache"] == "miss"
        code, _, b = bob.submit(chain, 16, seed=21, macro_batches=4)
        assert code == 201 and b["cache"] == "attach"

        # carol holds one executing job; her next is over quota → 429
        code, _, c1 = carol.submit(chain, 8, seed=99)
        assert code == 201
        code, headers, err = carol.submit(chain, 8, seed=100)
        assert code == 429 and int(headers["Retry-After"]) >= 1

        release.set()
        a_frames, a_term = alice.stream_frames(a["id"])
        b_frames, b_term = bob.stream_frames(b["id"])
        assert a_term["kind"] == "end" and b_term["kind"] == "end"
        assert a_frames == b_frames            # byte-for-byte shared stream

        # bit-identical to the in-process SamplingService run — at the
        # BYTES level, not just the decoded arrays
        assert a_frames == _inprocess_frames(chain, 16, jax.random.key(21), 4)
        assert svc.stats()["jobs"]["done"] >= 1
        assert cache.stats()["misses"] == 2
        assert cache.stats()["attaches"] == 1

        carol.stream_samples(c1["id"])
        # /metrics: nonzero queue / admission / cache counters
        conn = alice.conn
        conn.request("GET", "/metrics")
        resp = conn.getresponse()
        assert resp.status == 200
        assert resp.getheader("Content-Type").startswith("text/plain")
        text = resp.read().decode()

        def value(sample):
            for line in text.splitlines():
                if line.startswith(sample + " "):
                    return float(line.split()[-1])
            raise AssertionError(f"{sample} not exposed:\n{text}")

        # 2 service submissions: alice's miss + carol's job — bob's attach
        # deliberately never reaches the service
        assert value("fastmps_jobs_submitted_total") == 2
        assert value('fastmps_queue_events_total{event="claim"}') >= 5
        assert value('fastmps_queue_events_total{event="complete"}') >= 5
        assert value('fastmps_cache_events_total{event="miss"}') >= 2
        assert value('fastmps_cache_events_total{event="attach"}') >= 1
        assert value('fastmps_tenant_rejections_total') == 1
        assert value('fastmps_http_requests_total{route="submit",'
                     'code="429"}') == 1
        assert value("fastmps_admission_queued_jobs") >= 0
        assert value("fastmps_admission_backpressure") >= 0
        assert value("fastmps_batches_total") >= 5
        for c in (alice, bob, carol):
            c.close()


# ---------------------------------------------------------------------------
# telemetry under chaos (fleet lanes — worker processes, slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_metrics_expose_transport_faults_after_chaos(chain):
    """A chaos-injected fleet run (dropped result → lane fault → requeue)
    surfaces in the Prometheus scrape: transport fault counters nonzero,
    result still served."""
    registry = MetricsRegistry()
    with SamplingService(workers=2, pool=True, straggler_k=None) as svc, \
            Gateway(svc, registry=registry) as gw:
        instrument_service(svc, registry)
        svc._pool.injectors.append(DropResult(batch_ids={2}))
        c = Client(gw)
        code, _, sub = c.submit(chain, 96, seed=7, macro_batches=4)
        assert code == 201
        samples = c.stream_samples(sub["id"])
        ref = np.concatenate([transport.array_from_frame(f) for _, f in
                              _inprocess_frames(chain, 96,
                                                jax.random.key(7), 4)])
        assert np.array_equal(samples, ref)
        c.conn.request("GET", "/metrics")
        resp = c.conn.getresponse()
        text = resp.read().decode()
        assert 'fastmps_transport_lane_faults_total 1' in text \
            or 'fastmps_transport_lane_faults_total 2' in text
        assert 'fastmps_transport_events_total{event="fault"}' in text
        assert 'fastmps_transport_events_total{event="dispatch"}' in text
        assert "fastmps_transport_dispatch_bytes_total" in text
        c.close()
