"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step + one decode step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro import configs
from repro.launch import steps
from repro.models import transformer as T
from repro.optim import optimizers


def _extra_for(cfg, B, kind):
    extra = {}
    if cfg.family == "encdec":
        key = "enc_out" if kind == "decode" else "frames"
        extra[key] = jax.random.normal(
            jax.random.key(11), (B, cfg.enc_len, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(
            jax.random.key(12), (B, cfg.n_patches, cfg.d_model), cfg.dtype)
    return extra


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward(arch):
    cfg = configs.get_smoke_config(arch)
    params, _ = T.init_params(jax.random.key(0), cfg)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    logits, aux = T.forward(params, toks, cfg, _extra_for(cfg, B, "train"))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    params, _ = T.init_params(jax.random.key(0), cfg)
    opt = optimizers.adamw(1e-3)
    opt_state = opt.init(params)
    step = steps.make_train_step(cfg, opt)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1),
             **_extra_for(cfg, B, "train")}
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["gnorm"])) and float(metrics["gnorm"]) > 0
    # parameters actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_decode_step(arch):
    cfg = configs.get_smoke_config(arch)
    params, _ = T.init_params(jax.random.key(0), cfg)
    B, cache = 2, 32
    state = T.init_decode_state(cfg, B, cache)
    serve = steps.make_serve_step(cfg)
    toks = jax.random.randint(jax.random.key(1), (B, 1), 0, cfg.vocab)
    batch = {"tokens": toks, **_extra_for(cfg, B, "decode")}
    nxt, new_state = serve(params, batch, state)
    assert nxt.shape == (B, 1)
    assert int(new_state.position) == 1
    assert int(nxt.min()) >= 0 and int(nxt.max()) < cfg.vocab


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "mamba2-1.3b",
                                  "deepseek-v3-671b", "whisper-small",
                                  "zamba2-7b"])
def test_decode_matches_prefill(arch):
    """Greedy next-token from step-by-step decode == argmax of full forward
    at each position (representative archs, one per cache family)."""
    cfg = configs.get_smoke_config(arch)
    params, _ = T.init_params(jax.random.key(0), cfg)
    B, S = 2, 6
    toks = jax.random.randint(jax.random.key(5), (B, S), 0, cfg.vocab)
    extra_fwd = _extra_for(cfg, B, "train")
    logits_full, _ = T.forward(params, toks, cfg, extra_fwd)

    extra_dec = _extra_for(cfg, B, "decode")
    if cfg.family == "encdec":
        extra_dec["enc_out"] = T.encode(params, extra_fwd["frames"], cfg)
    state = T.init_decode_state(cfg, B, S + 1)
    outs = []
    for t in range(S):
        logits_t, state = T.decode_step(params, toks[:, t:t + 1], state, cfg,
                                        extra_dec)
        outs.append(logits_t[:, 0])
    dec = jnp.stack(outs, axis=1)               # (B, S, V)
    # bf16 numerics: compare argmax agreement rather than exact values
    agree = jnp.mean((jnp.argmax(dec, -1) == jnp.argmax(logits_full, -1))
                     .astype(jnp.float32))
    assert float(agree) > 0.9, float(agree)


def test_param_count_sane():
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        total, active = cfg.param_count()
        assert active <= total
        assert total > 1e8                      # full configs are real models
    # spot-check the published sizes (±40% — count conventions differ)
    qw = configs.get_config("qwen1.5-4b").param_count()[0]
    assert 2.5e9 < qw < 5.5e9
    ds = configs.get_config("deepseek-7b").param_count()[0]
    assert 5e9 < ds < 9e9
    dv3, dv3a = configs.get_config("deepseek-v3-671b").param_count()
    assert 4.5e11 < dv3 < 9e11
    assert 2e10 < dv3a < 6e10                  # ~37B active
    kimi, kimia = configs.get_config("kimi-k2-1t-a32b").param_count()
    assert 0.7e12 < kimi < 1.4e12
    assert 2e10 < kimia < 5e10                 # ~32B active


def test_cell_support_matrix():
    """long_500k only for sub-quadratic archs; every other cell defined."""
    n_cells = 0
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        for name, shape in configs.SHAPES.items():
            ok, why = configs.cell_supported(cfg, shape)
            n_cells += 1
            if name == "long_500k":
                assert ok == (arch in ("mamba2-1.3b", "zamba2-7b")), arch
            else:
                assert ok, (arch, name, why)
    assert n_cells == 40


def test_input_specs_all_cells():
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        for name, shape in configs.SHAPES.items():
            spec = configs.input_specs(cfg, shape)
            assert "tokens" in spec
            B = shape.global_batch
            if shape.kind == "decode":
                assert spec["tokens"].shape == (B, 1)
            else:
                assert spec["tokens"].shape == (B, shape.seq_len)
