"""Executable performance models (paper Eqs. 1, 2, 3, 4, 7)."""
import pytest

from repro.core import perfmodel as PM


W = PM.Workload(n_samples=10_000_000, n_sites=288, chi=10_000, d=4,
                macro_batch=20_000, micro_batch=5_000, bytes_per_elt=8)


def test_eq2_beats_eq1_with_equal_resources():
    """The paper's §3.1 claim: with p = M processes AND the macro batch
    sized to the overlap threshold (T_comp ≥ T_IO), data parallel beats the
    [19] site pipeline (no pipeline fill, no imbalance).  At too-small N₁
    the DP scheme is I/O-bound — exactly the paper's §2.2 failure mode —
    and eq1 can win; both regimes are asserted."""
    import dataclasses
    hw = PM.A100
    n1 = max(W.macro_batch, PM.min_macro_batch_for_overlap(W, hw))
    w_ok = dataclasses.replace(W, macro_batch=n1)
    t_dp = PM.eq2_data_parallel(w_ok, hw, p=W.n_sites)
    t_mp = PM.eq1_model_parallel(w_ok, hw)
    assert t_dp < t_mp
    # undersized N₁ → I/O leaks into the DP critical path (paper §3.1)
    w_small = dataclasses.replace(W, macro_batch=2_000)
    t_dp_small = PM.eq2_data_parallel(w_small, hw, p=W.n_sites)
    assert t_dp_small > t_dp * 0.99


def test_eq3_memory_accounting():
    mem = PM.eq3_memory(W)
    manual = (W.macro_batch * W.chi + W.chi * W.chi * W.d
              + W.micro_batch * W.chi * W.d) * W.bytes_per_elt
    assert mem == manual
    # χ=20 000, d=3 Γ alone ≈ 19.2 GB in fp64 16B complex (paper §3.2)
    w2 = PM.Workload(1, 1, 20_000, 3, bytes_per_elt=16)
    assert PM.eq3_memory(w2) > 19e9


def test_overlap_threshold_scales_with_hardware():
    """§3.1: N₁ must exceed the compute/IO break-even; faster chips need
    bigger macro batches."""
    n_gpu = PM.min_macro_batch_for_overlap(W, PM.A100)
    slow = PM.Hardware(peak_flops=2e12, hbm_bw=100e9, io_bw=5e9)
    n_cpu = PM.min_macro_batch_for_overlap(W, slow)
    assert n_cpu < n_gpu
    # paper: safe N₁ ~ 1e5-1e6 on A100-class hardware at χ=1e4
    assert 1e4 < n_gpu < 5e6


def test_eq4_single_vs_double_bandwidth_regimes():
    """Fast AllReduce, slow ReduceScatter (the paper's NVLink numbers) →
    double-site wins; symmetric bandwidths → single-site's d× smaller wire
    volume wins."""
    nv = PM.Hardware(allreduce_bw=401e9, reducescatter_bw=46e9,
                     peak_flops=156e12, hbm_bw=2039e9)
    assert PM.choose_tp_scheme(W, nv, p2=4) == "double"

    sym = PM.Hardware(allreduce_bw=50e9, reducescatter_bw=50e9)
    assert PM.choose_tp_scheme(W, sym, p2=4) == "single"


def test_eq7_overhead_monotone_in_p2():
    hw = PM.TPU_V5E
    o2 = PM.eq7_tp_overhead(W, hw, 2, "single")
    o8 = PM.eq7_tp_overhead(W, hw, 8, "single")
    assert o8 > o2                     # replicated measurement η=p₂ bites


def test_t_site_compute_linear_in_n():
    hw = PM.TPU_V5E
    assert PM.t_site_compute(W, hw, 2000) == pytest.approx(
        2 * PM.t_site_compute(W, hw, 1000), rel=1e-9)


def test_macro_batch_count():
    assert W.n_macro == 500
