"""Chaos helpers: fault injectors for the fleet transport and service.

The fault-tolerance story of the service rests on one paper property —
batch = f(seed, id), so any batch may be killed, delayed, duplicated, or
dropped and the recomputation is bit-identical.  This module makes those
faults *injectable* so tests exercise the claims instead of assuming them:

* transport injectors (plug into ``WorkerPool.injectors``): each sees
  every dispatch via ``before(worker, payload)`` and every result via
  ``after(worker, payload, result)`` and may return ``"drop"`` (raise a
  ``TransportError`` — lane fault, batch requeues) or ``"duplicate"``
  (deliver the payload twice; the pool asserts both results agree
  bit-for-bit);
* :class:`KillLane` (plug into ``SamplingService.batch_hook``): removes
  the lane that claims a chosen batch — mid-job worker loss, the queue
  requeues its claims;
* :func:`run_queue_script`: a deterministic interpreter for abstract
  op sequences against a ``WorkQueue`` that enforces the queue invariants
  after every op — the shared engine behind the seeded-random storm tests
  (``tests/test_fleet.py``) and the hypothesis property tests
  (``tests/test_property.py``).
"""
from __future__ import annotations

import time


def _payload_batch(payload: dict):
    job = payload.get("job") or {}
    return job.get("batch_id")


class _Matching:
    """Base: match payloads by batch id (None = every batch), fire at most
    ``times`` times (None = unlimited)."""

    def __init__(self, batch_ids=None, times=1):
        self.batch_ids = None if batch_ids is None else set(batch_ids)
        self.remaining = times
        self.fired: list = []       # (worker, batch_id) log

    def _take(self, worker, payload) -> bool:
        b = _payload_batch(payload)
        if self.batch_ids is not None and b not in self.batch_ids:
            return False
        if self.remaining is not None and self.remaining <= 0:
            return False
        if self.remaining is not None:
            self.remaining -= 1
        self.fired.append((worker, b))
        return True


class DelayBatch(_Matching):
    """Hold a matching dispatch for ``delay_s`` before it reaches the
    worker — the straggler: its claim goes stale while the lane sleeps, so
    an idle lane's EWMA-deadline reclaim fires and the late original's
    completion is ownership-rejected."""

    def __init__(self, batch_ids=None, delay_s=1.0, times=1):
        super().__init__(batch_ids, times)
        self.delay_s = delay_s

    def before(self, worker, payload):
        if self._take(worker, payload):
            time.sleep(self.delay_s)
        return None


class HoldUntil(_Matching):
    """Hold a matching dispatch until ``predicate()`` turns true (or
    ``max_wait_s`` passes) — the *deterministic* straggler: the test can
    pin the release to an observable event (e.g. "my batch was reclaimed")
    instead of guessing sleep durations."""

    def __init__(self, predicate, batch_ids=None, max_wait_s=60.0, times=1):
        super().__init__(batch_ids, times)
        self.predicate = predicate
        self.max_wait_s = max_wait_s

    def before(self, worker, payload):
        if self._take(worker, payload):
            t0 = time.monotonic()
            while (not self.predicate()
                   and time.monotonic() - t0 < self.max_wait_s):
                time.sleep(0.01)
        return None


class DuplicateDelivery(_Matching):
    """Deliver a matching payload twice (at-least-once transport).  The
    pool asserts the two results are bit-identical — the idempotence the
    whole design leans on."""

    def before(self, worker, payload):
        return "duplicate" if self._take(worker, payload) else None


class DropDispatch(_Matching):
    """Fail a matching dispatch before the worker sees it (lost request).
    Surfaces as a ``TransportError``: lane fault, batch requeues."""

    def before(self, worker, payload):
        return "drop" if self._take(worker, payload) else None


class DropResult(_Matching):
    """Discard a matching result after the worker computed it (lost
    response) — the worker did the work, the caller must still recompute,
    and the bits must come out the same."""

    def after(self, worker, payload, result):
        return "drop" if self._take(worker, payload) else None


class KillLane:
    """``SamplingService.batch_hook``: remove the lane that claims batch
    ``on_batch`` (fires once).  ``remove_worker`` requeues the victim's
    claims and, in fleet mode, hard-kills its worker process — the full
    mid-job node-loss scenario."""

    def __init__(self, service, on_batch: int, job_id=None):
        self.service = service
        self.on_batch = on_batch
        self.job_id = job_id
        self.victim = None          # lane name once fired

    def __call__(self, job, b, worker):
        if self.victim is not None or b != self.on_batch:
            return
        if self.job_id is not None and job.job_id != self.job_id:
            return
        self.victim = worker
        self.service.remove_worker(worker)


class HookChain:
    """Compose several batch_hook callables (service takes exactly one)."""

    def __init__(self, *hooks):
        self.hooks = list(hooks)

    def __call__(self, job, b, worker):
        for h in self.hooks:
            h(job, b, worker)


# ---------------------------------------------------------------------------
# WorkQueue op-script interpreter (shared by seeded and hypothesis tests)
# ---------------------------------------------------------------------------

class QueueInvariantError(AssertionError):
    pass


def run_queue_script(n_batches: int, ops) -> dict:
    """Interpret an abstract op sequence against a fresh ``WorkQueue``,
    enforcing the queue's invariants after every step, then drain to
    completion.  Deterministic: time is a virtual counter, so identical
    scripts replay identically.

    Ops (``w`` is a small int naming a worker):

    * ``("add", w)`` / ``("remove", w)`` — membership
    * ``("claim", w)`` — worker claims; the interpreter records ownership
    * ``("complete", w)`` — worker completes its oldest *believed* claim
      (which may have been requeued from under it — the interpreter then
      asserts the completion is REJECTED, never double-counted)
    * ``("reclaim", t)`` — ``reclaim_stale(timeout=t)`` at the current
      virtual time
    * ``("tick",)`` — advance the virtual clock

    Returns counters (counted completions per batch, rejections, …).
    Raises :class:`QueueInvariantError` on: a lost batch, a double-counted
    completion, or a requeue-FIFO fairness violation.
    """
    from repro.runtime.elastic import WorkQueue

    q = WorkQueue(n_batches)
    now = 0.0
    counted: dict[int, int] = {}     # batch -> completions that counted
    rejected = 0
    believed: dict[str, list[int]] = {}   # worker -> claims it thinks it owns

    def check(op):
        # 1. conservation: every batch is exactly one of {done, owned,
        #    unowned-pending}; nothing vanishes
        seen = 0
        for b, r in q.records.items():
            states = [r.done, r.owner is not None and not r.done,
                      r.owner is None and not r.done]
            if sum(states) != 1:
                raise QueueInvariantError(
                    f"after {op}: batch {b} in impossible state {r}")
            seen += 1
        if seen != n_batches:
            raise QueueInvariantError(
                f"after {op}: {seen} records, expected {n_batches}")
        # 2. no batch completed more than once
        for b, n in counted.items():
            if n > 1:
                raise QueueInvariantError(
                    f"after {op}: batch {b} completed {n} times")
        # 3. a done batch never sits in the re-offer FIFO as live work
        st = q.stats()
        if st["done"] + st["pending"] != n_batches:
            raise QueueInvariantError(f"after {op}: done+pending != total")

    def live_requeued():
        return [b for b in q._requeued
                if q.records[b].owner is None and not q.records[b].done]

    for op in ops:
        kind = op[0]
        if kind == "tick":
            now += 1.0
        elif kind == "add":
            q.add_worker(f"w{op[1]}")
        elif kind == "remove":
            q.remove_worker(f"w{op[1]}")
        elif kind == "claim":
            w = f"w{op[1]}"
            fifo = live_requeued()
            b = q.claim(w, now=now)
            if b is not None:
                # fairness: requeued work re-offers FIFO before fresh
                if fifo and b != fifo[0]:
                    raise QueueInvariantError(
                        f"after {op}: claimed {b}, but requeue FIFO head "
                        f"was {fifo[0]} ({fifo})")
                believed.setdefault(w, []).append(b)
        elif kind == "complete":
            w = f"w{op[1]}"
            claims = believed.get(w, [])
            if claims:
                b = claims.pop(0)
                owns = q.records[b].owner == w and not q.records[b].done
                ok = q.complete(b, worker=w)
                if ok != owns:
                    raise QueueInvariantError(
                        f"complete({b}, {w}) returned {ok} but ownership "
                        f"was {owns}")
                if ok:
                    counted[b] = counted.get(b, 0) + 1
                else:
                    rejected += 1
        elif kind == "reclaim":
            q.reclaim_stale(float(op[1]), now=now)
        else:
            raise ValueError(f"unknown op {op!r}")
        check(op)

    # drain: one fresh worker must be able to finish everything that isn't
    # done — if a batch were lost, this would hang; instead we bound it
    for _ in range(4 * n_batches + 8):
        if q.finished:
            break
        b = q.claim("drain", now=now)
        if b is None:
            # every pending batch is owned by someone who'll never return —
            # reclaim them (timeout 0 = everything) and keep going
            q.reclaim_stale(0.0, now=now + 1.0)
            now += 2.0
            continue
        if not q.complete(b, worker="drain"):
            raise QueueInvariantError(f"drain completion of {b} rejected")
        counted[b] = counted.get(b, 0) + 1
    if not q.finished:
        lost = [b for b, r in q.records.items() if not r.done]
        raise QueueInvariantError(f"batches lost (never completable): {lost}")
    for b in range(n_batches):
        if counted.get(b, 0) != 1:
            raise QueueInvariantError(
                f"batch {b} counted {counted.get(b, 0)} times, want exactly 1")
    check(("drain",))
    return {"counted": counted, "rejected": rejected, "stats": q.stats()}
