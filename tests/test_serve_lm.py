"""Continuous-batching serving driver (launch/serve.py)."""
import jax
import pytest

pytestmark = pytest.mark.slow
import numpy as np

from repro import configs
from repro.launch.serve_lm import serve
from repro.models import transformer as T


def test_continuous_batching_serves_all_requests():
    cfg = configs.get_smoke_config("granite-3-2b")
    params, _ = T.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [(int(t), int(l)) for t, l in
               zip(rng.integers(0, cfg.vocab, 10), rng.integers(2, 9, 10))]
    done = serve(cfg, params, prompts, batch=3, max_new=8, cache_len=16,
                 verbose=False)
    assert sorted(done) == list(range(10))            # every request served
    for rid, (tok, limit) in enumerate(prompts):
        assert 1 <= len(done[rid]) <= limit
        assert all(0 <= t < cfg.vocab for t in done[rid])


def test_slot_isolation():
    """A refilled slot must not see the previous request's cache: the same
    prompt must generate the same continuation regardless of slot history."""
    cfg = configs.get_smoke_config("granite-3-2b")
    params, _ = T.init_params(jax.random.key(0), cfg)
    # run the same prompt alone and after another request in the same slot
    alone = serve(cfg, params, [(7, 6)], batch=1, max_new=6, cache_len=16,
                  verbose=False)
    packed = serve(cfg, params, [(3, 2), (7, 6)], batch=1, max_new=6,
                   cache_len=16, verbose=False)
    assert alone[0] == packed[1]
