"""Displacement operator via Zassenhaus split (paper §3.4.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import displacement as D


def _random_mu(key, n, scale=0.5):
    kr, ki = jax.random.split(key)
    return (scale * jax.random.normal(kr, (n,))
            + 1j * scale * jax.random.normal(ki, (n,))).astype(jnp.complex128)


def test_triangular_factors_closed_form():
    mu = _random_mu(jax.random.key(0), 4)
    d = 8
    lower = D.exp_mu_adag(mu, d)
    # must match scaling-and-squaring of μ·a†
    _, adag = D.ladder_ops(d)
    ref = jax.vmap(jax.scipy.linalg.expm)(mu[:, None, None] * adag[None])
    np.testing.assert_allclose(np.asarray(lower), np.asarray(ref),
                               atol=1e-10)
    # triangularity
    up = np.triu(np.asarray(lower), k=1)
    assert np.abs(up).max() < 1e-12


def test_zassenhaus_vs_exact_low_fock():
    """Paper validation: relative error < 0.2 % on the elements we care
    about (low Fock indices; GBS uses small |μ| and d=3..4 cutoffs)."""
    d = 10
    mu = _random_mu(jax.random.key(1), 64, scale=0.3)
    approx = D.displacement_zassenhaus(mu, d)
    exact = D.displacement_exact(mu, d)
    a = np.asarray(approx)[:, :4, :4]
    e = np.asarray(exact)[:, :4, :4]
    denom = np.maximum(np.abs(e), 1e-6)
    rel = np.abs(a - e) / denom
    assert rel.max() < 2e-3, rel.max()


def test_displacement_preserves_vacuum_norm():
    """⟨0|D†D|0⟩ = 1 in the untruncated space; small truncation loss only."""
    d = 12
    mu = _random_mu(jax.random.key(2), 16, scale=0.4)
    mats = D.displacement_zassenhaus(mu, d)
    col0 = np.asarray(mats)[:, :, 0]            # D|0> coherent state
    norms = np.sum(np.abs(col0) ** 2, axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-3)


def test_displace_env_batched():
    """Truncation error lives in the top Fock corner (paper §3.4.1); on
    low-Fock content — the GBS regime — Zassenhaus matches exact expm."""
    env = jax.random.uniform(jax.random.key(3), (8, 5, 6), dtype=jnp.float64)
    env = env.at[:, :, 4:].set(0.0)            # populate low Fock levels only
    mu = _random_mu(jax.random.key(4), 8, scale=0.2)
    out = D.displace_env(env, mu, 6)
    assert out.shape == (8, 5, 6)
    ref = D.displace_env(env, mu, 6, method="exact")
    np.testing.assert_allclose(np.asarray(out)[:, :, :4],
                               np.asarray(ref)[:, :, :4], atol=2e-3)


def test_zassenhaus_error_grows_toward_truncation_corner():
    """Quantifies the paper's claim: max error at (d−1, d−1), negligible at
    the low-Fock block."""
    d = 6
    mu = _random_mu(jax.random.key(6), 16, scale=0.2)
    diff = np.abs(np.asarray(D.displacement_zassenhaus(mu, d)
                             - D.displacement_exact(mu, d))).max(axis=0)
    assert diff[:3, :3].max() < 1e-4
    assert diff[d - 1, d - 1] == diff.max()


def test_speedup_structure():
    """The Zassenhaus path is two elementwise-generated triangulars + one
    batched GEMM — verify it produces finite values for a large batch fast
    (structure test, not a wall-clock benchmark)."""
    mu = _random_mu(jax.random.key(5), 4096, scale=0.3)
    out = D.displacement_zassenhaus(mu, 4)
    assert out.shape == (4096, 4, 4)
    assert bool(jnp.all(jnp.isfinite(jnp.abs(out))))
