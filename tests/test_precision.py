"""Adaptive mixed precision (paper §3.3, Figs. 5/6).

The central claim: a *global* auto-scale cannot contain the inter-sample
dynamic-range expansion, so long chains underflow in low precision; the
*per-sample* scale keeps every sample's range bounded and low-precision
sampling stays healthy to thousands of sites.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mps as M
from repro.core import precision
from repro.core import sampler as S


def test_rescale_modes():
    env = jnp.array([[1e-8, 1e-6], [1e2, 1e4]])
    out, lg = precision.rescale(env, "per_sample")
    assert np.allclose(np.asarray(jnp.max(jnp.abs(out), axis=1)), 1.0)
    assert np.allclose(np.asarray(lg), [-6.0, 4.0])

    out_g, lg_g = precision.rescale(env, "global")
    assert float(jnp.max(jnp.abs(out_g))) == 1.0
    # global scaling leaves the small sample tiny — the Fig. 5 failure mode
    assert float(jnp.max(jnp.abs(out_g[0]))) < 1e-9

    out_n, lg_n = precision.rescale(env, "none")
    assert jnp.all(out_n == env) and jnp.all(lg_n == 0)


def test_rescale_zero_row_safe():
    env = jnp.zeros((3, 4))
    out, lg = precision.rescale(env, "per_sample")
    assert bool(jnp.all(jnp.isfinite(out))) and bool(jnp.all(lg == 0))


def test_measurement_invariant_under_per_sample_scale():
    """Alg. 1 linearity: scaling a sample's env rescales its probs by the
    same factor, which normalisation cancels — the paper's key insight."""
    key = jax.random.key(0)
    temp = jax.random.uniform(key, (8, 6, 3), dtype=jnp.float64)
    lam = jax.random.uniform(jax.random.key(1), (6,), dtype=jnp.float64)
    probs = jnp.einsum("nrs,r->ns", temp, lam)
    norm = probs / probs.sum(axis=1, keepdims=True)

    scale = 10.0 ** jax.random.uniform(jax.random.key(2), (8, 1, 1),
                                       minval=-30, maxval=30)
    probs_s = jnp.einsum("nrs,r->ns", temp * scale, lam)
    norm_s = probs_s / probs_s.sum(axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(norm), np.asarray(norm_s), rtol=1e-9)


def _long_decaying_chain(m=60, chi=4, d=3):
    """Chain whose env magnitude decays fast with high per-sample variance
    (Eq. 5 with random per-site k) — the Fig. 5/6 regime, scaled to f32."""
    mps = M.random_linear_mps(jax.random.key(3), m, chi, d, decay=1.2,
                              dtype=jnp.float64)
    return mps.astype(jnp.float32)


@pytest.mark.slow
def test_underflow_without_scaling_fig6():
    """No scaling → env hits exact 0 mid-chain (float32), draws degenerate."""
    mps = _long_decaying_chain()
    state = S.init_state(mps, 64, jax.random.key(0),
                         S.SamplerConfig(scaling="none"))
    res = S.sample_chain(mps, state, S.SamplerConfig(scaling="none"))
    max_env = np.asarray(res.site_stats[:, 0])
    assert max_env[-1] == 0.0, "expected Fig. 6 underflow without scaling"


def test_per_sample_scaling_survives_fig6():
    mps = _long_decaying_chain()
    cfg = S.SamplerConfig(scaling="per_sample")
    state = S.init_state(mps, 64, jax.random.key(0), cfg)
    res = S.sample_chain(mps, state, cfg)
    max_env = np.asarray(res.site_stats[:, 0])
    assert max_env[-1] > 1e-3, "per-sample scaling must keep env alive"
    # the accumulated log-scale diagnostic recovers absolute magnitudes
    assert bool(jnp.all(jnp.isfinite(res.state.log_scale)))
    assert float(jnp.max(res.state.log_scale)) < 0.0   # decaying chain


@pytest.mark.slow
def test_per_sample_beats_global_range():
    """After per-sample rescale every sample is pinned to max 1; global
    scaling leaves an inter-sample spread that *grows with the chain length*
    (Fig. 5 a→d) — the range-expansion a single scalar cannot contain."""
    def final_range(mode, m):
        mps = _long_decaying_chain(m=m)
        cfg = S.SamplerConfig(scaling=mode)
        state = S.init_state(mps, 32, jax.random.key(1), cfg)
        res = S.sample_chain(mps, state, cfg)
        stats = precision.sample_range_stats(res.state.env)
        return np.asarray(stats["sample_max"])

    ps = final_range("per_sample", 120)
    assert ps.min() == pytest.approx(1.0)      # every sample pinned to 1

    gl_short = final_range("global", 30)
    gl_long = final_range("global", 120)
    assert gl_long.min() < 0.05                 # ≥ 20× inter-sample spread
    assert gl_long.min() < gl_short.min()       # ...and it widens with sites


def test_policy_table():
    for name in ("fp64", "fp32", "mxu_bf16", "store_bf16"):
        st, inp, acc = precision.policy_dtypes(name)
        assert jnp.dtype(acc).itemsize >= jnp.dtype(inp).itemsize or name == "fp64"
    with pytest.raises(ValueError):
        precision.policy_dtypes("tf32")        # not a TPU tier


def test_policy_gemm_accumulates_fp32():
    a = jnp.ones((4, 8), jnp.float32)
    b = jnp.ones((8, 4), jnp.float32)
    out = precision.gemm(a, b, "mxu_bf16")
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), 8.0)
