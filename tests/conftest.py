"""Shared test config.

x64 is enabled because the MPS oracles compare in float64 (the paper's
reference precision).  Device count is NOT forced here — smoke tests and
benches must see the real single CPU device; multi-device behaviour is
tested via subprocesses (tests/test_parallel.py) and the dry-run sets its
own XLA_FLAGS.
"""
import jax
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
