"""Shared test config.

x64 is enabled because the MPS oracles compare in float64 (the paper's
reference precision).  Device count is NOT forced here — smoke tests and
benches must see the real single CPU device; multi-device behaviour is
tested via subprocesses (tests/test_parallel.py) and the dry-run sets its
own XLA_FLAGS.

Session-scoped MPS fixtures: building a random MPS is cheap, but sharing
one set of *shapes* across tests keeps the jit cache warm — prefer these
over per-test ``random_*_mps`` calls when the test doesn't need a bespoke
shape.  The fast tier-1 path skips the heavyweight system/model tests:

    PYTHONPATH=src python -m pytest -x -q -m "not slow"
"""
import jax
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import mps as M  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


@pytest.fixture(scope="session")
def linear_mps_small():
    """(M, χ, d) = (6, 4, 3) linear-semantics chain — the default oracle."""
    return M.random_linear_mps(jax.random.key(0), 6, 4, 3)


@pytest.fixture(scope="session")
def linear_mps_10x6():
    """(10, 6, 3) chain, big enough for multi-segment streaming walks."""
    return M.random_linear_mps(jax.random.key(0), 10, 6, 3)


@pytest.fixture(scope="session")
def born_mps_6x4():
    """(6, 4, 2) complex Born-semantics chain."""
    return M.random_born_mps(jax.random.key(2), 6, 4, 2)
