"""The dry-run profiler: loop-corrected HLO cost analysis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hloanalysis as H


def _compiled(fn, *sds):
    return jax.jit(fn).lower(*sds).compile()


def test_plain_dot_flops():
    c = _compiled(lambda a, b: a @ b,
                  jax.ShapeDtypeStruct((64, 128), jnp.float32),
                  jax.ShapeDtypeStruct((128, 32), jnp.float32))
    cost = H.analyze(c.as_text())
    assert cost.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_trip_count_correction():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        return jax.lax.scan(body, x, ws)[0]
    c = _compiled(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                  jax.ShapeDtypeStruct((17, 128, 128), jnp.float32))
    cost = H.analyze(c.as_text())
    assert cost.flops == pytest.approx(17 * 2 * 128 ** 3, rel=0.01)


def test_nested_scan_multiplies():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return ci @ w, None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, ws)[0]
    c = _compiled(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((5, 64, 64), jnp.float32))
    cost = H.analyze(c.as_text())
    assert cost.flops == pytest.approx(15 * 2 * 64 ** 3, rel=0.01)


def test_collective_wire_bytes():
    import os
    import subprocess
    import sys
    import textwrap
    child = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import hloanalysis as H
        mesh = jax.make_mesh((4,), ("x",))
        def f(v):
            return jax.lax.psum(v, "x")
        from repro.compat import shard_map
        g = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                      check_vma=False)
        c = jax.jit(g).lower(jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
        cost = H.analyze(c.as_text())
        # ring all-reduce of 4 KiB over 4 ranks: 2*4096*(3/4) = 6144 B
        assert abs(cost.collective_wire_bytes - 6144) < 1, cost.collective_wire_bytes
        assert cost.n_collectives.get("all-reduce") == 1
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", child], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0 and "OK" in proc.stdout, proc.stderr[-2000:]


def test_parse_tuple_types_with_index_comments():
    text = """
ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %t = (f32[8,8]{1,0}, s32[], /*index=2*/f32[4]{0}) tuple(%a, %a, %a)
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%t), index=0
}
"""
    comps = H.parse_hlo(text)
    assert "main" in comps
    ops = [i.opcode for i in comps["main"].instrs]
    assert ops == ["parameter", "tuple", "get-tuple-element"]


def test_roofline_terms():
    cost = H.HLOCost(flops=197e12, memory_bytes=819e9,
                     collective_wire_bytes=50e9, collective_raw_bytes=0,
                     per_collective={}, n_collectives={})
    rf = H.roofline(cost, n_chips=4, model_flops=4 * 197e12)
    assert rf.t_compute == pytest.approx(1.0)
    assert rf.t_memory == pytest.approx(1.0)
    assert rf.t_collective == pytest.approx(1.0)
    assert rf.useful_ratio == pytest.approx(1.0)


def test_complex_dot_flop_multiplier():
    c = _compiled(lambda a, b: a @ b,
                  jax.ShapeDtypeStruct((32, 32), jnp.complex64),
                  jax.ShapeDtypeStruct((32, 32), jnp.complex64))
    cost = H.analyze(c.as_text())
    if cost.flops:                       # CPU may lower c64 dot to custom-call
        assert cost.flops >= 4 * 2 * 32 ** 3 * 0.9
