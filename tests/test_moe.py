"""MoE layer unit tests: routing math, capacity semantics, EP invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.models import moe as MOE
from repro.models.common import mlp_apply


def _cfg(**kw):
    base = dict(d_model=16, d_ff=32, n_experts=4, top_k=2,
                capacity_factor=8.0)
    base.update(kw)
    return MOE.MoEConfig(**base)


def test_single_expert_equals_dense_mlp():
    """E = top_k = 1 with ample capacity: MoE must equal the expert MLP."""
    cfg = _cfg(n_experts=1, top_k=1)
    params, _ = MOE.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, 16), jnp.float32)
    y, aux = MOE.moe_apply(params, x, cfg)

    w = {"gate": params["gate"][0], "up": params["up"][0],
         "down": params["down"][0]}
    ref = mlp_apply(w, x.reshape(16, 16)).reshape(2, 8, 16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)
    assert float(aux["drop_frac"]) == 0.0


def test_no_drops_with_ample_capacity():
    cfg = _cfg(capacity_factor=8.0)
    params, _ = MOE.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(2), (3, 16, 16), jnp.float32)
    _, aux = MOE.moe_apply(params, x, cfg)
    assert float(aux["drop_frac"]) == 0.0


def test_capacity_drops_counted():
    """cf small enough that overflow must occur: drop_frac > 0 and the
    output stays finite (dropped tokens just lose that expert's term)."""
    cfg = _cfg(capacity_factor=0.05)
    params, _ = MOE.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (2, 64, 16), jnp.float32)
    y, aux = MOE.moe_apply(params, x, cfg)
    assert float(aux["drop_frac"]) > 0.0
    assert bool(jnp.all(jnp.isfinite(y)))


def test_capacity_rounding():
    dsv3 = MOE.MoEConfig(d_model=1, d_ff=1, n_experts=256, top_k=8,
                         capacity_factor=1.25)
    assert MOE._capacity(1, dsv3) == 1            # decode: never 8× padded
    assert MOE._capacity(4096, dsv3) % 8 == 0     # train: MXU-aligned


def test_gate_weights_normalized_and_applied():
    """Scaling one expert's down-projection scales only its routed share."""
    cfg = _cfg(n_experts=2, top_k=2)              # every token uses both
    params, _ = MOE.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(4), (1, 4, 16), jnp.float32)
    y1, _ = MOE.moe_apply(params, x, cfg)
    params2 = dict(params)
    params2["down"] = params["down"].at[0].multiply(2.0)
    y2, _ = MOE.moe_apply(params2, x, cfg)
    assert not np.allclose(np.asarray(y1), np.asarray(y2))


def test_shared_expert_added():
    cfg = _cfg(n_shared=1)
    params, _ = MOE.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(5), (1, 4, 16), jnp.float32)
    y, _ = MOE.moe_apply(params, x, cfg)
    sp = params["shared"]
    shared_out = mlp_apply(sp, x.reshape(4, 16)).reshape(1, 4, 16)
    # zero all routed experts → only the shared path remains
    params0 = dict(params)
    for k in ("gate", "up", "down"):
        params0[k] = jnp.zeros_like(params[k])
    y0, _ = MOE.moe_apply(params0, x, cfg)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(shared_out),
                               rtol=1e-5, atol=1e-5)


def test_lb_loss_range():
    cfg = _cfg()
    params, _ = MOE.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(6), (2, 32, 16), jnp.float32)
    _, aux = MOE.moe_apply(params, x, cfg)
    # Switch-style lb loss is ≥ top_k·(uniform lower bound) and finite
    assert 0.0 < float(aux["lb_loss"]) < 4 * cfg.n_experts
