"""Fleet-scale service tests: one job table, many processes, proven under
chaos.

The headline assertion (ISSUE acceptance): a multi-process fleet run —
persistent worker processes behind ``SamplingService(pool=True)`` — with
an injected mid-job lane kill AND a forced straggler reclaim returns
samples **bit-identical** to a single-lane ``runtime="local"`` run of the
same (source, config, key).  Everything else here triangulates the same
property from cheaper angles: thread-lane chaos, seeded WorkQueue storms,
straggler EWMA math, admission backpressure, and the raw frame protocol.

Worker processes pay a jax import each, so anything spawning them is
``slow`` (CI's fleet-smoke job runs them; tier-1 keeps the thread-lane
and control-plane tests).
"""
import io
import random
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from chaos import (DelayBatch, DropDispatch, DropResult, DuplicateDelivery,
                   HoldUntil, HookChain, KillLane, QueueInvariantError,
                   run_queue_script)
from repro import api
from repro.api.service import SamplingService, batch_key
from repro.data.gamma_store import GammaStore
from repro.runtime import transport
from repro.runtime.elastic import WorkQueue
from repro.runtime.stragglers import StragglerMitigator


@pytest.fixture(scope="module")
def chain(tmp_path_factory, linear_mps_10x6):
    root = str(tmp_path_factory.mktemp("fleet_gamma"))
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(linear_mps_10x6)
    return root


def _baseline(root, n_samples, key, macro_batches):
    """The single-lane runtime="local" reference the fleet must match."""
    with SamplingService(workers=1) as svc:
        h = svc.submit(root, n_samples=n_samples, key=key,
                       macro_batches=macro_batches)
        return h.result(timeout=300)


# ---------------------------------------------------------------------------
# frame protocol (no processes)
# ---------------------------------------------------------------------------

def test_frame_roundtrip():
    buf = io.BytesIO()
    transport.write_json(buf, {"kind": "batch", "payload": {"x": 1}})
    transport.write_frame(buf, transport.array_to_frame(
        np.arange(12, dtype=np.float64).reshape(3, 4)))
    buf.seek(0)
    assert transport.read_json(buf) == {"kind": "batch", "payload": {"x": 1}}
    out = transport.array_from_frame(transport.read_frame(buf))
    np.testing.assert_array_equal(out, np.arange(12.0).reshape(3, 4))


def test_frame_eof_raises_worker_died():
    buf = io.BytesIO(b"\x00\x00\x00")          # truncated length prefix
    with pytest.raises(transport.WorkerDied):
        transport.read_frame(buf)
    half = io.BytesIO()
    transport.write_frame(half, b"full frame")
    truncated = io.BytesIO(half.getvalue()[:-4])
    with pytest.raises(transport.WorkerDied):
        transport.read_frame(truncated)


def test_transport_error_is_not_a_job_error():
    # the service routes RuntimeError to job-failure and TransportError to
    # requeue-and-respawn; the subclass order must keep those separable
    assert issubclass(transport.TransportError, RuntimeError)
    assert issubclass(transport.WorkerDied, transport.TransportError)


# ---------------------------------------------------------------------------
# WorkQueue regressions: double-complete, steal, ownership
# ---------------------------------------------------------------------------

def test_double_complete_rejected():
    q = WorkQueue(2)
    assert q.claim("a", now=0.0) == 0
    assert q.complete(0, worker="a") is True
    assert q.complete(0, worker="a") is False      # duplicate delivery
    assert q.complete(0) is False                  # even ownerless
    assert q.stats()["done"] == 1


def test_steal_reassigns_and_leaves_fifo_clean():
    q = WorkQueue(3)
    assert q.claim("a", now=0.0) == 0
    assert q.claim("b", now=0.0) == 1
    assert q.reclaim_stale(5.0, now=10.0) == [0, 1]
    assert q.steal(0, "c", now=10.0) is True
    assert q.records[0].owner == "c"
    # 0 left the re-offer FIFO with the steal; a fresh claim gets 1 then 2
    assert q.claim("d", now=10.0) == 1
    assert q.claim("d", now=10.0) == 2
    # stealing an owned or done batch refuses
    assert q.steal(1, "e") is False
    q.complete(2, worker="d")
    assert q.steal(2, "e") is False


def test_late_completion_after_reclaim_rejected():
    q = WorkQueue(1)
    q.claim("slow", now=0.0)
    q.reclaim_stale(1.0, now=100.0)
    assert q.steal(0, "fast", now=100.0)
    assert q.complete(0, worker="slow") is False   # the late original
    assert q.complete(0, worker="fast") is True
    assert q.stats()["done"] == 1


# ---------------------------------------------------------------------------
# StragglerMitigator regressions: EWMA deadline math + steal integration
# ---------------------------------------------------------------------------

def test_ewma_deadline_math():
    m = StragglerMitigator(WorkQueue(1), k=2.0, ewma_alpha=0.5)
    assert m.deadline is None and m.stats()["ewma_s"] is None
    m.observe_completion(4.0)
    assert m.deadline == pytest.approx(8.0)        # first sample seeds EWMA
    m.observe_completion(2.0)
    assert m._ewma == pytest.approx(3.0)           # 0.5·2 + 0.5·4
    assert m.deadline == pytest.approx(6.0)
    assert m.stats() == {"ewma_s": pytest.approx(3.0),
                         "deadline_s": pytest.approx(6.0), "duplicates": 0}


def test_maybe_steal_respects_deadline():
    q = WorkQueue(2)
    m = StragglerMitigator(q, k=2.0, ewma_alpha=0.5)
    q.claim("slow", now=0.0)
    assert m.maybe_steal("idle", now=100.0) is None   # no EWMA yet
    m.observe_completion(1.0)                          # deadline = 2.0
    assert m.maybe_steal("idle", now=1.5) is None      # not late yet
    assert m.maybe_steal("idle", now=3.0) == 0         # 3.0 > 2.0: reclaim
    assert q.records[0].owner == "idle"
    assert m.duplicates == 1
    assert q.complete(0, worker="slow") is False       # late original
    assert q.complete(0, worker="idle") is True


# ---------------------------------------------------------------------------
# seeded WorkQueue storms (the no-hypothesis interleaving matrix)
# ---------------------------------------------------------------------------

def _random_ops(rng: random.Random, n_ops: int):
    kinds = ["add", "remove", "claim", "claim", "claim", "complete",
             "complete", "reclaim", "tick"]
    ops = []
    for _ in range(n_ops):
        k = rng.choice(kinds)
        if k == "tick":
            ops.append(("tick",))
        elif k == "reclaim":
            ops.append(("reclaim", rng.randint(0, 3)))
        else:
            ops.append((k, rng.randint(0, 3)))
    return ops


@pytest.mark.parametrize("seed", range(25))
def test_queue_storm_never_loses_or_double_counts(seed):
    rng = random.Random(seed)
    n_batches = rng.randint(1, 12)
    out = run_queue_script(n_batches, _random_ops(rng, 120))
    assert all(v == 1 for v in out["counted"].values())
    assert len(out["counted"]) == n_batches


def test_queue_script_catches_a_planted_violation():
    # the checker itself must not be vacuous: a queue that claims success
    # without recording completion (lost/duplicated work) trips it
    orig = WorkQueue.complete
    try:
        WorkQueue.complete = lambda self, b, worker=None: True
        with pytest.raises(QueueInvariantError):
            run_queue_script(2, [("add", 0), ("claim", 0), ("complete", 0)])
    finally:
        WorkQueue.complete = orig


# ---------------------------------------------------------------------------
# thread-lane chaos (fast: no worker processes)
# ---------------------------------------------------------------------------

def test_straggler_reclaim_thread_lanes(chain):
    """End-to-end straggler path on thread lanes: the lane holding the
    last batch stalls until an idle lane's EWMA-deadline reclaim steals
    it; the late original's completion is ownership-rejected; the result
    is bit-identical to the single-lane baseline."""
    key = jax.random.key(11)
    ref = _baseline(chain, 96, key, 6)
    stalled = {}

    with SamplingService(workers=2, straggler_k=0.2,
                         steal_poll_s=0.01) as svc:
        def stall_last(job, b, worker):
            if b != 5 or stalled:
                return
            stalled["lane"] = worker
            t0 = time.monotonic()
            # release exactly when the reclaim lands (deterministic), with
            # a generous escape hatch so a broken steal fails the asserts,
            # not the suite's clock
            while (job.queue.records[b].owner == worker
                   and time.monotonic() - t0 < 60.0):
                time.sleep(0.01)
        svc.batch_hook = stall_last
        h = svc.submit(chain, n_samples=96, key=key, macro_batches=6)
        out = h.result(timeout=300)
        assert np.array_equal(out, ref)
        assert h.progress["duplicates"] >= 1
        st = svc.stats()
        assert st["stragglers"]["duplicates"] >= 1
        assert st["stragglers"]["steals"] >= 1
    assert stalled, "the stall hook never saw batch 5"
    # the stalled lane's late execution (if it ran) was discarded by the
    # ownership check — either way, every batch counted exactly once
    assert h.progress["done"] == 6


def test_kill_lane_thread_mode(chain):
    """Mid-job lane kill on thread lanes: the victim's claim requeues and
    the survivor finishes; bit-identity holds."""
    key = jax.random.key(13)
    ref = _baseline(chain, 64, key, 4)
    with SamplingService(workers=2, straggler_k=None) as svc:
        kill = KillLane(svc, on_batch=1)
        svc.batch_hook = kill
        h = svc.submit(chain, n_samples=64, key=key, macro_batches=4)
        out = h.result(timeout=300)
    assert kill.victim is not None
    assert np.array_equal(out, ref)
    assert h.progress["requeues"] >= 1


def test_admission_backpressure(chain):
    """A burst over the perfmodel budget queues in priority order with the
    backpressure visible in stats(); the queue drains as jobs finish."""
    key = jax.random.key(17)
    # probe the modeled footprint of one job without running anything
    with SamplingService(workers=0) as probe:
        mb = probe.submit(chain, n_samples=32, key=key).progress["model_bytes"]
    assert mb > 0

    gate = threading.Event()
    started = threading.Event()
    with SamplingService(workers=1,
                         max_active_bytes=1.5 * mb) as svc:
        def hold_first(job, b, worker):
            if job.job_id == 0:
                started.set()
                gate.wait(timeout=60.0)
        svc.batch_hook = hold_first
        h1 = svc.submit(chain, n_samples=32, key=key)
        assert started.wait(timeout=60.0)
        h2 = svc.submit(chain, n_samples=32, key=jax.random.key(18))
        time.sleep(0.05)
        st = svc.stats()
        assert st["admission"]["queued_jobs"] == 1
        assert st["admission"]["backpressure"] is True
        assert st["admission"]["active_model_bytes"] == pytest.approx(mb)
        assert st["queue_depth"] == 2
        gate.set()
        r1, r2 = h1.result(timeout=300), h2.result(timeout=300)
        st = svc.stats()
        assert st["admission"]["backpressure"] is False
        assert st["admission"]["queued_jobs"] == 0
    assert r1.shape == r2.shape == (32, 10)
    assert not np.array_equal(r1, r2)              # different keys


def test_admission_always_admits_one(chain):
    """A job bigger than the whole budget still runs — alone."""
    key = jax.random.key(19)
    ref = _baseline(chain, 32, key, 1)
    with SamplingService(workers=1, max_active_bytes=1.0) as svc:
        h = svc.submit(chain, n_samples=32, key=key)
        out = h.result(timeout=300)
    assert np.array_equal(out, ref)


def test_fleet_submit_validation(chain, tmp_path):
    """Fleet lanes reject job shapes they can't dispatch (local chain-walk
    state) — at submit time, on the caller's thread."""
    with SamplingService(workers=0, pool=True) as svc:
        with pytest.raises(ValueError, match="skip_batches"):
            svc.submit(chain, n_samples=8, key=jax.random.key(0),
                       checkpoint_root=str(tmp_path / "ck"))


def test_lane_batches_in_stats(chain):
    key = jax.random.key(23)
    with SamplingService(workers=1) as svc:
        h = svc.submit(chain, n_samples=64, key=key, macro_batches=4)
        h.result(timeout=300)
        lanes = svc.stats()["lane_batches"]
    assert sum(lanes.values()) == 4


# ---------------------------------------------------------------------------
# the fleet itself (worker processes — slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fleet_acceptance_kill_and_reclaim(chain):
    """THE acceptance run: ≥2 persistent worker processes, a mid-job lane
    kill AND a forced straggler reclaim, and the assembled samples are
    bit-identical to the single-lane runtime="local" baseline."""
    key = jax.random.key(42)
    n, k = 192, 8
    ref = _baseline(chain, n, key, k)

    with SamplingService(workers=3, pool=True, straggler_k=0.3,
                         steal_poll_s=0.02) as svc:
        kill = KillLane(svc, on_batch=1)
        svc.batch_hook = kill
        hold = HoldUntil(
            lambda: svc.stats()["stragglers"]["duplicates"] > 0,
            batch_ids={k - 1}, max_wait_s=120.0)
        svc._pool.injectors.append(hold)
        h = svc.submit(chain, n_samples=n, key=key, macro_batches=k)
        out = h.result(timeout=560)
        st = svc.stats()
        assert np.array_equal(out, ref), "fleet result diverged from baseline"
        assert kill.victim is not None, "lane kill never fired"
        assert h.progress["requeues"] >= 1              # the kill's claim
        assert st["stragglers"]["duplicates"] >= 1      # the forced reclaim
        assert st["transport"]["workers"] >= 2          # ≥2 live processes
        assert sum(st["lane_batches"].values()) >= k    # incl. duplicates? no:
        # lane_batches counts COUNTED completions only — exactly k
        assert sum(st["lane_batches"].values()) == k
    svc.close()
    assert svc.stats()["stragglers"]["rejected_results"] >= 0


@pytest.mark.slow
@pytest.mark.parametrize("fault", ["drop_dispatch", "drop_result",
                                   "duplicate"])
def test_fleet_chaos_matrix(chain, fault):
    """Each transport fault class, injected mid-job, leaves the result
    bit-identical to the baseline."""
    key = jax.random.key(5)
    n, k = 96, 4
    ref = _baseline(chain, n, key, k)
    inj = {"drop_dispatch": DropDispatch(batch_ids={2}),
           "drop_result": DropResult(batch_ids={2}),
           "duplicate": DuplicateDelivery(batch_ids={2})}[fault]
    with SamplingService(workers=2, pool=True, straggler_k=None) as svc:
        svc._pool.injectors.append(inj)
        h = svc.submit(chain, n_samples=n, key=key, macro_batches=k)
        out = h.result(timeout=560)
        st = svc.stats()
    assert np.array_equal(out, ref)
    assert inj.fired, f"{fault} injector never matched"
    if fault.startswith("drop"):
        # the fault surfaced as a lane fault and the batch was recomputed
        assert st["transport"]["lane_faults"] >= 1
        assert h.progress["requeues"] >= 1
    assert h.progress["done"] == k


@pytest.mark.slow
def test_fleet_worker_death_respawns(chain):
    """SIGKILL a worker process mid-run: its lane absorbs the fault,
    respawns a fresh process under the same lane name, and the job
    completes bit-identically."""
    key = jax.random.key(29)
    n, k = 96, 4
    ref = _baseline(chain, n, key, k)
    with SamplingService(workers=2, pool=True, straggler_k=None) as svc:
        fired = {}

        def murder(job, b, worker):
            if b == 2 and not fired:
                fired["lane"] = worker
                svc._pool.workers[worker]._proc.kill()
        svc.batch_hook = murder
        h = svc.submit(chain, n_samples=n, key=key, macro_batches=k)
        out = h.result(timeout=560)
        st = svc.stats()
    assert fired
    assert np.array_equal(out, ref)
    assert st["transport"]["lane_faults"] >= 1
    assert st["transport"]["spawned"] >= 3          # 2 lanes + ≥1 respawn


@pytest.mark.slow
def test_remote_runtime_persistent_worker_reuse(chain):
    """runtime="remote" now keeps ONE worker across submits (warm jit
    cache) instead of a subprocess per batch; both modes agree bitwise."""
    key = jax.random.key(31)
    cfg = api.SamplerConfig(backend="remote", runtime="remote")
    with api.SamplingSession(chain, cfg) as s:
        a = np.asarray(s.sample(16, key))
        pid1 = s.runtime._worker.pid
        b = np.asarray(s.sample(16, jax.random.key(32)))
        assert s.runtime._worker.pid == pid1        # same process, reused
        io_c = s.runtime.io_counters()
        assert io_c["persistent_worker"] is True
        assert io_c["dispatches"] == 2
    rt = api.RemoteRuntime(persistent=False)
    cfg2 = api.SamplerConfig(backend="remote", runtime=rt)
    with api.SamplingSession(chain, cfg2) as s:
        assert np.array_equal(np.asarray(s.sample(16, key)), a)
    assert not np.array_equal(a, b)
