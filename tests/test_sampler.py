"""Sampler correctness against the exact enumeration oracle (paper Fig.1 + Alg.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mps as M
from repro.core import sampler as S


def _tv_distance(samples: np.ndarray, probs: np.ndarray, d: int) -> float:
    n, m = samples.shape
    idx = np.ravel_multi_index(samples.T, (d,) * m)
    emp = np.bincount(idx, minlength=d ** m) / n
    return 0.5 * np.abs(emp - probs).sum()


@pytest.mark.parametrize("semantics,chi,m,d", [
    ("linear", 4, 5, 3),
    ("linear", 8, 4, 2),
    ("born", 4, 4, 2),
    ("born", 3, 3, 3),
])
def test_sampler_matches_enumeration(semantics, chi, m, d):
    key = jax.random.key(42)
    if semantics == "linear":
        mps = M.random_linear_mps(key, m, chi, d)
    else:
        mps = M.random_born_mps(key, m, chi, d)
    probs = M.enumerate_probabilities(mps)
    n = 40_000
    out = S.sample(mps, n, jax.random.key(1), S.SamplerConfig(semantics=semantics))
    tv = _tv_distance(np.asarray(out), probs, d)
    # TV of empirical vs truth concentrates ~ sqrt(K/N); bound loosely.
    assert tv < 4.0 * np.sqrt(d ** m / n), tv


def test_sampler_deterministic_per_seed(linear_mps_small):
    mps = linear_mps_small
    a = S.sample(mps, 100, jax.random.key(5))
    b = S.sample(mps, 100, jax.random.key(5))
    c = S.sample(mps, 100, jax.random.key(6))
    assert jnp.all(a == b)
    assert not jnp.all(a == c)


def test_micro_batching_equals_memory_model():
    """sample_batched must produce valid outcomes with the Eq.(3) layout."""
    mps = M.random_linear_mps(jax.random.key(2), 5, 4, 3)
    out = S.sample_batched(mps, 64, jax.random.key(3), micro_batch=16)
    assert out.shape == (64, 5)
    assert int(out.min()) >= 0 and int(out.max()) < 3


def test_draw_from_probs_inverse_cdf():
    probs = jnp.array([[0.5, 0.5, 0.0], [0.0, 0.0, 1.0], [1.0, 0.0, 0.0]])
    out = S.draw_from_probs(jnp.tile(probs, (100, 1)), jax.random.key(0))
    out = out.reshape(100, 3)
    assert jnp.all(out[:, 1] == 2)          # deterministic rows
    assert jnp.all(out[:, 2] == 0)
    assert jnp.all((out[:, 0] == 0) | (out[:, 0] == 1))


def test_draw_from_probs_underflow_guard():
    """Fully-underflowed rows (the Fig. 6 failure) fall back to uniform."""
    probs = jnp.zeros((512, 4))
    out = S.draw_from_probs(probs, jax.random.key(0))
    counts = np.bincount(np.asarray(out), minlength=4)
    assert counts.min() > 0                  # all outcomes occur


def test_mixed_precision_path_close_to_fp64():
    mps = M.random_linear_mps(jax.random.key(7), 6, 8, 3)
    cfg64 = S.SamplerConfig()
    cfg_mx = S.SamplerConfig(compute_dtype=jnp.bfloat16)
    # identical seeds: outcome sequences should agree for the vast majority
    # of draws (bf16 GEMM perturbs probabilities only slightly)
    a = S.sample(mps.astype(jnp.float32), 2000, jax.random.key(8), cfg64)
    b = S.sample(mps.astype(jnp.float32), 2000, jax.random.key(8), cfg_mx)
    agree = float(jnp.mean((a == b).astype(jnp.float32)))
    assert agree > 0.95, agree


def test_resume_mid_chain_exact(linear_mps_10x6):
    """Paper §4.1 seed-consistency: mid-chain restart reproduces the full run."""
    mps = linear_mps_10x6
    cfg = S.SamplerConfig()
    state0 = S.init_state(mps, 32, jax.random.key(1), cfg)
    full = S.sample_chain(mps, state0, cfg)

    head = M.MPS(mps.gammas[:3], mps.lambdas[:3], mps.semantics)
    part = S.sample_chain(head, state0, cfg)
    rest = S.sample_resumable(mps, part.state, 3, cfg)
    stitched = jnp.concatenate([part.samples, rest.samples], axis=0)
    assert jnp.all(stitched == full.samples)


def test_site_stats_shape(linear_mps_small):
    mps = linear_mps_small
    state = S.init_state(mps, 16, jax.random.key(1))
    res = S.sample_chain(mps, state)
    assert res.site_stats.shape == (6, 3)
    assert bool(jnp.all(jnp.isfinite(res.site_stats)))
