"""Optimizers, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import compression as C
from repro.optim import optimizers as O
from repro.optim import schedule


@pytest.mark.parametrize("make", [O.adamw, O.adafactor])
def test_optimizer_decreases_quadratic(make):
    opt = make(1e-1)
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3), "b": jnp.zeros((3, 4))}

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["b"] ** 2)

    state = opt.init(params)
    l0 = float(loss_fn(params))
    for _ in range(60):
        grads = jax.grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss_fn(params)) < 0.2 * l0


def test_adafactor_state_is_factored():
    opt = O.adafactor()
    params = {"w": jnp.zeros((64, 32)), "s": jnp.zeros((16,)),
              "stacked": jnp.zeros((4, 8, 12))}
    st = opt.init(params)
    assert st.inner["w"]["vr"].shape == (64,)
    assert st.inner["w"]["vc"].shape == (32,)
    assert st.inner["stacked"]["vr"].shape == (4, 8)
    assert st.inner["stacked"]["vc"].shape == (4, 12)
    assert st.inner["s"]["v"].shape == (16,)   # 1-D not factored


def test_optimizer_policy():
    from repro import configs
    small = configs.get_config("qwen1.5-4b")
    big = configs.get_config("deepseek-v3-671b")
    assert O.optimizer_for(small).name == "adamw"
    assert O.optimizer_for(big).name == "adafactor"


def test_schedule_warmup_cosine():
    fn = schedule.cosine_schedule(1e-3, warmup=10, total=100, min_frac=0.05)
    assert float(fn(0)) == pytest.approx(0.0, abs=1e-9)
    assert float(fn(10)) == pytest.approx(1e-3, rel=1e-2)
    assert float(fn(100)) == pytest.approx(0.05e-3, rel=1e-2)
    # monotone decay after warmup
    vals = [float(fn(s)) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_int8_compression_roundtrip_error():
    x = jax.random.normal(jax.random.key(0), (1000,), jnp.float32) * 3.0
    q, scale = C.int8_compress(x)
    y = C.int8_decompress(q, scale, x.shape, x.dtype)
    # per-block max-abs quantization: |err| <= scale/2 per element
    blocks = jnp.pad(x, (0, (-x.size) % C.BLOCK)).reshape(-1, C.BLOCK)
    bound = jnp.repeat(scale / 2, C.BLOCK)[: x.size] + 1e-7
    assert bool(jnp.all(jnp.abs(y - x) <= bound))


def test_int8_compression_zero_block():
    x = jnp.zeros((512,), jnp.float32)
    q, scale = C.int8_compress(x)
    y = C.int8_decompress(q, scale, x.shape, x.dtype)
    assert bool(jnp.all(y == 0))


def test_compressed_psum_single_axis():
    """On a 1-device mesh axis the compressed all-reduce must be ≈identity."""
    mesh = jax.make_mesh((1,), ("pod",))
    x = jax.random.normal(jax.random.key(1), (300,), jnp.float32)

    def f(v):
        return C.compressed_psum(v, "pod")

    from repro.compat import shard_map
    out = shard_map(f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
                    out_specs=jax.sharding.PartitionSpec(),
                    check_vma=False)(x)
    assert float(jnp.max(jnp.abs(out - x))) < 0.05 * float(jnp.max(jnp.abs(x)))
