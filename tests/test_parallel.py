"""Multi-level parallel schemes (paper §3.1–3.2).

Multi-device tests run in a subprocess with XLA_FLAGS forcing 8 host
devices (the main pytest process must keep the real single-device view).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from repro.core import mps as M, parallel as PP, sampler as S
    from repro.launch.mesh import make_host_mesh

    out = {}
    m = M.random_linear_mps(jax.random.key(0), n_sites=6, chi=8, d=3)
    mesh = make_host_mesh(model=4)           # 2 data x 4 model
    key = jax.random.key(7)
    cfg = S.SamplerConfig()
    dp = PP._multilevel_sample(mesh, m, 64, key, PP.ParallelConfig("dp"), cfg)
    ts = PP._multilevel_sample(mesh, m, 64, key, PP.ParallelConfig("tp_single"), cfg)
    td = PP._multilevel_sample(mesh, m, 64, key, PP.ParallelConfig("tp_double"), cfg)
    out["dp_eq_single"] = bool(jnp.all(dp == ts))
    out["dp_eq_double"] = bool(jnp.all(dp == td))
    out["shape_ok"] = list(dp.shape) == [64, 6]

    # born semantics through both TP schedules (psum-before-square correctness)
    mb = M.random_born_mps(jax.random.key(2), 4, 8, 2)
    cb = S.SamplerConfig(semantics="born")
    dpb = PP._multilevel_sample(mesh, mb, 32, key, PP.ParallelConfig("dp"), cb)
    tsb = PP._multilevel_sample(mesh, mb, 32, key, PP.ParallelConfig("tp_single"), cb)
    tdb = PP._multilevel_sample(mesh, mb, 32, key, PP.ParallelConfig("tp_double"), cb)
    out["born_dp_eq_single"] = bool(jnp.all(dpb == tsb))
    out["born_dp_eq_double"] = bool(jnp.all(dpb == tdb))

    # [19] baseline pipeline == per-macro-batch sequential chain
    mesh19 = jax.make_mesh((6,), ("data",))
    n, n1 = 60, PP.config_macro_batches(60)
    b19 = PP._baseline19_sample(mesh19, m, n, jax.random.key(9))
    bk = jax.random.split(jax.random.key(9), n1)
    ref = jnp.concatenate([S.sample(m, n // n1, bk[b]) for b in range(n1)], 0)
    out["baseline19_eq_seq"] = bool(jnp.all(b19 == ref))

    # single-device-sampler equivalence: DP with same per-shard base keys
    shard_keys = jax.random.split(key, 2)
    seq = jnp.concatenate([S.sample(m, 32, shard_keys[i], cfg) for i in range(2)], 0)
    out["dp_eq_sequential"] = bool(jnp.all(dp == seq))

    # ---- seed-consistency matrix (paper §4.1): the in-memory reference vs
    # every schedule, the streaming engine under every schedule, and a
    # kill-and-resume through sample_chain/sample_resumable ----
    import tempfile
    import numpy as np
    from repro.data.gamma_store import GammaStore
    from repro.engine import StreamPlan, StreamingEngine

    ref = np.asarray(seq)                   # == dp == tp_single == tp_double
    root = tempfile.mkdtemp()
    wstore = GammaStore(root, storage_dtype=jnp.float64,
                        compute_dtype=jnp.float64)
    wstore.write_mps(m)
    wstore.close()
    consistency = {
        "dp": bool(np.array_equal(np.asarray(dp), ref)),
        "tp_single": bool(np.array_equal(np.asarray(ts), ref)),
        "tp_double": bool(np.array_equal(np.asarray(td), ref)),
    }
    for scheme in ("dp", "tp_single", "tp_double"):
        store = GammaStore(root, storage_dtype=jnp.float64,
                           compute_dtype=jnp.float64)
        eng = StreamingEngine(store, plan=StreamPlan(segment_len=2,
                                                     scheme=scheme),
                              mesh=mesh)
        consistency["stream_" + scheme] = bool(
            np.array_equal(eng.sample(64, key), ref))
        eng.close()

    # kill after 2 segments, resume from the checkpoint: still == ref
    ck = tempfile.mkdtemp()
    store = GammaStore(root, storage_dtype=jnp.float64,
                       compute_dtype=jnp.float64)
    eng = StreamingEngine(store, plan=StreamPlan(segment_len=2, scheme="dp",
                                                 checkpoint_every=1),
                          mesh=mesh, checkpoint_dir=ck)
    eng.sample(64, key, stop_after_segments=2)
    eng.close()
    store = GammaStore(root, storage_dtype=jnp.float64,
                       compute_dtype=jnp.float64)
    eng = StreamingEngine(store, plan=StreamPlan(segment_len=2, scheme="dp",
                                                 checkpoint_every=1),
                          mesh=mesh, checkpoint_dir=ck)
    consistency["stream_resume"] = bool(
        np.array_equal(eng.sample(64, key, resume=True), ref))
    eng.close()

    # the sampler-level restart primitive the engine builds on
    st0 = S.init_state(m, 32, shard_keys[0])
    head = M.MPS(m.gammas[:3], m.lambdas[:3], m.semantics)
    part = S.sample_chain(head, st0, cfg)
    rest = S.sample_resumable(m, part.state, 3, cfg)
    stitched = jnp.concatenate([part.samples, rest.samples], 0).T
    consistency["sample_resumable"] = bool(
        np.array_equal(np.asarray(stitched), ref[:32]))
    out["consistency"] = consistency
    print(json.dumps(out))
""")
_CHILD = "import json\n" + _CHILD


@pytest.fixture(scope="module")
def child_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_dp_tp_single_seed_identical(child_results):
    assert child_results["dp_eq_single"]


def test_dp_tp_double_seed_identical(child_results):
    assert child_results["dp_eq_double"]


def test_output_shape(child_results):
    assert child_results["shape_ok"]


def test_born_semantics_tp(child_results):
    assert child_results["born_dp_eq_single"]
    assert child_results["born_dp_eq_double"]


def test_baseline19_pipeline_exact(child_results):
    assert child_results["baseline19_eq_seq"]


def test_dp_equals_sequential_per_shard(child_results):
    assert child_results["dp_eq_sequential"]


@pytest.mark.parametrize("schedule", [
    "dp", "tp_single", "tp_double",
    "stream_dp", "stream_tp_single", "stream_tp_double",
    "stream_resume", "sample_resumable",
])
def test_seed_consistency_across_schedules(child_results, schedule):
    """Paper §4.1: the per-shard in-memory sampler, every DP/TP schedule,
    the streaming engine under each scheme, and both restart paths emit
    bit-identical samples from one seed."""
    assert child_results["consistency"][schedule]
