"""End-to-end behaviour tests: the GBS pipeline and a mini LM training run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import displacement as D
from repro.core import dynamic_bond as DB
from repro.core import mps as M
from repro.core import sampler as S
from repro.data.tokens import synthetic_token_stream
from repro.launch import steps
from repro.models import transformer as T
from repro.optim import optimizers, schedule


@pytest.mark.slow
def test_gbs_pipeline_end_to_end(tmp_path):
    """MPS build → dynamic-χ stages → displaced sampling → correlations.

    Mirrors the paper's validation flow (§4.1) at laptop scale.
    """
    m_sites, chi, d = 16, 16, 3
    mps = M.gbs_like_mps(jax.random.key(0), m_sites, chi, d)

    # dynamic bond profile (Table 1 accounting)
    prof = DB.area_law_profile(m_sites, chi, n_photon=1.0)
    buck = DB.bucketize(prof, [4, 8, 16])
    metrics = DB.table1_metrics(prof, chi)
    assert metrics["comp_ratio"] < 1.0

    out = DB.sample_staged(mps, buck, 20_000, jax.random.key(1))
    assert out.shape == (20_000, m_sites)

    # internal consistency of site marginals: two independent halves agree
    half1 = np.asarray(out[:10_000])
    half2 = np.asarray(out[10_000:])
    m1 = half1.mean(axis=0)
    m2 = half2.mean(axis=0)
    slope = np.polyfit(m1, m2, 1)[0]
    assert 0.9 < slope < 1.1

    # displaced measurement: apply D(μ) to an unmeasured env
    env = jax.random.uniform(jax.random.key(2), (64, chi, d), dtype=jnp.float64)
    mu = 0.3 * (jax.random.normal(jax.random.key(3), (64,))
                + 1j * jax.random.normal(jax.random.key(4), (64,)))
    disp = D.displace_env(env, mu.astype(jnp.complex128), d)
    assert disp.shape == env.shape
    assert bool(jnp.all(jnp.isfinite(jnp.abs(disp))))


@pytest.mark.slow
def test_mini_lm_training_loss_decreases():
    """Train a tiny dense LM for 30 steps on a fixed synthetic batch —
    loss must drop (the end-to-end driver contract of launch/train.py)."""
    cfg = configs.get_smoke_config("granite-3-2b")
    params, _ = T.init_params(jax.random.key(0), cfg)
    opt = optimizers.adamw(schedule.cosine_schedule(3e-3, warmup=5, total=30))
    opt_state = opt.init(params)
    step_fn = jax.jit(steps.make_train_step(cfg, opt))

    bat = synthetic_token_stream(seed=0, vocab=cfg.vocab, batch=4, seq=16)
    batch = bat(0)
    losses = []
    for _ in range(30):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < 0.7 * losses[0], losses[::10]


@pytest.mark.slow
def test_serve_batched_requests():
    """Batched greedy decode over a KV cache — the serving driver contract."""
    cfg = configs.get_smoke_config("deepseek-7b")
    params, _ = T.init_params(jax.random.key(0), cfg)
    serve = jax.jit(steps.make_serve_step(cfg))
    B, steps_n = 4, 8
    state = T.init_decode_state(cfg, B, 32)
    tok = jax.random.randint(jax.random.key(1), (B, 1), 0, cfg.vocab)
    outs = []
    for _ in range(steps_n):
        tok, state = serve(params, {"tokens": tok}, state)
        outs.append(tok)
    seq = jnp.concatenate(outs, axis=1)
    assert seq.shape == (B, steps_n)
    assert int(state.position) == steps_n


def test_multilevel_sampler_on_one_device_mesh():
    """The multi-level API degrades gracefully to a 1×1 mesh (the 'users
    with limited computing resources' case the paper §2.2 point (1) makes)."""
    from repro import api
    mps = M.random_linear_mps(jax.random.key(0), 5, 4, 3)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    key = jax.random.key(1)
    with api.SamplingSession(mps, api.SamplerConfig(scheme="tp_single"),
                             mesh=mesh) as sess:
        out = sess.sample(16, key)
    # DP group g draws with split(key, p1)[g]; p1 = 1 here
    ref = S.sample(mps, 16, jax.random.split(key, 1)[0])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.slow
def test_first_and_second_order_correlations_fig9():
    """Paper Fig. 9 a/c: 1st- and 2nd-order correlations of sampled outcomes
    match the exact enumeration (slope ≈ 1) at exact-oracle scale."""
    mps = M.gbs_like_mps(jax.random.key(10), 6, 6, 3)
    joint = M.enumerate_probabilities(mps)
    outcomes = np.stack(np.meshgrid(*([np.arange(3)] * 6), indexing="ij"),
                        axis=-1).reshape(-1, 6).astype(np.float64)
    # exact moments
    exact_n = joint @ outcomes                             # ⟨n_i⟩
    exact_nn = np.einsum("k,ki,kj->ij", joint, outcomes, outcomes)

    samples = np.asarray(S.sample(mps, 60_000, jax.random.key(11)),
                         dtype=np.float64)
    emp_n = samples.mean(axis=0)
    emp_nn = samples.T @ samples / samples.shape[0]

    slope1 = np.polyfit(exact_n, emp_n, 1)[0]
    iu = np.triu_indices(6, k=1)
    slope2 = np.polyfit(exact_nn[iu], emp_nn[iu], 1)[0]
    assert 0.97 < slope1 < 1.03, slope1                    # paper: 0.97
    assert 0.94 < slope2 < 1.06, slope2                    # paper: 0.96
