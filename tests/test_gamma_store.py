"""Γ store: low-precision storage + double-buffered prefetch (paper §3.3.2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mps as M
from repro.data.gamma_store import GammaStore
from repro.data.tokens import synthetic_token_stream


def test_roundtrip_bf16_storage(tmp_path):
    store = GammaStore(str(tmp_path), storage_dtype=jnp.bfloat16,
                       compute_dtype=jnp.float32)
    mps = M.random_linear_mps(jax.random.key(0), 4, 8, 3, dtype=jnp.float32)
    store.write_mps(mps)
    g0, lam0 = store.get(0)
    assert g0.shape == (8, 8, 3) and g0.dtype == np.float32
    # bf16 storage: ~3 decimal digits
    np.testing.assert_allclose(g0, np.asarray(mps.gammas[0]), rtol=2e-2,
                               atol=1e-4)
    np.testing.assert_allclose(lam0, np.asarray(mps.lambdas[0]), rtol=1e-6)
    store.close()


def test_fp16_storage_halves_io(tmp_path):
    a = GammaStore(str(tmp_path / "bf16"), storage_dtype=jnp.bfloat16)
    b = GammaStore(str(tmp_path / "fp32"), storage_dtype=jnp.float32)
    mps = M.random_linear_mps(jax.random.key(1), 2, 16, 3, dtype=jnp.float32)
    a.write_mps(mps)
    b.write_mps(mps)
    a.get(0, prefetch_next=False)
    b.get(0, prefetch_next=False)
    # §3.3.2: Γ wire/IO bytes halve with 2-byte storage
    assert a.io_bytes < 0.6 * b.io_bytes
    a.close()
    b.close()


def test_prefetch_chain(tmp_path):
    store = GammaStore(str(tmp_path))
    mps = M.random_linear_mps(jax.random.key(2), 6, 4, 2, dtype=jnp.float32)
    store.write_mps(mps)
    for i in range(6):                      # sequential walk hits the prefetch
        g, lam = store.get(i)
        assert g.shape == (4, 4, 2)
    store.close()


def test_token_stream_restart_exact():
    bat = synthetic_token_stream(seed=3, vocab=100, batch=4, seq=16)
    a = bat(10)
    b = bat(10)
    c = bat(11)
    assert jnp.all(a["tokens"] == b["tokens"])       # idempotent by (seed, step)
    assert not jnp.all(a["tokens"] == c["tokens"])
    assert jnp.all(a["labels"][:, :-1] == a["tokens"][:, 1:])
