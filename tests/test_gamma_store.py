"""Γ store: low-precision storage + double-buffered prefetch (paper §3.3.2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mps as M
from repro.data.gamma_store import GammaStore
from repro.data.tokens import synthetic_token_stream


def test_roundtrip_bf16_storage(tmp_path):
    store = GammaStore(str(tmp_path), storage_dtype=jnp.bfloat16,
                       compute_dtype=jnp.float32)
    mps = M.random_linear_mps(jax.random.key(0), 4, 8, 3, dtype=jnp.float32)
    store.write_mps(mps)
    g0, lam0 = store.get(0)
    assert g0.shape == (8, 8, 3) and g0.dtype == np.float32
    # bf16 storage: ~3 decimal digits
    np.testing.assert_allclose(g0, np.asarray(mps.gammas[0]), rtol=2e-2,
                               atol=1e-4)
    np.testing.assert_allclose(lam0, np.asarray(mps.lambdas[0]), rtol=1e-6)
    store.close()


def test_fp16_storage_halves_io(tmp_path):
    a = GammaStore(str(tmp_path / "bf16"), storage_dtype=jnp.bfloat16)
    b = GammaStore(str(tmp_path / "fp32"), storage_dtype=jnp.float32)
    mps = M.random_linear_mps(jax.random.key(1), 2, 16, 3, dtype=jnp.float32)
    a.write_mps(mps)
    b.write_mps(mps)
    a.get(0, prefetch_next=False)
    b.get(0, prefetch_next=False)
    # §3.3.2: Γ wire/IO bytes halve with 2-byte storage
    assert a.io_bytes < 0.6 * b.io_bytes
    a.close()
    b.close()


def test_prefetch_chain(tmp_path):
    store = GammaStore(str(tmp_path))
    mps = M.random_linear_mps(jax.random.key(2), 6, 4, 2, dtype=jnp.float32)
    store.write_mps(mps)
    for i in range(6):                      # sequential walk hits the prefetch
        g, lam = store.get(i)
        assert g.shape == (4, 4, 2)
    store.close()


def test_prefetch_reads_each_site_exactly_once(tmp_path):
    """Regression: an in-flight prefetch must be awaited, not re-read — a
    sequential walk costs exactly one disk read per site."""
    store = GammaStore(str(tmp_path), storage_dtype=jnp.float32)
    mps = M.random_linear_mps(jax.random.key(4), 8, 4, 2, dtype=jnp.float32)
    store.write_mps(mps)
    per_site = int(mps.gammas[0].size * 4 + mps.lambdas[0].size * 4)
    for i in range(8):
        store.get(i)
    assert store.io_bytes == 8 * per_site, (store.io_bytes, per_site)
    # nothing leaked into the buffer besides the final scheduled site
    assert set(store._prefetched) <= {8}
    store.close()
    assert not store._thread.is_alive()


def test_segment_reads_and_device_handoff(tmp_path):
    store = GammaStore(str(tmp_path), storage_dtype=jnp.bfloat16,
                       compute_dtype=jnp.float32)
    mps = M.random_linear_mps(jax.random.key(5), 10, 4, 3, dtype=jnp.float32)
    store.write_mps(mps)
    assert store.n_sites == 10
    g, lam = store.get_segment(0, 4)
    assert g.shape == (4, 4, 4, 3) and lam.shape == (4, 4)
    gd, ld = store.get_segment_on_device(4, 4)
    assert gd.shape == (4, 4, 4, 3) and ld.shape == (4, 4)
    # tail segment is clipped to the chain end
    g2, _ = store.get_segment(8, 4)
    assert g2.shape[0] == 2
    # every site read exactly once across the three segment calls:
    # bf16 gamma (4·4·3·2 B) + f32 lambda (4·4 B) per site
    assert store.io_bytes == 10 * (4 * 4 * 3 * 2 + 4 * 4)
    store.close()


def test_token_stream_restart_exact():
    bat = synthetic_token_stream(seed=3, vocab=100, batch=4, seq=16)
    a = bat(10)
    b = bat(10)
    c = bat(11)
    assert jnp.all(a["tokens"] == b["tokens"])       # idempotent by (seed, step)
    assert not jnp.all(a["tokens"] == c["tokens"])
    assert jnp.all(a["labels"][:, :-1] == a["tokens"][:, 1:])
