"""Streaming engine: segment-streamed chains ≡ the in-memory paths.

The engine's contract (paper §3.1 + §4.1): for the same seed and Γ, a
segment-streamed walk is bit-identical to the all-in-memory scan, holds at
most two Γ segments on device, and survives a mid-chain kill exactly.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import mps as M
from repro.core import sampler as S
from repro.core.perfmodel import Hardware, Workload, choose_tp_scheme
from repro.data.gamma_store import GammaStore
from repro.engine import (StreamPlan, StreamingEngine, explain_plan,
                          plan_stream)
from repro.engine.streaming import identity_sites
from repro.runtime.elastic import WorkQueue


@pytest.fixture(scope="module")
def chain(tmp_path_factory, linear_mps_10x6):
    """A 10-site chain written once to disk (fp64: no storage rounding, so
    the in-memory MPS is the exact reference)."""
    root = str(tmp_path_factory.mktemp("gamma"))
    store = GammaStore(root, storage_dtype=jnp.float64,
                       compute_dtype=jnp.float64)
    store.write_mps(linear_mps_10x6)
    store.close()
    return root, linear_mps_10x6


def _store(root):
    return GammaStore(root, storage_dtype=jnp.float64,
                      compute_dtype=jnp.float64)


@pytest.mark.parametrize("segment_len", [4, 5, 16])
def test_stream_bitexact_vs_inmemory(chain, segment_len):
    """Remainder segments (4: 4+4+2), exact division (5), and a single
    padded over-long segment (16 > M) all reproduce sample() exactly."""
    root, mps = chain
    key = jax.random.key(3)
    ref = np.asarray(S.sample(mps, 24, key))
    eng = StreamingEngine(_store(root),
                          plan=StreamPlan(segment_len=segment_len))
    out = eng.sample(24, key)
    assert np.array_equal(out, ref)
    assert eng.stats["max_live_segments"] <= 2
    eng.close()


def test_stream_reads_each_site_once_per_walk(chain):
    root, mps = chain
    store = _store(root)
    eng = StreamingEngine(store, plan=StreamPlan(segment_len=4))
    eng.sample(8, jax.random.key(0))
    per_site = mps.gammas[0].size * 8 + mps.lambdas[0].size * 8
    # the constructor's metadata probe is header-only — exactly one payload
    # read per site for the whole walk
    assert store.io_bytes == mps.n_sites * per_site
    eng.close()


def test_micro_batched_stream_matches_sample_batched(chain):
    root, mps = chain
    key = jax.random.key(9)
    ref = np.asarray(S.sample_batched(mps, 24, key, micro_batch=8))
    eng = StreamingEngine(_store(root),
                          plan=StreamPlan(segment_len=4, micro_batch=8))
    out = eng.sample(24, key)
    assert np.array_equal(out, ref)
    eng.close()


def test_kill_and_resume_bitexact(chain, tmp_path):
    root, mps = chain
    key = jax.random.key(11)
    ref = np.asarray(S.sample(mps, 16, key))
    plan = StreamPlan(segment_len=4, checkpoint_every=1)

    crashed = StreamingEngine(_store(root), plan=plan,
                              checkpoint_dir=str(tmp_path))
    part = crashed.sample(16, key, stop_after_segments=2)
    assert part.shape == (16, 8)                 # 2 of 3 segments done
    assert np.array_equal(part, ref[:, :8])
    crashed.close()

    resumed = StreamingEngine(_store(root), plan=plan,
                              checkpoint_dir=str(tmp_path))
    out = resumed.sample(16, key, resume=True)
    assert np.array_equal(out, ref)
    assert resumed.stats["segments"] == 1        # only the remaining work
    # checkpoint-per-segment must not accumulate the chain's history
    ckpts = [f for f in tmp_path.iterdir() if f.suffix == ".npz"]
    assert len(ckpts) <= 3
    resumed.close()


def test_workqueue_macro_batches_idempotent(chain):
    """Macro batches as engine work items: batch = f(seed, id) exactly as
    runtime/elastic.py requires, so results are owner/order-independent."""
    root, mps = chain
    base = jax.random.key(21)
    eng = StreamingEngine(_store(root), plan=StreamPlan(segment_len=5))
    q = WorkQueue(3)
    outs = eng.run_queue(q, 8, base)
    assert q.finished
    for b in range(3):
        ref = np.asarray(S.sample(mps, 8, jax.random.fold_in(base, b)))
        assert np.array_equal(outs[b], ref)
    eng.close()


def test_born_semantics_stream(tmp_path, born_mps_6x4):
    mps = born_mps_6x4
    key = jax.random.key(2)
    cfg = S.SamplerConfig(semantics="born")
    ref = np.asarray(S.sample(mps, 16, key, cfg))
    with GammaStore(str(tmp_path), storage_dtype=jnp.complex128,
                    compute_dtype=jnp.complex128) as store:
        store.write_mps(mps)
        with StreamingEngine(store, semantics="born", config=cfg,
                             plan=StreamPlan(segment_len=4)) as eng:
            out = eng.sample(16, key)
    assert np.array_equal(out, ref)


def test_multihost_engine_root_reads_peers_receive(chain):
    """Tentpole unit test at the engine level: on a 2-process emulated
    runtime, ONLY the root engine issues GammaStore payload reads (its
    per-engine store-I/O delta covers the whole chain; the peer's is zero)
    and both walks are bit-identical to the single-process one."""
    import threading

    from repro.api.runtime import emulated_cluster

    root, mps = chain
    key = jax.random.key(5)
    ref = np.asarray(S.sample(mps, 16, key))
    per_site = mps.gammas[0].size * 8 + mps.lambdas[0].size * 8

    runtimes = emulated_cluster(2)
    outs, stats, errs = {}, {}, []

    def walk(rt):
        try:
            with _store(root) as store:
                eng = StreamingEngine(store, plan=StreamPlan(segment_len=4),
                                      runtime=rt)
                outs[rt.process_index] = eng.sample(16, key)
                stats[rt.process_index] = dict(eng.stats)
                eng.close(close_store=False)
        except Exception as e:          # pragma: no cover - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=walk, args=(rt,)) for rt in runtimes]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errs, errs
    assert np.array_equal(outs[0], ref) and np.array_equal(outs[1], ref)
    # the §3.1 contract: one reader, everyone else on the interconnect
    assert stats[0]["io_bytes"] == mps.n_sites * per_site
    assert stats[1]["io_bytes"] == 0
    assert stats[0]["broadcast_send_bytes"] == mps.n_sites * per_site
    assert stats[1]["broadcast_recv_bytes"] == mps.n_sites * per_site
    assert stats[1]["broadcast_segments"] == stats[1]["segments"]


def test_identity_pad_sites_are_noops():
    g, lam = identity_sites(2, 4, 3, np.float64)
    assert g.shape == (2, 4, 4, 3) and lam.shape == (2, 4)
    env = np.array([[0.2, 0.5, 0.1, 0.0]])
    temp = np.einsum("nl,lrs->nrs", env, g[0])
    np.testing.assert_array_equal(temp[:, :, 0], env)   # outcome 0 = identity
    np.testing.assert_array_equal(temp[:, :, 1:], 0.0)  # others impossible


# ---------------------------------------------------------------------------
# Planner (perfmodel-driven)
# ---------------------------------------------------------------------------

def _wl(**kw):
    base = dict(n_samples=80_000, n_sites=512, chi=128, d=3,
                macro_batch=20_000, micro_batch=5_000)
    base.update(kw)
    return Workload(**base)


def test_planner_segment_shrinks_with_budget():
    hw = Hardware()
    w = _wl()
    big = plan_stream(w, hw, device_budget=16e9)
    small = plan_stream(w, hw, device_budget=1e9)
    assert big.segment_len >= small.segment_len
    assert small.segment_len >= 2
    assert big.segment_len % 2 == 0 and small.segment_len % 2 == 0
    assert big.segment_len <= w.n_sites


def test_planner_raises_when_env_does_not_fit():
    with pytest.raises(ValueError):
        plan_stream(_wl(), Hardware(), device_budget=1e6)


def test_planner_scheme_selection():
    hw = Hardware()
    w = _wl()
    assert plan_stream(w, hw).scheme == "inmem"
    assert plan_stream(w, hw, p1=4).scheme == "dp"
    tp = plan_stream(w, hw, p2=4)
    assert tp.scheme == "tp_" + choose_tp_scheme(w, hw, 4)
    assert tp.micro_batch == 5_000       # N₂ now composes with DP/TP too
    dp = plan_stream(w, hw, p1=4)
    assert dp.micro_batch == 5_000 // 4  # per data shard


def test_planner_micro_batch_passthrough():
    plan = plan_stream(_wl(), Hardware(), device_budget=16e9)
    assert plan.micro_batch == 5_000
    info = explain_plan(plan, _wl(), Hardware())
    assert info["io_overlapped"] == (info["t_compute_per_site_s"]
                                     >= info["t_io_per_site_s"])
    assert info["min_macro_batch_for_overlap"] > 0
