"""`SamplingService` job semantics: submit / stream / cancel / elasticity.

The service contract on top of the §4.1 seed-consistency guarantee:

* a single-batch job IS the one-shot call (`batch_key` passes the key
  through), so `session.sample` — now a synchronous wrapper over a
  one-job service — stays bit-identical to every pre-service release;
* a k-batch job's streamed blocks are bit-identical per seed to one-shot
  `session.sample` calls (batch b ≡ sample(per, fold_in(key, b))),
  across {inmem, streamed} × {seq, dp} — dp in an 8-device subprocess;
* killing a worker mid-job requeues its batches and the survivors emit
  the exact same samples (batch = f(seed, id) — owner-independent);
* same-(source, config)-cell jobs coalesce onto ONE session (one resolved
  plan, one streamed engine, one jit cache).
"""
import json
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.api.service import JobCancelled, batch_key
from repro.core import sampler as S
from repro.data.gamma_store import GammaStore


@pytest.fixture(scope="module")
def chain(tmp_path_factory, linear_mps_10x6):
    root = str(tmp_path_factory.mktemp("svc_gamma"))
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(linear_mps_10x6)
    return root, linear_mps_10x6


# ---------------------------------------------------------------------------
# Job lifecycle
# ---------------------------------------------------------------------------

def test_single_batch_job_is_the_one_shot_call(chain):
    root, mps = chain
    key = jax.random.key(3)
    ref = np.asarray(S.sample(mps, 24, key))
    with api.SamplingService() as svc:
        h = svc.submit(root, api.SamplerConfig(segment_len=4),
                       n_samples=24, key=key)
        assert np.array_equal(h.result(), ref)
        assert h.status() == "done"
        assert h.progress["done"] == 1 and h.progress["total"] == 1
        # the session facade is the same job in synchronous clothing
    with api.SamplingSession(root, api.SamplerConfig(segment_len=4)) as sess:
        assert np.array_equal(sess.sample(24, key), ref)


@pytest.mark.parametrize("backend", ["inmem", "streamed"])
def test_stream_blocks_bitidentical_per_seed_seq(chain, backend):
    """Acceptance (seq cells): the concatenation of a job's streamed
    macro-batch blocks equals the per-seed one-shot `session.sample`
    results — and each block lands exactly once, in batch order."""
    root, mps = chain
    key = jax.random.key(7)
    n, k = 32, 4
    src = mps if backend == "inmem" else root
    cfg = api.SamplerConfig(backend=backend, segment_len=4)
    refs = [np.asarray(S.sample(mps, n // k, batch_key(key, b, k)))
            for b in range(k)]
    with api.SamplingService(workers=2) as svc:
        h = svc.submit(src, cfg, n_samples=n, key=key, macro_batches=k)
        seen = []
        for b, block in h.stream(timeout=300):
            seen.append(b)
            assert np.array_equal(block, refs[b])
        assert seen == list(range(k))
        assert np.array_equal(h.result(), np.concatenate(refs, axis=0))
        assert h.progress["claims"] == k and h.progress["requeues"] == 0


def test_skip_batches_resume_by_id(chain):
    """Idempotent restart: batches already durable elsewhere are skipped;
    the stream yields only the remaining ids with unchanged keys."""
    root, mps = chain
    key = jax.random.key(11)
    with api.SamplingService() as svc:
        h = svc.submit(root, api.SamplerConfig(segment_len=4), n_samples=24,
                       key=key, macro_batches=3, skip_batches=[1])
        got = dict(h.stream(timeout=300))
        assert sorted(got) == [0, 2]
        for b in got:
            assert np.array_equal(
                got[b], np.asarray(S.sample(mps, 8, batch_key(key, b, 3))))


def test_cancel_pending_job_and_elastic_scale_up(chain):
    root, _ = chain
    key = jax.random.key(13)
    with api.SamplingService(workers=0) as svc:       # no lanes: nothing runs
        h = svc.submit(root, api.SamplerConfig(segment_len=4),
                       n_samples=16, key=key, macro_batches=2)
        assert h.status() == "pending"
        assert h.cancel()
        assert h.status() == "cancelled"
        with pytest.raises(JobCancelled):
            h.result(timeout=30)
        # scale-up: a fresh lane picks up later work
        h2 = svc.submit(root, api.SamplerConfig(segment_len=4),
                        n_samples=8, key=key)
        svc.add_worker()
        assert h2.result(timeout=300).shape == (8, 10)


def test_cancel_mid_job_stops_remaining_batches(chain):
    root, mps = chain
    key = jax.random.key(17)

    with api.SamplingService(workers=0) as svc:
        h = None

        def hook(job, b, worker):
            if b == 1:
                h.cancel()            # in-flight batch 1 gets discarded

        svc.batch_hook = hook
        h = svc.submit(root, api.SamplerConfig(segment_len=4),
                       n_samples=32, key=key, macro_batches=4)
        svc.add_worker()
        stream = h.stream(timeout=300)
        b0, block0 = next(stream)
        assert b0 == 0 and np.array_equal(
            block0, np.asarray(S.sample(mps, 8, batch_key(key, 0, 4))))
        with pytest.raises(JobCancelled):
            list(stream)
        assert h.status() == "cancelled"
        assert h.progress["blocks"] == 1          # nothing ran after cancel


def test_purge_drops_finished_jobs_but_handles_keep_answering(chain):
    root, mps = chain
    key = jax.random.key(53)
    ref = np.asarray(S.sample(mps, 8, key))
    with api.SamplingService() as svc:
        h = svc.submit(root, api.SamplerConfig(segment_len=4),
                       n_samples=8, key=key)
        assert np.array_equal(h.result(timeout=300), ref)
        assert svc.purge() == 1
        # table forgot the job — stable schema: all states present, zeroed
        assert all(n == 0 for n in svc.stats()["jobs"].values())
        assert h.status() == "done"                # the handle did not
        assert np.array_equal(h.result(), ref)


def test_multihost_runtime_rejects_multi_lane_service(chain):
    """Concurrent lanes on a shared multi-process runtime would interleave
    broadcast collectives in thread order — rejected at submit time."""
    root, _ = chain
    rt = api.emulated_cluster(2)[0]
    cfg = api.SamplerConfig(runtime=rt, backend="streamed", segment_len=4)
    with api.SamplingSession(root, cfg) as sess:
        with api.SamplingService(workers=2) as svc:
            with pytest.raises(ValueError, match="single-lane"):
                svc.submit(sess, n_samples=8, key=jax.random.key(0))


def test_stream_timeout_is_a_real_deadline(chain):
    """The per-batch timeout must not re-arm on unrelated notifies (every
    submit/completion broadcasts the condition)."""
    root, _ = chain
    with api.SamplingService(workers=0) as svc:     # job can never run
        h = svc.submit(root, api.SamplerConfig(segment_len=4),
                       n_samples=8, key=jax.random.key(0))

        def churn():                                # constant notifies
            for _ in range(50):
                with svc._cond:
                    svc._cond.notify_all()
                import time
                time.sleep(0.01)

        t = threading.Thread(target=churn)
        t.start()
        with pytest.raises(TimeoutError):
            h.result(timeout=0.2)
        t.join()


def test_removed_worker_name_can_be_revived(chain):
    root, _ = chain
    key = jax.random.key(59)
    with api.SamplingService(workers=0) as svc:
        svc.add_worker("gpu-lane")
        h1 = svc.submit(root, api.SamplerConfig(segment_len=4),
                        n_samples=8, key=key)
        h1.result(timeout=300)
        svc.remove_worker("gpu-lane")
        svc._threads["gpu-lane"].join(timeout=60)   # lane drains and exits
        svc.add_worker("gpu-lane")                  # stable ops name revives
        h2 = svc.submit(root, api.SamplerConfig(segment_len=4),
                        n_samples=8, key=key)
        assert np.array_equal(h2.result(timeout=300), h1.result())


def test_single_batch_job_honours_checkpoint_root(chain, tmp_path):
    """--service --macro-batches 1 keeps the sync path's mid-chain fault
    tolerance: checkpoint_root applies to 1-batch jobs too, with the
    shared per-batch dir convention."""
    from repro.api.service import batch_checkpoint_dir
    root, mps = chain
    key = jax.random.key(61)
    ref = np.asarray(S.sample(mps, 16, key))
    ck_root = str(tmp_path)
    # seed a mid-chain checkpoint via the engine-level kill hook
    cfg = api.SamplerConfig(segment_len=4, checkpoint_every=1)
    ck = batch_checkpoint_dir(ck_root, 0)
    os.makedirs(ck, exist_ok=True)
    with api.SamplingSession(root, cfg) as sess:
        part = sess.sample(16, key, checkpoint_dir=ck, stop_after_segments=2)
        assert np.array_equal(part, ref[:, :8])
    with api.SamplingService() as svc:
        h = svc.submit(root, cfg, n_samples=16, key=key,
                       checkpoint_root=ck_root)
        out = h.result(timeout=300)
        # resumed from site 8: only the remaining segments walked
        assert h.stats[0]["segments"] == 1
    assert np.array_equal(out, ref)
    assert not os.path.exists(ck)          # durable output → dir cleaned
    with api.SamplingService(workers=0) as svc:
        with pytest.raises(ValueError, match="checkpoint_root"):
            svc.submit(root, cfg, n_samples=16, key=key,
                       checkpoint_root=ck_root, resume=True)


def test_store_handles_with_different_dtypes_do_not_coalesce(chain):
    """Two GammaStore handles on one root with different compute dtypes
    must get separate sessions — precision is per-open state."""
    root, _ = chain
    key = jax.random.key(67)
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as s64, \
         GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float32) as s32, \
         api.SamplingService() as svc:
        cfg = api.SamplerConfig(segment_len=4)
        svc.submit(s64, cfg, n_samples=8, key=key).result(timeout=300)
        svc.submit(s32, cfg, n_samples=8, key=key).result(timeout=300)
        assert svc.stats()["sessions"] == 2        # no silent precision mix


def test_priority_ordering(chain):
    """Higher-priority jobs are served first once a lane appears."""
    root, _ = chain
    key = jax.random.key(19)
    order = []
    with api.SamplingService(workers=0) as svc:
        svc.batch_hook = lambda job, b, w: order.append(job.job_id)
        lo = svc.submit(root, api.SamplerConfig(segment_len=4),
                        n_samples=8, key=key, priority=0)
        hi = svc.submit(root, api.SamplerConfig(segment_len=4),
                        n_samples=8, key=key, priority=5)
        svc.add_worker()
        lo.result(timeout=300), hi.result(timeout=300)
    assert order == [hi.job_id, lo.job_id]


def test_failed_job_reraises_original_error(chain):
    root, _ = chain
    with api.SamplingSession(root, api.SamplerConfig(segment_len=4)) as sess:
        # resume without a checkpoint_dir fails inside the engine — the
        # worker's exception must surface type-intact from result()
        with pytest.raises(ValueError, match="checkpoint_dir"):
            sess.sample(8, jax.random.key(0), resume=True)


def test_submit_validation(chain):
    root, _ = chain
    key = jax.random.key(0)
    with api.SamplingService(workers=0) as svc:
        with pytest.raises(ValueError, match="divide"):
            svc.submit(root, api.SamplerConfig(segment_len=4),
                       n_samples=10, key=key, macro_batches=3)
        with pytest.raises(ValueError, match="skip_batches"):
            svc.submit(root, api.SamplerConfig(segment_len=4),
                       n_samples=8, key=key, macro_batches=2,
                       skip_batches=[2])
        with pytest.raises(ValueError, match="checkpoint_root"):
            svc.submit(root, api.SamplerConfig(segment_len=4),
                       n_samples=8, key=key, macro_batches=2, resume=True)
        # config errors surface at submit time, on the caller's thread
        with pytest.raises(ValueError, match="needs a mesh"):
            svc.submit(root, api.SamplerConfig(scheme="dp", segment_len=4),
                       n_samples=8, key=key)


# ---------------------------------------------------------------------------
# Elasticity: worker kill → requeue → identical samples
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["inmem", "streamed"])
def test_worker_kill_requeues_and_samples_identical(chain, backend):
    root, mps = chain
    key = jax.random.key(23)
    n, k = 32, 4
    src = mps if backend == "inmem" else root
    refs = [np.asarray(S.sample(mps, n // k, batch_key(key, b, k)))
            for b in range(k)]
    killed = []

    with api.SamplingService(workers=2) as svc:
        def hook(job, b, worker):
            if b == 2 and not killed:          # first claimant of batch 2
                killed.append(worker)
                svc.remove_worker(worker)      # its claims requeue at once

        svc.batch_hook = hook
        h = svc.submit(src, api.SamplerConfig(backend=backend, segment_len=4),
                       n_samples=n, key=key, macro_batches=k)
        out = h.result(timeout=300)
    assert killed, "the kill hook never fired"
    assert np.array_equal(out, np.concatenate(refs, axis=0))
    p = h.progress
    assert p["requeues"] >= 1 and p["done"] == k


def test_late_completion_from_removed_worker_is_discarded():
    """WorkQueue ownership check (unit): a removed worker's completion of a
    requeued batch does not count; the new owner's does."""
    from repro.runtime.elastic import WorkQueue
    q = WorkQueue(2)
    assert q.claim("a", now=0.0) == 0
    q.remove_worker("a")
    assert not q.complete(0, worker="a")       # late result: discarded
    assert q.claim("b", now=1.0) == 0          # requeued, re-offered first
    assert q.complete(0, worker="b")
    assert q.stats()["requeues"] == 1


# ---------------------------------------------------------------------------
# Plan coalescing
# ---------------------------------------------------------------------------

def test_same_cell_jobs_coalesce_onto_one_session(chain):
    """Two jobs with equal (source, config, mesh) share one session —
    hence one resolved plan and ONE streamed engine (the jit cache and
    prefetch pool compile/warm once for both)."""
    root, mps = chain
    key = jax.random.key(29)
    cfg_a = api.SamplerConfig(segment_len=4)
    cfg_b = api.SamplerConfig(segment_len=4)    # equal value, distinct object
    ref = np.asarray(S.sample(mps, 16, key))
    with api.SamplingService() as svc:
        h1 = svc.submit(root, cfg_a, n_samples=16, key=key)
        h2 = svc.submit(root, cfg_b, n_samples=16, key=key)
        assert np.array_equal(h1.result(timeout=300), ref)
        assert np.array_equal(h2.result(timeout=300), ref)
        st = svc.stats()
        assert st["sessions"] == 1 and st["coalesced_jobs"] == 1
        # one session ⇒ one cached streamed engine serves both jobs
        (sess,) = svc._sessions.values()
        assert len(sess._engines) == 1
    # plans in one cell share compilation given equal shapes (plan.cell is
    # the coalescing identity the service reports)
    with api.SamplingSession(root, cfg_a) as sess:
        assert sess.plan(16).cell == ("streamed", "local", "seq",
                                      "linear", "xla")


def test_streamed_engine_cached_per_engine_identity(chain):
    """The session keeps one engine per engine identity: sample() calls
    that differ only in batch size share it (jit is per shape inside), so
    a service handling varied job sizes never accumulates engines."""
    root, mps = chain
    key = jax.random.key(31)
    with api.SamplingSession(root, api.SamplerConfig(segment_len=4)) as sess:
        sess.sample(16, key)
        assert len(sess._engines) == 1
        sess.sample(16, jax.random.key(99))
        assert len(sess._engines) == 1          # same identity → same engine
        out8 = sess.sample(8, key)              # different n → SAME engine
        assert len(sess._engines) == 1
        assert np.array_equal(out8, np.asarray(S.sample(mps, 8, key)))


def test_multibatch_job_never_falls_back_to_config_checkpoint_dir(
        chain, tmp_path):
    """submit() rejects per-walk checkpoint_dir for multi-batch jobs; the
    config-level one must not sneak back in through the fallback, or every
    batch would overwrite one directory's site_*/samples_* files."""
    root, mps = chain
    key = jax.random.key(71)
    cfg = api.SamplerConfig(segment_len=4, checkpoint_dir=str(tmp_path),
                            checkpoint_every=1)
    with api.SamplingService() as svc:
        h = svc.submit(root, cfg, n_samples=16, key=key, macro_batches=2)
        got = dict(h.stream(timeout=300))
    for b in range(2):
        assert np.array_equal(
            got[b], np.asarray(S.sample(mps, 8, batch_key(key, b, 2))))
    assert os.listdir(str(tmp_path)) == []      # no shared-dir checkpoints


def test_add_worker_rejected_while_multiprocess_job_active(chain):
    """Scale-up must honour the same single-lane invariant submit() does:
    a running multi-process job owns the lane exclusively."""
    root, _ = chain
    rt = api.emulated_cluster(2)[0]
    cfg = api.SamplerConfig(runtime=rt, backend="streamed", segment_len=4)
    claimed, release = threading.Event(), threading.Event()
    with api.SamplingSession(root, cfg) as sess:
        with api.SamplingService(workers=0) as svc:
            # park the lane in the pre-compute hook so the job is RUNNING
            # without ever touching the (un-driven) peer's collectives
            def hook(job, b, worker):
                claimed.set()
                release.wait(timeout=60)

            svc.batch_hook = hook
            h = svc.submit(sess, n_samples=8, key=jax.random.key(0))
            svc.add_worker()                    # the single allowed lane
            assert claimed.wait(timeout=60)
            with pytest.raises(ValueError, match="multi-process"):
                svc.add_worker()
            h.cancel()                          # lane drops the batch
            release.set()


# ---------------------------------------------------------------------------
# Gang-scheduled multi-batch pipelining (streamed)
# ---------------------------------------------------------------------------

def test_pipelined_batches_bitidentical_and_prefetch_reused(chain):
    """A multi-batch streamed job gang-schedules batch b+1's first segment
    behind batch b's tail compute: samples stay bit-identical and the
    engine's live-segment bound (≤ 2) holds throughout."""
    root, mps = chain
    key = jax.random.key(37)
    refs = [np.asarray(S.sample(mps, 8, batch_key(key, b, 3)))
            for b in range(3)]
    with api.SamplingService() as svc:
        h = svc.submit(root, api.SamplerConfig(segment_len=4),
                       n_samples=24, key=key, macro_batches=3)
        got = dict(h.stream(timeout=300))
        for b in range(3):
            assert np.array_equal(got[b], refs[b])
        stats = h.stats
        assert all(s["max_live_segments"] <= 2 for s in stats.values())


def test_run_queue_still_splits_work_across_sessions(chain):
    """run_queue keeps its external-queue contract (shared restart state)
    while routing execution through the service path."""
    from repro.runtime.elastic import WorkQueue
    root, mps = chain
    key = jax.random.key(41)
    q = WorkQueue(4)
    with api.SamplingSession(root, api.SamplerConfig(segment_len=4)) as sess:
        out = sess.run_queue(q, 8, key, worker="w0")
    assert sorted(out) == [0, 1, 2, 3] and q.finished
    for b, blk in out.items():
        assert np.array_equal(
            blk, np.asarray(S.sample(mps, 8, jax.random.fold_in(key, b))))


# ---------------------------------------------------------------------------
# Job batches as the remote dispatch unit
# ---------------------------------------------------------------------------

def test_remote_payload_carries_job_batch_unit(chain):
    """backend='remote': the payload `ClusterRuntime.submit` dispatches is
    one JOB BATCH (base key + batch identity; the worker folds the batch
    key itself) — blocks come back bit-identical to the local schedule."""
    root, mps = chain
    key = jax.random.key(43)
    refs = [np.asarray(S.sample(mps, 8, batch_key(key, b, 2)))
            for b in range(2)]
    cfg = api.SamplerConfig(backend="remote", segment_len=4)
    with api.SamplingService() as svc:
        h = svc.submit(root, cfg, n_samples=16, key=key, macro_batches=2)
        got = dict(h.stream(timeout=300))
    for b in range(2):
        assert np.array_equal(got[b], refs[b])

    # schema: v2 payload carries the job identity; the v1 (job-less)
    # payload still executes — one worker entry point for both
    from repro.api.remote import build_payload, execute_payload
    from repro.api.service import JobBatch
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        p = build_payload(cfg, store, 8, key, job=JobBatch(0, 1, 2))
        assert p["version"] == 2 and p["job"]["batch_id"] == 1
        assert json.loads(json.dumps(p)) == p          # plain JSON
        out = execute_payload(json.loads(json.dumps(p)))
        assert np.array_equal(np.asarray(out), refs[1])
        p1 = build_payload(cfg, store, 8, key)
        assert "job" not in p1
        out1 = execute_payload(p1)
        assert np.array_equal(np.asarray(out1),
                              np.asarray(S.sample(mps, 8, key)))


# ---------------------------------------------------------------------------
# WorkQueue fairness (satellite)
# ---------------------------------------------------------------------------

def test_workqueue_requeued_before_fresh_and_stats():
    from repro.runtime.elastic import WorkQueue
    q = WorkQueue(5)
    assert q.claim("a", now=0.0) == 0
    assert q.claim("a", now=0.0) == 1
    assert q.claim("b", now=0.0) == 2
    q.complete(0)
    q.remove_worker("a")                 # batch 1 orphaned → requeue FIFO
    s = q.stats()
    assert s == {"total": 5, "done": 1, "claimed": 1, "requeued": 1,
                 "pending": 4, "claims": 3, "requeues": 1, "workers": 1}
    assert q.claim("c", now=1.0) == 1    # re-offered before fresh 3, 4
    assert q.claim("c", now=1.0) == 3


# ---------------------------------------------------------------------------
# DP cells + kill (8 forced host devices, subprocess) and multihost pipeline
# ---------------------------------------------------------------------------

_DP_CHILD = textwrap.dedent("""
    import json, os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.api.service import batch_key
    from repro.core import mps as M, parallel as PP
    from repro.data.gamma_store import GammaStore
    from repro.launch.mesh import make_host_mesh

    m = M.random_linear_mps(jax.random.key(0), 8, 8, 3)
    mesh = make_host_mesh(model=1)                 # 8-way data parallel
    key = jax.random.key(7)
    root = tempfile.mkdtemp()
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as st:
        st.write_mps(m)

    # per-seed one-shot references from the internal segment runner
    def ref(n, k):
        return np.asarray(PP._multilevel_sample(mesh, m, n, k,
                                                PP.ParallelConfig("dp")))
    refs = [ref(32, batch_key(key, b, 2)) for b in range(2)]

    out = {}
    for backend, src in (("inmem", m), ("streamed", root)):
        cfg = api.SamplerConfig(backend=backend, scheme="dp", segment_len=2)
        killed = []
        with api.SamplingService(workers=2) as svc:
            def hook(job, b, worker, svc=svc, killed=killed):
                if b == 1 and not killed:
                    killed.append(worker)
                    svc.remove_worker(worker)
            svc.batch_hook = hook
            h = svc.submit(src, cfg, mesh=mesh, n_samples=64, key=key,
                           macro_batches=2)
            blocks = dict(h.stream(timeout=500))
            out[backend + "_dp_blocks"] = bool(
                all(np.array_equal(blocks[b], refs[b]) for b in range(2)))
            out[backend + "_dp_killed"] = bool(
                killed and h.progress["requeues"] >= 1)
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def dp_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _DP_CHILD], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("cell", [
    f"{b}_dp_{w}" for b in ("inmem", "streamed")
    for w in ("blocks", "killed")])
def test_service_dp_matrix(dp_results, cell):
    """Acceptance (dp cells): streamed job blocks bit-identical per seed to
    the one-shot dp schedule on {inmem, streamed}, with a mid-job worker
    kill → requeue → identical samples."""
    assert dp_results[cell]


@pytest.mark.slow
def test_multihost_pipelined_job_bitidentical(chain):
    """Gang-scheduling on the emulated 2-process cluster: each process runs
    the same 2-batch job; batch b+1's first-segment broadcast rides the
    prefetch pool behind batch b's tail compute, and every process emits
    the local per-seed blocks."""
    root, mps = chain
    key = jax.random.key(47)
    refs = [np.asarray(S.sample(mps, 8, batch_key(key, b, 2)))
            for b in range(2)]
    runtimes = api.emulated_cluster(2)
    outs, errs = {}, []

    def run(rt):
        try:
            cfg = api.SamplerConfig(runtime=rt, backend="streamed",
                                    segment_len=4)
            with api.SamplingSession(root, cfg) as sess:
                with api.SamplingService() as svc:
                    h = svc.submit(sess, n_samples=16, key=key,
                                   macro_batches=2)
                    outs[rt.process_index] = dict(h.stream(timeout=300))
        except Exception as e:              # pragma: no cover - shown below
            errs.append(repr(e))

    threads = [threading.Thread(target=run, args=(rt,)) for rt in runtimes]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=600)
    assert not errs, errs
    for p in (0, 1):
        for b in range(2):
            assert np.array_equal(outs[p][b], refs[b])
