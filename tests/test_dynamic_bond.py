"""Dynamic bond dimensions (paper §3.4.2, Table 1)."""
import jax
import pytest
import jax.numpy as jnp
import numpy as np

from repro.core import dynamic_bond as DB
from repro.core import mps as M
from repro.core import sampler as S


def test_area_law_profile_shape():
    prof = DB.area_law_profile(100, chi_max=64, n_photon=1.0)
    assert prof.shape == (100,)
    assert prof.min() >= 1 and prof.max() <= 64
    # grows from the edges, plateaus at the centre
    assert prof[0] < prof[50] and prof[-1] < prof[50]
    assert prof[50] == 64


def test_bucketize_covers_profile():
    prof = DB.area_law_profile(64, chi_max=50)
    buck = DB.bucketize(prof, [4, 16, 50])
    assert np.all(buck >= prof)
    assert set(np.unique(buck)) <= {4, 16, 50}


def test_stages_contiguous():
    buck = np.array([4, 4, 16, 16, 16, 4])
    stages = DB.stages_from_profile(buck)
    assert [(s.start, s.stop, s.chi) for s in stages] == [
        (0, 2, 4), (2, 5, 16), (5, 6, 4)]


def test_table1_metrics():
    prof = np.full(100, 50)
    m = DB.table1_metrics(prof, chi_fixed=50)
    assert m["equiv_chi"] == 50 and m["step_ratio"] == 1.0 and m["comp_ratio"] == 1.0

    prof2 = DB.area_law_profile(100, chi_max=200, n_photon=0.5)
    m2 = DB.table1_metrics(prof2, chi_fixed=200)
    assert m2["comp_ratio"] < 1.0           # dynamic χ saves compute
    assert 0.0 <= m2["step_ratio"] <= 1.0
    assert m2["equiv_chi"] <= 200


def test_single_stage_equals_uniform_sampler():
    """bucketed == χ everywhere ⇒ staged sampling is exactly the plain chain."""
    mps = M.random_linear_mps(jax.random.key(0), 6, 8, 3)
    buck = np.full(6, 8)
    a = DB.sample_staged(mps, buck, 32, jax.random.key(1))
    b = S.sample(mps, 32, jax.random.key(1))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_multi_stage_runs_and_is_valid():
    mps = M.gbs_like_mps(jax.random.key(2), 12, 16, 3)
    prof = DB.area_law_profile(12, chi_max=16, n_photon=1.0)
    buck = DB.bucketize(prof, [4, 8, 16])
    out = DB.sample_staged(mps, buck, 64, jax.random.key(3))
    assert out.shape == (64, 12)
    assert int(out.min()) >= 0 and int(out.max()) < 3


@pytest.mark.slow
def test_staged_distribution_close_on_low_rank_state():
    """On a state whose true bond rank ≤ the bucket, truncation is lossless:
    build a χ=8 MPS that actually has rank 4 on the edge bonds."""
    key = jax.random.key(4)
    base = M.random_linear_mps(key, 6, 4, 2)         # true rank 4
    # embed into χ=8 with zero padding
    g = jnp.zeros((6, 8, 8, 2), dtype=base.gammas.dtype)
    g = g.at[:, :4, :4, :].set(base.gammas)
    lam = jnp.zeros((6, 8), dtype=base.lambdas.dtype).at[:, :4].set(base.lambdas)
    big = M.MPS(g, lam, "linear")

    buck = np.array([4, 4, 8, 8, 4, 4])
    staged = DB.sample_staged(big, buck, 30_000, jax.random.key(5))
    probs = M.enumerate_probabilities(base)
    idx = np.ravel_multi_index(np.asarray(staged).T, (2,) * 6)
    emp = np.bincount(idx, minlength=2 ** 6) / 30_000
    tv = 0.5 * np.abs(emp - probs).sum()
    assert tv < 4.0 * np.sqrt(2 ** 6 / 30_000), tv
