"""Checkpointing: sharding-aware store + exact mid-chain sampler resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import sampler_state as SS
from repro.checkpoint import store
from repro.core import mps as M
from repro.core import sampler as S


def _tree(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "layers": {"w": jax.random.normal(k1, (4, 8), jnp.float32),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "embed": jax.random.normal(k2, (16, 4), jnp.float64),
        "step_count": jnp.asarray(7, jnp.int32),
        "nested": [jax.random.normal(k3, (3,)), jnp.asarray(1.5)],
    }


def test_save_load_roundtrip(tmp_path):
    tree = _tree(jax.random.key(0))
    store.save_checkpoint(str(tmp_path), 42, tree, {"note": "hello"})
    loaded, step, extra = store.load_checkpoint(str(tmp_path), tree)
    assert step == 42 and extra == {"note": "hello"}
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, dtype=np.float64),
                                      np.asarray(b, dtype=np.float64))
        assert a.dtype == b.dtype


def test_latest_and_prune(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        store.save_checkpoint(str(tmp_path), s, tree)
    assert store.latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3                       # keep-last-3 pruning


def test_atomicity_no_tmp_left(tmp_path):
    tree = {"x": jnp.arange(4.0)}
    store.save_checkpoint(str(tmp_path), 1, tree)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_load_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        store.load_checkpoint(str(tmp_path / "nope"), {"x": jnp.zeros(1)})


def test_sampler_resume_exact(tmp_path, linear_mps_10x6):
    """Paper §4.1: same seeds ⇒ same samples across a crash/restart."""
    mps = linear_mps_10x6
    cfg = S.SamplerConfig()
    state0 = S.init_state(mps, 32, jax.random.key(9), cfg)
    full = S.sample_chain(mps, state0, cfg)

    # run to site 4, checkpoint, "crash", reload, resume
    head = M.MPS(mps.gammas[:4], mps.lambdas[:4], mps.semantics)
    part = S.sample_chain(head, state0, cfg)
    SS.save_sampler_state(str(tmp_path), 4, part.state,
                          np.asarray(part.samples))

    site, state, samples_so_far = SS.load_sampler_state(str(tmp_path))
    assert site == 4
    rest = S.sample_resumable(mps, state, site, cfg)
    stitched = np.concatenate([samples_so_far, np.asarray(rest.samples)], axis=0)
    np.testing.assert_array_equal(stitched, np.asarray(full.samples))


def test_sampler_state_key_roundtrip(tmp_path):
    mps = M.random_linear_mps(jax.random.key(1), 4, 4, 2)
    st = S.init_state(mps, 8, jax.random.key(123))
    SS.save_sampler_state(str(tmp_path), 0, st, np.zeros((0, 8)))
    _, loaded, _ = SS.load_sampler_state(str(tmp_path), 0)
    assert jnp.all(jax.random.key_data(loaded.key)
                   == jax.random.key_data(st.key))
