"""Pallas kernels vs. pure-jnp oracles — shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.contract_measure import contract_measure as cm_kernel
from repro.kernels.displacement_expm import displacement_expm as de_kernel


@pytest.mark.parametrize("n,chi,d", [
    (8, 16, 2), (16, 32, 3), (32, 64, 4), (64, 128, 3), (128, 256, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_contract_measure_shapes(n, chi, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    env = jax.random.uniform(k1, (n, chi), dtype=dtype)
    gamma = jax.random.uniform(k2, (chi, chi, d), dtype=dtype)
    lam = jax.random.uniform(k3, (chi,), dtype=dtype)
    t_ref, p_ref = ref.contract_measure_ref(env, gamma, lam)
    t_k, p_k = cm_kernel(env, gamma, lam, bn=min(n, 32), br=min(chi, 64),
                         bl=min(chi, 64), interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 1e-9
    np.testing.assert_allclose(np.asarray(t_k), np.asarray(t_ref), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_ref), rtol=tol,
                               atol=tol)


def test_contract_measure_bf16_inputs():
    """The paper's TF32 tier → bf16 inputs, fp32 accumulate."""
    k1, k2, k3 = jax.random.split(jax.random.key(1), 3)
    env = jax.random.uniform(k1, (16, 32), dtype=jnp.float32).astype(jnp.bfloat16)
    gamma = jax.random.uniform(k2, (32, 32, 3), dtype=jnp.float32).astype(jnp.bfloat16)
    lam = jax.random.uniform(k3, (32,), dtype=jnp.float32).astype(jnp.bfloat16)
    t_k, p_k = cm_kernel(env, gamma, lam, bn=16, br=32, bl=32, interpret=True)
    assert t_k.dtype == jnp.float32           # upcast accumulate
    t_ref, _ = ref.contract_measure_ref(env.astype(jnp.float32),
                                        gamma.astype(jnp.float32),
                                        lam.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(t_k), np.asarray(t_ref),
                               rtol=2e-2, atol=2e-2)


def test_contract_measure_multi_tile_reduction():
    """Force a >1 l-tile grid so the VMEM accumulator path is exercised."""
    env = jax.random.uniform(jax.random.key(2), (8, 64), dtype=jnp.float32)
    gamma = jax.random.uniform(jax.random.key(3), (64, 64, 2), dtype=jnp.float32)
    lam = jax.random.uniform(jax.random.key(4), (64,), dtype=jnp.float32)
    t_ref, p_ref = ref.contract_measure_ref(env, gamma, lam)
    t_k, p_k = cm_kernel(env, gamma, lam, bn=8, br=16, bl=16, interpret=True)
    np.testing.assert_allclose(np.asarray(t_k), np.asarray(t_ref), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_ref), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("b,d", [(128, 3), (128, 4), (256, 8), (128, 16)])
def test_displacement_kernel_vs_ref(b, d):
    kr, ki = jax.random.split(jax.random.key(5))
    mre = 0.4 * jax.random.normal(kr, (b,), dtype=jnp.float32)
    mim = 0.4 * jax.random.normal(ki, (b,), dtype=jnp.float32)
    ore, oim = de_kernel(mre, mim, d, bb=128, interpret=True)
    rre, rim = ref.displacement_zassenhaus_ref(mre, mim, d)
    tol = 3e-5 * d            # fp32 kernel vs f64 oracle; coeffs grow with d
    np.testing.assert_allclose(np.asarray(ore), np.asarray(rre), atol=tol)
    np.testing.assert_allclose(np.asarray(oim), np.asarray(rim), atol=tol)


def test_displacement_kernel_mu_zero():
    """μ=0 → identity matrix (guards the log(r)=log(0) branch)."""
    mre = jnp.zeros((128,), jnp.float32)
    mim = jnp.zeros((128,), jnp.float32)
    ore, oim = de_kernel(mre, mim, 5, bb=128, interpret=True)
    eye = np.eye(5, dtype=np.float32)
    np.testing.assert_allclose(np.asarray(ore[0]), eye, atol=1e-6)
    np.testing.assert_allclose(np.asarray(oim[0]), 0.0, atol=1e-6)


def test_ops_wrappers_route_dispatch():
    env = jax.random.uniform(jax.random.key(6), (32, 64), dtype=jnp.float32)
    gamma = jax.random.uniform(jax.random.key(7), (64, 64, 3), dtype=jnp.float32)
    lam = jax.random.uniform(jax.random.key(8), (64,), dtype=jnp.float32)
    t1, p1 = ops.contract_measure(env, gamma, lam, kernels="pallas")
    t2, p2 = ops.contract_measure(env, gamma, lam, kernels="xla")
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-5)

    mu = (0.3 * jax.random.normal(jax.random.key(9), (128,))
          + 0.3j * jax.random.normal(jax.random.key(10), (128,)))
    d1 = ops.displacement_matrices(mu, 6, use_kernel=True)
    d2 = ops.displacement_matrices(mu, 6, use_kernel=False)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)


@pytest.mark.parametrize("kernels", ["pallas", "xla", "auto"])
def test_collapse_rescale_dispatch(kernels):
    """The satellite fix: collapse_rescale now reaches the collapse_select
    kernel through the dispatch layer instead of always calling the ref
    (and no longer needs the materialized temp at all)."""
    env = jax.random.uniform(jax.random.key(11), (16, 8), dtype=jnp.float64)
    gamma = jax.random.uniform(jax.random.key(13), (8, 8, 3),
                               dtype=jnp.float64)
    samples = jax.random.randint(jax.random.key(12), (16,), 0, 3)
    out = ops.collapse_rescale(env, gamma, samples, kernels=kernels)
    assert out.shape == (16, 8)
    np.testing.assert_allclose(np.asarray(jnp.max(jnp.abs(out), axis=1)), 1.0)
    # equals collapse of the materialized temp + per-sample rescale
    temp = np.einsum("nl,lrs->nrs", np.asarray(env), np.asarray(gamma))
    picked = np.take_along_axis(temp,
                                np.asarray(samples)[:, None, None],
                                axis=2)[:, :, 0]
    m = np.abs(picked).max(axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(out), picked / m, rtol=1e-12)


@pytest.mark.parametrize("b,s,h,kvh,dh,causal", [
    (2, 64, 4, 2, 32, True),
    (1, 128, 4, 4, 16, True),
    (2, 64, 8, 2, 32, False),
    (1, 64, 6, 1, 64, True),          # MQA
])
@pytest.mark.slow
def test_flash_attention_vs_ref(b, s, h, kvh, dh, causal):
    from repro.kernels.flash_attention import flash_attention
    q = jax.random.normal(jax.random.key(0), (b, s, h, dh), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (b, s, kvh, dh), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (b, s, kvh, dh), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, bq=32, bk=32,
                          interpret=True)
    r = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=3e-6)


@pytest.mark.slow
def test_flash_attention_bf16():
    from repro.kernels.flash_attention import flash_attention
    q = jax.random.normal(jax.random.key(0), (1, 64, 4, 32), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (1, 64, 2, 32), jnp.float32)
    out = flash_attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                          v.astype(jnp.bfloat16), bq=32, bk=32,
                          interpret=True)
    r = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out.astype(jnp.float32)),
                               np.asarray(r), atol=3e-2)


@pytest.mark.parametrize("n,L,R,d", [(32, 64, 64, 3), (64, 96, 128, 4),
                                     (16, 32, 32, 2)])
def test_collapse_select_vs_ref(n, L, R, d):
    from repro.kernels.collapse_select import collapse_select
    env = jax.random.uniform(jax.random.key(0), (n, L), dtype=jnp.float32)
    gamma = jax.random.uniform(jax.random.key(1), (L, R, d), dtype=jnp.float32)
    samples = jax.random.randint(jax.random.key(2), (n,), 0, d)
    out = collapse_select(env, gamma, samples, bn=16, br=32, bl=32,
                          interpret=True)
    r = ref.collapse_select_ref(env, gamma, samples)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=1e-5,
                               atol=1e-5)


def test_measure_first_equals_contract_measure():
    """The tp-3 associativity identity: env@(Γ·Λ) == measure(env·Γ)."""
    env = jax.random.uniform(jax.random.key(3), (32, 64), dtype=jnp.float64)
    gamma = jax.random.uniform(jax.random.key(4), (64, 64, 3), dtype=jnp.float64)
    lam = jax.random.uniform(jax.random.key(5), (64,), dtype=jnp.float64)
    p1 = ref.measure_first_probs_ref(env, gamma, lam)
    _, p2 = ref.contract_measure_ref(env, gamma, lam)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-12)
