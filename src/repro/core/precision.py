"""Adaptive mixed precision (paper §3.3).

Three scaling modes for the left environment:

- ``none``        : no rescaling — reproduces the Fig. 6 underflow failure.
- ``global``      : the [19] auto-scale — one scalar (the global max) per
                    micro batch.  Fixes the shift *of the mean* but not the
                    inter-sample range expansion (Fig. 5).
- ``per_sample``  : the paper's contribution — each sample is rescaled by its
                    own max.  Because Alg. 1's measurement is linear in the
                    environment and immediately normalised, the factor cancels
                    and no reverse-scaling vector is needed.

``rescale`` returns the rescaled tensor plus per-sample log10 of the factor so
callers that *do* need absolute magnitudes (e.g. amplitude estimation) can
recover them — the sampler just accumulates it as a diagnostic.

The compute-precision policy (TF32-on-A100 → bf16-on-MXU with fp32
accumulation) lives here too; see DESIGN.md §2 hardware adaptation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def real_dtype_of(dtype) -> jnp.dtype:
    return jnp.zeros((), dtype=dtype).real.dtype


def rescale(env: Array, mode: str = "per_sample") -> tuple[Array, Array]:
    """Rescale env (N, chi); returns (env', log10_per_sample_factor (N,))."""
    n = env.shape[0]
    rdt = real_dtype_of(env.dtype)
    if mode == "none":
        return env, jnp.zeros((n,), dtype=rdt)
    a = jnp.abs(env)
    if mode == "global":
        m = jnp.max(a)
        factor = jnp.where(m > 0, m, 1.0).astype(rdt)
        return env / factor, jnp.broadcast_to(jnp.log10(factor), (n,))
    if mode == "per_sample":
        m = jnp.max(a, axis=1, keepdims=True)                 # (N, 1)
        factor = jnp.where(m > 0, m, 1.0).astype(rdt)
        return env / factor, jnp.log10(factor[:, 0])
    raise ValueError(f"unknown scaling mode: {mode}")


def sample_range_stats(env: Array) -> dict[str, Array]:
    """The Fig. 5 axes: per-sample max and max/min-nonzero ratio."""
    a = jnp.abs(env)
    smax = jnp.max(a, axis=1)
    smin = jnp.min(jnp.where(a > 0, a, jnp.inf), axis=1)
    return {"sample_max": smax, "range_ratio": smax / smin}


# ---------------------------------------------------------------------------
# Compute-precision policies (TPU adaptation of the paper's TF32/FP16 tiers)
# ---------------------------------------------------------------------------

POLICIES = {
    # name: (storage dtype, gemm input dtype, accumulation dtype)
    "fp64": (jnp.float64, jnp.float64, jnp.float64),
    "fp32": (jnp.float32, jnp.float32, jnp.float32),
    # paper's TF32 tier → TPU bf16 inputs + fp32 accumulate on the MXU
    "mxu_bf16": (jnp.float32, jnp.bfloat16, jnp.float32),
    # paper's FP16-storage tier → bf16 storage (same exponent range as fp32),
    # upcast at contraction.  Halves I/O / bcast / memcpy exactly as §3.3.2.
    "store_bf16": (jnp.bfloat16, jnp.bfloat16, jnp.float32),
}


def policy_dtypes(name: str):
    try:
        return POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown precision policy {name!r}; have {list(POLICIES)}")


def gemm(a: Array, b: Array, policy: str = "fp32") -> Array:
    """dot(a, b) under a named precision policy (contraction over a's last dim)."""
    _, in_dt, acc_dt = policy_dtypes(policy)
    return jax.lax.dot_general(
        a.astype(in_dt), b.astype(in_dt),
        (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=acc_dt,
    )
