"""Sequential MPS chain sampler (the paper's Figure 1 workflow + Alg. 1).

The sampler walks the chain left→right carrying a *left environment*
``env[N, chi]``.  At each site i:

  1. contraction:  temp[n, r, s] = Σ_l env[n, l] · Γ_i[l, r, s]
  2. measurement (Alg. 1):
       linear:  probs[n, s] = Σ_r temp[n, r, s] Λ_i[r]
       born:    probs[n, s] = Σ_r |temp[n, r, s] λ_i[r]|²
     normalise → cumsum → inverse-CDF draw with one uniform per sample
  3. collapse:  env'[n, r] = temp[n, r, s_n]   (born: ×λ_i[r])
  4. per-sample adaptive rescale (§3.3) so the dynamic range stays bounded.

The chain is a single ``lax.scan`` over the stacked Γ (static shapes), so it
jits once regardless of M.  Micro-batching (N₂) happens *outside* via vmap-
like batching of the whole scan; macro-batching (N₁) and the double-buffered
Γ streaming live in ``data/gamma_store.py`` + ``core/parallel.py``.

The site body itself is dispatched through ``kernels/dispatch.py``:
``SamplerConfig.kernels`` picks the fused Pallas site-step pipeline
(``"pallas"`` — contract → measure → draw → collapse → rescale with the
(N, χ, d) intermediate VMEM-resident, never in HBM) or the reference XLA
ops (``"xla"``).  Randomness is identical either way: the per-site uniform
is drawn from ``fold_in(key, site)`` *before* the dispatch, so both
backends consume the same bits and emit bit-identical samples (§4.1).

This module is the innermost data plane; the application front door that
composes it with DP/TP placement, streaming, dynamic χ, and checkpointing
is :class:`repro.api.SamplingSession`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.mps import MPS
from repro.core import precision
from repro.kernels import dispatch
from repro.kernels.site_impls import draw_from_uniform, site_probs_dtype

Array = jax.Array


class SamplerState(NamedTuple):
    """Carry of the chain scan — also the unit of mid-chain checkpointing."""
    env: Array          # (N, chi) left environment (rescaled)
    key: Array          # *base* PRNG key — never consumed; site i draws with
                        # fold_in(key, i), so every parallel schedule (DP, TP
                        # single/double, the [19] pipeline) that shares the
                        # base key draws identical randoms per site.
    log_scale: Array    # (N,) accumulated log10 of the per-sample rescale factors


class SampleResult(NamedTuple):
    samples: Array      # (M, N) int32 outcomes  (site-major, transpose for user)
    state: SamplerState
    site_stats: Array   # (M, 3) [max |env|, min nonzero |env|, mean photon] diagnostics


def draw_from_probs(probs: Array, key: Array) -> Array:
    """Alg. 1 lines 2-4: normalise, cumsum, threshold draw.  probs (N, d) ≥ 0."""
    u = jax.random.uniform(key, (probs.shape[0], 1), dtype=probs.dtype)
    return draw_from_uniform(probs, u)


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    semantics: str = "linear"          # "linear" | "born"
    scaling: str = "per_sample"        # "none" | "global" | "per_sample"  (§3.3)
    compute_dtype: Optional[jnp.dtype] = None  # e.g. jnp.bfloat16 for MXU path
    kernels: str = "xla"               # "pallas" (fused site step) | "xla" | "auto"


def init_state(mps: MPS, n_samples: int, key: Array,
               config: SamplerConfig = SamplerConfig()) -> SamplerState:
    """Boundary condition: env starts as the one-hot left boundary row."""
    chi = mps.chi
    dtype = mps.gammas.dtype
    if dtype in (jnp.bfloat16, jnp.float16):     # low-precision Γ *storage*
        dtype = jnp.float32                      # never a low-precision env
    env = jnp.zeros((n_samples, chi), dtype=dtype).at[:, 0].set(1.0)
    log_scale = jnp.zeros((n_samples,), dtype=precision.real_dtype_of(dtype))
    return SamplerState(env, key, log_scale)


def site_step(state: SamplerState, site: tuple[Array, Array, Array],
              config: SamplerConfig) -> tuple[SamplerState, tuple[Array, Array]]:
    """One site of the chain: contract → measure → collapse → rescale.

    The pipeline body is a dispatched :func:`kernels.dispatch.get_site_op`
    — the fused Pallas kernel when ``config.kernels`` resolves to
    ``"pallas"``, the reference XLA ops otherwise.  The inverse-CDF uniform
    is drawn here (same fold_in, same shape/dtype as always), so the two
    backends are draw-for-draw identical.
    """
    gamma, lam, site_idx = site            # (chi, chi, d), (chi,), () int32
    env, key, log_scale = state
    sub = jax.random.fold_in(key, site_idx)

    u = jax.random.uniform(
        sub, (env.shape[0], 1),
        dtype=site_probs_dtype(env, gamma, lam, config.semantics,
                               config.compute_dtype))
    op = dispatch.get_site_op("site_step", config.semantics, config.kernels)
    new_env, samples, dlog = op(env, gamma, lam, u, scaling=config.scaling,
                                compute_dtype=config.compute_dtype)

    absenv = jnp.abs(new_env)
    stats = jnp.stack([
        jnp.max(absenv),
        jnp.min(jnp.where(absenv > 0, absenv, jnp.inf)),
        jnp.mean(samples.astype(absenv.dtype)),
    ])
    return SamplerState(new_env, key, log_scale + dlog), (samples, stats)


@partial(jax.jit, static_argnames=("config",))
def sample_chain(mps: MPS, state: SamplerState,
                 config: SamplerConfig = SamplerConfig(),
                 start_site: Array | int = 0) -> SampleResult:
    """Run the full chain with a scan over stacked sites.

    ``start_site`` offsets the fold_in site indices so a resumed chain draws
    the exact randoms the uninterrupted chain would have drawn.  It is a
    *traced* argument: the streaming engine calls this once per fixed-size
    segment with varying offsets and reuses a single compilation.
    """
    def body(carry, site):
        carry, (s, st) = site_step(carry, site, config)
        return carry, (s, st)

    sites = (jnp.asarray(start_site, dtype=jnp.int32)
             + jnp.arange(mps.n_sites, dtype=jnp.int32))
    state, (samples, stats) = jax.lax.scan(
        body, state, (mps.gammas, mps.lambdas, sites))
    return SampleResult(samples, state, stats)


def sample(mps: MPS, n_samples: int, key: Array,
           config: SamplerConfig = SamplerConfig()) -> Array:
    """User-facing: returns (N, M) outcomes."""
    state = init_state(mps, n_samples, key, config)
    result = sample_chain(mps, state, config)
    return result.samples.T


def sample_resumable(mps: MPS, state: SamplerState, start_site: int,
                     config: SamplerConfig = SamplerConfig()) -> SampleResult:
    """Resume mid-chain from a checkpointed ``SamplerState`` at ``start_site``.

    Restart is exact: the carried PRNG key reproduces the same draws the
    uninterrupted chain would have made (paper §4.1 seed-consistency).
    """
    rest = MPS(mps.gammas[start_site:], mps.lambdas[start_site:], mps.semantics)
    return sample_chain(rest, state, config, start_site=start_site)


# ---------------------------------------------------------------------------
# Micro/macro batching (paper §3.1): macro batch N₁ lives in memory as the
# left environment; micro batches N₂ bound the (N₂, chi, d) intermediate.
# ---------------------------------------------------------------------------

def sample_batched(mps: MPS, n_samples: int, key: Array, micro_batch: int,
                   config: SamplerConfig = SamplerConfig()) -> Array:
    """Split N into micro batches of N₂ and scan them sequentially.

    Mirrors the memory model Eq. (3): only one (N₂, chi, d) intermediate is
    alive at a time while the (N, chi) macro environment persists.
    """
    assert n_samples % micro_batch == 0, (n_samples, micro_batch)
    n_micro = n_samples // micro_batch
    keys = jax.random.split(key, n_micro)

    def one(k):
        return sample(mps, micro_batch, k, config)

    outs = jax.lax.map(one, keys)           # (n_micro, N₂, M)
    return outs.reshape(n_samples, mps.n_sites)
