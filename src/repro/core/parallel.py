"""Multi-level parallel MPS sampling (paper §3.1–3.2) + the [19] baseline.

Mesh layout (shared with the LM stack, see launch/mesh.py):

    ("data", "model")            single pod, p = p₁ × p₂
    ("pod", "data", "model")     multi-pod; "pod" is folded into data parallel

* **Data parallel** (§3.1): samples are independent; each of the p₁ data
  groups owns N/p₁ samples and walks the full chain.  Γ is replicated
  (broadcast from the loader — in-XLA this is the implicit all-gather of a
  fully-replicated operand; the host-side streaming version lives in
  ``data/gamma_store.py``).

* **Tensor parallel** (§3.2): within a group, Γᵢ and the environment are
  split along the bond axis χ over p₂ workers.

  - ``single``-site: split-K GEMM over the *left* bond; measurement is
    computed from partial probabilities (a tiny ``psum`` of (N₂, d)) *before*
    the big collective, so the wire carries the measured (N₂, χ) environment
    — a factor d smaller — via ``psum_scatter``.  Bandwidth-optimal.
    (Valid because Alg. 1 is linear in the environment; for ``born``
    semantics this is invalid — |Σ·|² ≠ Σ|·|² — so we fall back to
    ``psum_scatter`` of the unmeasured (N₂, χ, d) + tiny psum of partial
    square-weights.)
  - ``double``-site: one ``psum`` (AllReduce) of the unmeasured (N₂, χ, d)
    every *two* sites.  The even site's Γ is split along the *right* bond, so
    its GEMM is communication-free and leaves the environment pre-sliced for
    the next odd site.  Half the collective count → latency-optimal; odd-site
    measurement is replicated (the η=1 vs η=p₂ trade of Eq. 7).

All schemes draw identical randoms within a TP group (the key is replicated
over "model"), so DP and both TP schedules produce bit-identical samples for
the same seed — asserted in tests.

This module is the *data plane*.  The only application front door is
:class:`repro.api.SamplingSession` — the deprecation-shimmed legacy entry
points (``multilevel_sample`` / ``dp_sample`` / ``baseline19_sample``)
were removed one release after the facade shipped, as scheduled; the
internal ``_multilevel_sample`` / ``_baseline19_sample`` /
``sample_segment`` callables below are what the registered backends route
through.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.core.mps import MPS
from repro.core import precision
from repro.core.sampler import SamplerConfig, draw_from_probs
from repro.kernels import dispatch
from repro.kernels.site_impls import contract_parallel, measure_probs_xla

Array = jax.Array


def _env_dtype(gamma_dtype):
    """Environments accumulate across sites — keep them ≥ fp32 even when Γ
    is stored low-precision (§3.3.2: storage ≠ compute precision)."""
    return (jnp.float32 if gamma_dtype in (jnp.bfloat16, jnp.float16)
            else gamma_dtype)


def _contract(env: Array, gamma: Array, config: SamplerConfig) -> Array:
    """temp[n,r,s] = Σ_l env[n,l] Γ[l,r,s] under the configured precision
    (one shared implementation with the dispatched xla cells)."""
    return contract_parallel(env, gamma, config.compute_dtype)


_measure = measure_probs_xla


def _tp_rescale(env: Array, mode: str, axis: Optional[str] = None
                ) -> tuple[Array, Array]:
    """Adaptive rescale of a (possibly bond-sharded) environment.

    Mirrors ``precision.rescale`` with the max taken across the TP group
    (``pmax`` over ``axis``) when the environment is sharded, so every shard
    divides by the same factor.  Returns (env', per-sample log10 factor) —
    the same diagnostic the in-memory path accumulates in
    ``SamplerState.log_scale``.
    """
    rdt = precision.real_dtype_of(env.dtype)
    n = env.shape[0]
    if mode == "none":
        return env, jnp.zeros((n,), dtype=rdt)
    a = jnp.abs(env)
    if mode == "per_sample":
        m = jnp.max(a, axis=1, keepdims=True)
        if axis is not None:
            m = jax.lax.pmax(m, axis)
        factor = jnp.where(m > 0, m, 1.0).astype(rdt)
        return env / factor, jnp.log10(factor[:, 0])
    if mode == "global":
        m = jnp.max(a)
        if axis is not None:
            m = jax.lax.pmax(m, axis)
        factor = jnp.where(m > 0, m, 1.0).astype(rdt)
        return env / factor, jnp.broadcast_to(jnp.log10(factor), (n,))
    raise ValueError(f"unknown scaling mode: {mode}")


# ---------------------------------------------------------------------------
# Tensor parallel — single-site (ReduceScatter) schedule
# ---------------------------------------------------------------------------

def _tp_single_site_step(env, gamma_l, lam, key, config, axis,
                         wire_dtype=None):
    """One site with env (N, χ/p₂) and Γ sharded on the left bond.

    Returns (new sharded env, per-sample log10 rescale factor, samples).
    """
    semantics = config.semantics
    dtype = env.dtype
    if semantics == "linear":
        # contract + partial measure in one dispatched op (the Pallas cell
        # fuses them so the partial temp makes one HBM pass, not two), then
        # measure-before-communicate: tiny psum of (N, d) partial probs
        cm = dispatch.get_site_op("contract_measure", semantics,
                                  config.kernels)
        temp_partial, probs_partial = cm(env, gamma_l, lam,
                                         semantics=semantics,
                                         compute_dtype=config.compute_dtype)
        probs = jax.lax.psum(probs_partial, axis)
        samples = draw_from_probs(probs, key)
        collapsed = jnp.take_along_axis(
            temp_partial, samples[:, None, None], axis=2)[:, :, 0]  # (N, χ) partial
        if wire_dtype is not None:
            collapsed = collapsed.astype(wire_dtype)
        env_new = jax.lax.psum_scatter(
            collapsed, axis, scatter_dimension=1, tiled=True)       # (N, χ/p₂)
        env_new = env_new.astype(dtype)
    else:
        # born: must sum split-K partials before squaring (|Σ·|² ≠ Σ|·|², so
        # there is no valid fused-measure cell here — stays XLA by design).
        temp_partial = _contract(env, gamma_l, config)    # (N, χ, d) partial
        temp = jax.lax.psum_scatter(temp_partial, axis,
                                    scatter_dimension=1, tiled=True)  # (N, χ/p₂, d)
        p2 = axis_size(axis)
        idx = jax.lax.axis_index(axis)
        lam_shard = jax.lax.dynamic_slice_in_dim(
            lam, idx * (lam.shape[0] // p2), lam.shape[0] // p2)
        probs = jax.lax.psum(_measure(temp, lam_shard, semantics), axis)
        samples = draw_from_probs(probs, key)
        env_new = jnp.take_along_axis(
            temp, samples[:, None, None], axis=2)[:, :, 0] * lam_shard[None, :]
    # per-sample rescale: the max must be consistent across the TP group
    env_new, dlog = _tp_rescale(env_new, config.scaling, axis)
    return env_new, dlog, samples


def _tp_single_site_step_measure_first(env, gamma_l, w_l, key, config, axis,
                                       wire_dtype=None):
    """tp-3: probs from the tiny env@W GEMM; collapse via select-GEMM.

    env (N, χ/p₂) sharded; gamma_l (χ/p₂, χ, d); w_l (χ/p₂, d).  Both ops
    are dispatched: the Pallas cells are ``kernels/site_step.measure_probs``
    and ``kernels/collapse_select.collapse_select`` (masked operand
    VMEM-resident — the (N, χ, d) temp never exists anywhere).
    """
    dtype = env.dtype
    measure_op = dispatch.get_site_op("measure", "linear", config.kernels)
    collapse_op = dispatch.get_site_op("collapse", "linear", config.kernels)
    probs = jax.lax.psum(
        measure_op(env, w_l, compute_dtype=config.compute_dtype)
        .astype(dtype), axis)
    samples = draw_from_probs(probs, key)
    collapsed = collapse_op(env, gamma_l, samples,
                            compute_dtype=config.compute_dtype)  # (N, χ)
    if wire_dtype is not None:
        collapsed = collapsed.astype(wire_dtype)
    env_new = jax.lax.psum_scatter(
        collapsed, axis, scatter_dimension=1, tiled=True).astype(dtype)
    env_new, dlog = _tp_rescale(env_new, config.scaling, axis)
    return env_new, dlog, samples


# ---------------------------------------------------------------------------
# Tensor parallel — double-site (AllReduce) schedule
# ---------------------------------------------------------------------------

def _tp_double_site_pair(env, gamma_odd_l, lam_odd, gamma_even_r, lam_even,
                         key_pair, config, axis, wire_dtype=None):
    """Two sites per round: AllReduce once, even site communication-free."""
    semantics = config.semantics
    k_odd, k_even = key_pair
    fused = (dispatch.resolve_kernels(config.kernels) == "pallas"
             and semantics == "linear")

    # --- odd site: split-K over left bond, AllReduce the unmeasured temp ----
    if fused and wire_dtype is None:
        # Pallas cell: partial probs come out of the contraction's output
        # tiles (one HBM pass over the partial temp instead of two); the
        # measurement linearity makes psum-of-partial-measures ≡ measure-of-
        # psum, and the extra (N, d) psum is noise next to the (N, χ, d) one.
        # With a wire_dtype the XLA reference measures the *wire-rounded*
        # psummed temp, which partial measures cannot reproduce — that cell
        # keeps the reference structure below so pallas ≡ xla stays exact.
        cm = dispatch.get_site_op("contract_measure", semantics,
                                  config.kernels)
        temp, probs_partial = cm(env, gamma_odd_l, lam_odd,
                                 semantics=semantics,
                                 compute_dtype=config.compute_dtype)
        temp = jax.lax.psum(temp, axis).astype(env.dtype)   # (N, χ, d) full
        probs = jax.lax.psum(probs_partial, axis)
    else:
        temp = _contract(env, gamma_odd_l, config)
        if wire_dtype is not None:
            temp = temp.astype(wire_dtype)
        temp = jax.lax.psum(temp, axis).astype(env.dtype)   # (N, χ, d) full
        probs = _measure(temp, lam_odd, semantics)      # replicated (η overhead)
    samples_odd = draw_from_probs(probs, k_odd)
    env_full = jnp.take_along_axis(temp, samples_odd[:, None, None], axis=2)[:, :, 0]
    if semantics == "born":
        env_full = env_full * lam_odd[None, :]
    # full (replicated) environment: every shard computes the same max
    env_full, dlog_odd = _tp_rescale(env_full, config.scaling)

    # --- even site: Γ split on the right bond; local GEMM, no collective ----
    p2 = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    lam_shard = jax.lax.dynamic_slice_in_dim(
        lam_even, idx * (lam_even.shape[0] // p2), lam_even.shape[0] // p2)
    if fused:
        cm = dispatch.get_site_op("contract_measure", semantics,
                                  config.kernels)
        temp_loc, probs_partial = cm(env_full, gamma_even_r, lam_shard,
                                     semantics=semantics,
                                     compute_dtype=config.compute_dtype)
        probs = jax.lax.psum(probs_partial, axis)          # tiny (N, d)
    else:
        temp_loc = _contract(env_full, gamma_even_r, config)  # (N, χ/p₂, d)
        probs = jax.lax.psum(_measure(temp_loc, lam_shard, semantics),
                             axis)                         # tiny
    samples_even = draw_from_probs(probs, k_even)
    env_new = jnp.take_along_axis(temp_loc, samples_even[:, None, None], axis=2)[:, :, 0]
    if semantics == "born":
        env_new = env_new * lam_shard[None, :]
    env_new, dlog_even = _tp_rescale(env_new, config.scaling, axis)
    return env_new, dlog_odd + dlog_even, (samples_odd, samples_even)


# ---------------------------------------------------------------------------
# Top-level multi-level sampler
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    scheme: str = "dp"                 # "dp" | "tp_single" | "tp_double" | "baseline19"
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    # §3.3.2 extended to the TP wire (beyond-paper, §Perf iteration tp-2):
    # cast the collapsed environment to this dtype before the big collective.
    # bf16 keeps fp32's exponent range, so with per-sample scaling the wire
    # cast cannot under/overflow — it only rounds the 8-bit mantissa.
    wire_dtype: Optional[jnp.dtype] = None
    # measure-first reformulation (beyond-paper, §Perf iteration tp-3):
    # probs = env @ (Γ·Λ) by associativity of Alg. 1, so the (N, χ, d)
    # unmeasured temp is never materialized; the collapse becomes a
    # sample-selected GEMM (kernels/collapse_select.py keeps the masked
    # operand VMEM-resident on TPU; the XLA fallback loops over the d
    # outcomes with a per-sample row mask).  Linear semantics only.
    measure_first: bool = False
    # §3.1 micro batching N₂ *per data shard*: the chain walk runs over
    # n_local/N₂ chunks with chunk keys split(shard_key, n_micro) — the
    # exact ``sampler.sample_batched`` schedule — so the (N₂, χ, d)
    # unmeasured intermediate is bounded under every DP/TP placement.
    micro_batch: Optional[int] = None


def _multilevel_sample(mesh: Mesh, mps: MPS, n_samples: int, key: Array,
                       pconfig: ParallelConfig = ParallelConfig(),
                       config: SamplerConfig = SamplerConfig()) -> Array:
    """DP over samples × TP over χ.  Returns (N, M) outcomes.

    The data plane is the segment runner below, run over the whole chain as
    one segment — an in-memory call and a streamed walk therefore share one
    code path (and one jit cache entry per shape).
    """
    if pconfig.scheme == "baseline19":
        return _baseline19_sample(mesh, mps, n_samples, key, config,
                                  pipeline_axis=pconfig.data_axes[-1])
    if pconfig.scheme not in ("dp", "tp_single", "tp_double"):
        raise ValueError(f"unknown scheme {pconfig.scheme!r}")
    env = segment_env_init(n_samples, mps.chi, mps.gammas.dtype)
    samples, _, _ = sample_segment(mesh, mps, env, key, 0, pconfig, config)
    return samples.T


# ---------------------------------------------------------------------------
# Segment runner (the shared DP×TP data plane, paper §3.1 + §3.3.2)
#
# This entry point runs ONE contiguous segment of the chain under any DP×TP
# placement, carrying the full (N, χ) left environment and the per-sample
# ``log_scale`` diagnostic between calls.  ``_multilevel_sample`` is the
# whole chain as a single segment; the streaming engine walks fixed-size
# segments through the same callable.  All PRNG draws use
# fold_in(base_key, global_site) — per micro chunk when
# ``pconfig.micro_batch`` is set, with chunk keys split(shard_key, n_micro)
# exactly as ``sampler.sample_batched`` — so a segmented walk is
# bit-identical to the corresponding single-shot schedule.  ``start_site``
# is a traced operand and the jitted shard_map callable is cached per
# (mesh, pconfig, config), so every equally-shaped segment reuses one
# compilation regardless of its chain offset (and a dynamic-χ walk costs
# one compilation per χ bucket).
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _segment_callable(mesh: Mesh, pconfig: ParallelConfig,
                      config: SamplerConfig):
    """Build the cached shard_map program for one segment of the chain.

    Key data (not typed key arrays) crosses the shard_map boundary — typed
    PRNG keys do not survive shard_map partitioning on jax 0.4.x (same
    workaround as ``baseline19_sample``).
    """
    from repro.core import sampler as S

    d_axes, m_axis = pconfig.data_axes, pconfig.model_axis
    n2 = pconfig.micro_batch

    def _with_micro(chain_fn, base, env_l, ls_l, L):
        """§3.1 micro batching under any placement: run the shard's batch
        through ``chain_fn`` whole, or as n_local/N₂ chunks with chunk keys
        split(shard_key, n_micro) — the ``sampler.sample_batched`` schedule,
        so DP/TP micro-batched walks match the in-memory batched sampler
        draw-for-draw."""
        if n2 is None:
            return chain_fn(base, env_l, ls_l)
        n_loc = env_l.shape[0]
        n_micro = n_loc // n2
        keys_c = jax.random.split(base, n_micro)

        def one(xs):
            k, e, ls = xs
            return chain_fn(k, e, ls)

        smp, env_o, ls_o = jax.lax.map(
            one, (keys_c, env_l.reshape(n_micro, n2, -1),
                  ls_l.reshape(n_micro, n2)))
        samples = jnp.transpose(smp, (1, 0, 2)).reshape(L, n_loc)
        return samples, env_o.reshape(n_loc, -1), ls_o.reshape(n_loc)

    if pconfig.scheme == "dp":

        def shard_fn(keys_local, env_l, ls_l, gammas, lambdas, start_r):
            base = jax.random.wrap_key_data(keys_local[0].astype(jnp.uint32))
            L = gammas.shape[0]
            sites = start_r + jnp.arange(L, dtype=jnp.int32)

            def chain(k, e, ls):
                def body(carry, xs):
                    g, lam, i = xs
                    st, (smp, _) = S.site_step(
                        S.SamplerState(carry[0], k, carry[1]),
                        (g, lam, i), config)
                    return (st.env, st.log_scale), smp

                (env_out, ls_out), samples = jax.lax.scan(
                    body, (e, ls), (gammas, lambdas, sites))
                return samples, env_out, ls_out   # (L, n), (n, χ), (n,)

            return _with_micro(chain, base, env_l, ls_l, L)

        return jax.jit(shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(d_axes), P(d_axes), P(d_axes), P(), P(), P()),
            out_specs=(P(None, d_axes), P(d_axes), P(d_axes)),
            check_vma=False,
        ))

    if pconfig.scheme == "tp_single":
        measure_first = (pconfig.measure_first
                         and config.semantics == "linear")

        def shard_fn(keys_local, env_l, ls_l, gammas_l, lambdas, start_r):
            base = jax.random.wrap_key_data(keys_local[0].astype(jnp.uint32))
            L = gammas_l.shape[0]
            sites = start_r + jnp.arange(L, dtype=jnp.int32)

            if measure_first:
                # per-site measure-first operator W — identical per-site
                # arithmetic to the default schedule's probs, so the tp-3
                # path stays bit-identical when segmented or micro-batched
                w_l = jnp.einsum("mlrs,mr->mls",
                                 gammas_l.astype(jnp.float32),
                                 lambdas.astype(jnp.float32))

                def chain(k, e, ls):
                    def body(carry, xs):
                        g, w, i = xs
                        env_c, dlog, smp = _tp_single_site_step_measure_first(
                            carry[0], g, w, jax.random.fold_in(k, i), config,
                            m_axis, wire_dtype=pconfig.wire_dtype)
                        return (env_c, carry[1] + dlog), smp

                    (env_out, ls_out), samples = jax.lax.scan(
                        body, (e, ls), (gammas_l, w_l, sites))
                    return samples, env_out, ls_out
            else:
                def chain(k, e, ls):
                    def body(carry, xs):
                        g, lam, i = xs
                        env_c, dlog, smp = _tp_single_site_step(
                            carry[0], g, lam, jax.random.fold_in(k, i),
                            config, m_axis, wire_dtype=pconfig.wire_dtype)
                        return (env_c, carry[1] + dlog), smp

                    (env_out, ls_out), samples = jax.lax.scan(
                        body, (e, ls), (gammas_l, lambdas, sites))
                    return samples, env_out, ls_out

            return _with_micro(chain, base, env_l, ls_l, L)

        return jax.jit(shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(d_axes), P(d_axes, m_axis), P(d_axes),
                      P(None, m_axis, None, None), P(), P()),
            out_specs=(P(None, d_axes), P(d_axes, m_axis), P(d_axes)),
            check_vma=False,
        ))

    if pconfig.scheme == "tp_double":

        def shard_fn(keys_local, env_l, ls_l, godd_l, lamo, geven_r, lame,
                     start_r):
            base = jax.random.wrap_key_data(keys_local[0].astype(jnp.uint32))
            n_pairs = godd_l.shape[0]

            def chain(k, e, ls):
                def body(carry, xs):
                    go, lo, ge, le, j = xs
                    kp = (jax.random.fold_in(k, start_r + 2 * j),
                          jax.random.fold_in(k, start_r + 2 * j + 1))
                    env_c, dlog, (so, se) = _tp_double_site_pair(
                        carry[0], go, lo, ge, le, kp, config, m_axis,
                        wire_dtype=pconfig.wire_dtype)
                    return (env_c, carry[1] + dlog), jnp.stack([so, se])

                (env_out, ls_out), samples = jax.lax.scan(
                    body, (e, ls),
                    (godd_l, lamo, geven_r, lame,
                     jnp.arange(n_pairs, dtype=jnp.int32)))
                return (samples.reshape(2 * n_pairs, e.shape[0]),
                        env_out, ls_out)

            return _with_micro(chain, base, env_l, ls_l, 2 * n_pairs)

        return jax.jit(shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(d_axes), P(d_axes, m_axis), P(d_axes),
                      P(None, m_axis, None, None), P(),
                      P(None, None, m_axis, None), P(), P()),
            out_specs=(P(None, d_axes), P(d_axes, m_axis), P(d_axes)),
            check_vma=False,
        ))

    raise ValueError(f"segment runner has no scheme {pconfig.scheme!r}")


def sample_segment(mesh: Mesh, mps: MPS, env: Array, key: Array,
                   start_site: Array | int,
                   pconfig: ParallelConfig = ParallelConfig(),
                   config: SamplerConfig = SamplerConfig(),
                   log_scale: Optional[Array] = None
                   ) -> tuple[Array, Array, Array]:
    """Run sites [start, start+L) of the chain from a full environment.

    mps holds only the segment's L site tensors; returns
    (samples (L, N) int32 site-major, env' (N, χ), log_scale' (N,)).
    ``log_scale`` is the accumulated per-sample log10 rescale factor —
    diagnostic parity with the in-memory ``SamplerState.log_scale``;
    ``None`` starts the carry at zero.
    """
    d_axes, m_axis = pconfig.data_axes, pconfig.model_axis
    p1 = 1
    for ax in d_axes:
        p1 *= mesh.shape[ax]
    n_samples, chi = env.shape
    assert n_samples % p1 == 0, (n_samples, p1)
    if pconfig.scheme != "dp":
        p2 = mesh.shape[m_axis]
        assert chi % p2 == 0, (chi, p2)
    if pconfig.micro_batch is not None:
        assert (n_samples // p1) % pconfig.micro_batch == 0, \
            (n_samples, p1, pconfig.micro_batch)
    if log_scale is None:
        log_scale = jnp.zeros((n_samples,),
                              dtype=precision.real_dtype_of(env.dtype))
    start = jnp.asarray(start_site, dtype=jnp.int32)
    dp_keys = jax.random.key_data(jax.random.split(key, p1))  # (p1, key_size)
    f = _segment_callable(mesh, pconfig, config)

    if pconfig.scheme in ("dp", "tp_single"):
        return f(dp_keys, env, log_scale, mps.gammas, mps.lambdas, start)
    if pconfig.scheme == "tp_double":
        assert mps.n_sites % 2 == 0, \
            "double-site segments need an even site count"
        return f(dp_keys, env, log_scale, mps.gammas[0::2], mps.lambdas[0::2],
                 mps.gammas[1::2], mps.lambdas[1::2], start)
    raise ValueError(f"segment runner has no scheme {pconfig.scheme!r}")


def segment_env_init(n_samples: int, chi: int, gamma_dtype) -> Array:
    """Boundary environment for site 0: one-hot row 0, full (unsharded) view.
    TP shards slice it — shard 0 holds the hot column, others zeros —
    matching ``_multilevel_sample``'s per-shard initialisation exactly."""
    env = jnp.zeros((n_samples, chi), dtype=_env_dtype(gamma_dtype))
    return env.at[:, 0].set(1.0)


# ---------------------------------------------------------------------------
# Baseline [19]: one worker per site, macro-batch pipeline over a ring
# ---------------------------------------------------------------------------

def _baseline19_sample(mesh: Mesh, mps: MPS, n_samples: int, key: Array,
                       config: SamplerConfig = SamplerConfig(),
                       pipeline_axis: str = "data",
                       n_macro: Optional[int] = None) -> Array:
    """The model-parallel scheme of [19] (Fig. 2), for comparison benches.

    p processes = M sites (p must equal M here).  The left environment of
    each macro batch flows down a ``ppermute`` chain; at time step t, worker i
    processes macro batch (t − i).  Total steps = n₁ + M − 1 (the pipeline
    fill the paper criticises).  Emitted samples: worker i produces site i's
    outcomes for every macro batch.
    """
    p = mesh.shape[pipeline_axis]
    M = mps.n_sites
    assert p == M, f"[19] binds one process per site (p={p}, M={M})"
    n1 = n_macro or config_macro_batches(n_samples)
    assert n_samples % n1 == 0, (n_samples, n1)
    N1 = n_samples // n1
    semantics = mps.semantics

    # One base key per macro batch; worker i draws with fold_in(base_b, i) —
    # the same (batch, site) schedule as the data-parallel sampler, so [19]
    # and FastMPS produce identical samples from the same seed.
    base_keys = jax.random.key_data(jax.random.split(key, n1))  # (n1, key_size)
    base_keys = jnp.broadcast_to(base_keys[:, None, :],
                                 (n1, M, base_keys.shape[-1]))

    def shard_fn(gamma, lam, keys_batch):
        # gamma (1, χ, χ, d) local site tensor; keys_batch (n1, 1, key_size)
        gamma = gamma[0]
        lam = lam[0]
        i = jax.lax.axis_index(pipeline_axis)
        T = n1 + M - 1
        chi = gamma.shape[0]
        dt = gamma.dtype

        # ring buffer: env of whichever macro batch currently sits here
        env0 = jnp.zeros((N1, chi), dt).at[:, 0].set(1.0)

        def step(carry, t):
            env_in = carry
            b = t - i                      # macro batch index at this worker
            active = (b >= 0) & (b < n1)
            kb = jax.random.fold_in(
                jax.random.wrap_key_data(
                    keys_batch[jnp.clip(b, 0, n1 - 1), 0].astype(jnp.uint32)),
                i)
            temp = jnp.einsum("nl,lrs->nrs", env_in, gamma)
            probs = _measure(temp, lam, semantics)
            s = draw_from_probs(probs, kb)
            env_out = jnp.take_along_axis(temp, s[:, None, None], axis=2)[:, :, 0]
            if semantics == "born":
                env_out = env_out * lam[None, :]
            m = jnp.max(jnp.abs(env_out), axis=1, keepdims=True)
            env_out = env_out / jnp.where(m > 0, m, 1.0)
            s = jnp.where(active, s, -1)
            # fresh batches enter at worker 0
            fresh = jnp.zeros((N1, chi), dt).at[:, 0].set(1.0)
            send = jnp.where(active, env_out, env_in)
            nxt = jax.lax.ppermute(send, pipeline_axis,
                                   [(j, (j + 1) % M) for j in range(M)])
            nxt = jnp.where(i == 0, fresh, nxt)
            return nxt, s

        _, emitted = jax.lax.scan(step, env0, jnp.arange(T))
        # emitted (T, N1): site-i outcomes of batch b are at t = b + i
        rows = jnp.arange(n1) + i
        return emitted[rows][None]          # (1, n1, N1)

    f = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(pipeline_axis), P(pipeline_axis), P(None, pipeline_axis)),
        out_specs=P(pipeline_axis), check_vma=False,
    )
    out = f(mps.gammas, mps.lambdas, base_keys)  # (M, n1, N1)
    return out.transpose(1, 2, 0).reshape(n_samples, M)


def config_macro_batches(n_samples: int, target: int = 4) -> int:
    """n₁: number of macro batches (kept small for the CPU test harness)."""
    for n1 in range(target, 0, -1):
        if n_samples % n1 == 0:
            return n1
    return 1
