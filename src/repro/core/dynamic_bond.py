"""Dynamic bond dimensions (paper §3.4.2, Table 1).

The area law makes entanglement — and hence the useful bond dimension — grow
from the chain edges towards the centre.  A fixed χ wastes compute at the
edges.  FastMPS assigns a per-site χᵢ following the entanglement profile and
only computes the region under the profile.

XLA needs static shapes, so we realize per-site χ as *buckets*: χᵢ is
quantized to a small set of plateau values; consecutive same-bucket sites form
a *stage*, and the sampler runs one scan per stage with the environment
sliced/padded at stage boundaries.  The Table 1 accounting (equivalent χ,
step ratio, comp ratio) is computed from the un-bucketed profile.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mps import MPS
from repro.core import sampler as sampler_mod

Array = jax.Array


def area_law_profile(n_sites: int, chi_max: int, n_photon: float = 1.0,
                     d: int = 3) -> np.ndarray:
    """Entanglement-derived per-site χ profile.

    The bond at cut i can carry at most min(d**min(i+1, M-1-i), …) states
    (exact-simulation bound); physical entanglement saturates at a plateau set
    by the photon number.  We model the paper's Fig. 8: exponential growth
    from both edges, plateau χ_max in the centre.
    """
    i = np.arange(n_sites, dtype=np.float64)
    dist = np.minimum(i + 1, n_sites - 1 - i)          # distance to nearest edge
    log_bound = np.minimum(dist * np.log1p(n_photon), np.log(1e18))
    chi = np.minimum(np.exp(log_bound), chi_max)
    return np.maximum(chi.astype(np.int64), 1)


def bucketize(profile: np.ndarray, buckets: Sequence[int]) -> np.ndarray:
    """Round each site's χ up to the nearest allowed bucket."""
    buckets = np.sort(np.asarray(buckets))
    idx = np.searchsorted(buckets, profile, side="left")
    idx = np.minimum(idx, len(buckets) - 1)
    out = buckets[idx]
    if (out < profile).any():
        out = np.where(out < profile, buckets[-1], out)
    return out


@dataclasses.dataclass(frozen=True)
class Stage:
    start: int
    stop: int
    chi: int


def stages_from_profile(bucketed: np.ndarray) -> list[Stage]:
    stages: list[Stage] = []
    start = 0
    for i in range(1, len(bucketed) + 1):
        if i == len(bucketed) or bucketed[i] != bucketed[start]:
            stages.append(Stage(start, i, int(bucketed[start])))
            start = i
    return stages


def fit_env(env: Array, chi: int) -> Array:
    """Adapt a (N, χ_prev) environment to a stage with bond dimension χ.

    χ shrink slices, χ growth zero-pads — valid because truncated bond
    components carry (approximately) zero weight in an area-law state.  Every
    consumer of a staged walk (``sample_staged``, the streaming engine, the
    DP/TP stage loop in ``repro.api``) must use THIS function so stage
    transitions stay bit-identical across backends and schemes.
    """
    if env.shape[1] > chi:
        return env[:, :chi]
    if env.shape[1] < chi:
        return jnp.pad(env, ((0, 0), (0, chi - env.shape[1])))
    return env


def table1_metrics(profile: np.ndarray, chi_fixed: int) -> dict[str, float]:
    """The paper's Table 1 columns for a χ profile vs. a fixed-χ run."""
    prof = np.minimum(profile, chi_fixed).astype(np.float64)
    equiv_chi = float(np.sqrt(np.mean(prof ** 2)))
    step_ratio = float(np.mean(prof >= chi_fixed))
    comp_ratio = float(np.mean(prof ** 2) / chi_fixed ** 2)
    return {"equiv_chi": equiv_chi, "step_ratio": step_ratio,
            "comp_ratio": comp_ratio}


def truncate_mps_to_profile(mps: MPS, bucketed: np.ndarray) -> list[MPS]:
    """Slice a uniform-χ MPS into per-stage MPS's with the bucketed χ.

    Site i maps bond (left=bucket[i-1], right=bucket[i]); we conservatively
    use χ_stage = bucket value for both legs within a stage and pad at
    boundaries (the paper's filter instead *selects* high-amplitude points;
    slicing is the rank-truncation analogue for our synthetic data).
    """
    out = []
    for st in stages_from_profile(bucketed):
        g = mps.gammas[st.start:st.stop, :st.chi, :st.chi, :]
        lam = mps.lambdas[st.start:st.stop, :st.chi]
        out.append(MPS(g, lam, mps.semantics))
    return out


def sample_staged(mps: MPS, bucketed: np.ndarray, n_samples: int, key: Array,
                  config: sampler_mod.SamplerConfig = sampler_mod.SamplerConfig()) -> Array:
    """Run the chain as a sequence of fixed-χ stage scans.

    At a stage boundary the environment is sliced (χ shrink) or zero-padded
    (χ grow) — valid because truncated bond components carry (approximately)
    zero weight in an area-law state.
    """
    stage_mps = truncate_mps_to_profile(mps, bucketed)
    state = sampler_mod.init_state(stage_mps[0], n_samples, key, config)
    outs = []
    site_offset = 0
    for sm in stage_mps:
        state = sampler_mod.SamplerState(fit_env(state.env, sm.chi),
                                         state.key, state.log_scale)
        res = sampler_mod.sample_chain(sm, state, config, start_site=site_offset)
        state = res.state
        site_offset += sm.n_sites
        outs.append(res.samples)
    return jnp.concatenate(outs, axis=0).T      # (N, M)


def sample_staged_batched(mps: MPS, bucketed: np.ndarray, n_samples: int,
                          key: Array, micro_batch: int,
                          config: sampler_mod.SamplerConfig =
                          sampler_mod.SamplerConfig()) -> Array:
    """§3.1 micro batching composed with the staged (dynamic-χ) walk.

    Chunk c carries key ``split(key, n_micro)[c]`` for the *whole* chain —
    the exact ``sampler.sample_batched`` key schedule, which is also what
    the streaming engine's micro-batched segments use — so this in-memory
    cell is bit-identical to the streamed dynamic-χ micro-batched one (and
    to ``sample_batched`` when the profile is flat).  Each χ-stage's scan
    is jitted once and reused across every chunk.
    """
    assert n_samples % micro_batch == 0, (n_samples, micro_batch)
    keys = jax.random.split(key, n_samples // micro_batch)
    outs = [sample_staged(mps, bucketed, micro_batch, k, config)
            for k in keys]
    return jnp.concatenate(outs, axis=0)        # chunk-major, (N, M)
