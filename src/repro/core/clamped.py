"""Clamped (conditional) chain walks — the workloads-subsystem data plane.

A clamp fixes the outcome of a subset of sites (``repro.workloads.clamp``
spec, carried on the session config as ``SamplerConfig.clamp``).  The
walk here is the plain Alg. 1 schedule with one twist at each site::

    samples = where(mask_i, forced_outcome, inverse_cdf_draw)

— the forced outcome goes into the *existing* collapse path (a collapse
is "apply a selected outcome"; clamping just selects it for the sampler),
so the environment after a clamped site is exactly the conditional
environment.  Because each site's uniform comes from ``fold_in(key, i)``
independently of every other site, forcing site i leaves all other
draws untouched: a clamped run IS the unclamped run conditioned on the
clamped branch, rejection-free.

The walk additionally accumulates the clamped branch's Born weight,

    log_prob[n] = Σ_{i ∈ clamp} ln P(s_i = clamp_i | s_{<i})

(natural log; the unclamped sites contribute nothing).  ``w = exp(
log_prob)`` is the exact probability of the clamped outcomes given each
sample's prefix, which makes the self-normalized estimator

    P(s_j = x | clamp) ≈ Σ_n w_n · 1{s_j^n = x} / Σ_n w_n

an exact conditional-marginal estimator for every unclamped site j (and
``mean(w)`` an unbiased estimate of the clamp's marginal probability).

Two placements, mirroring ``core/parallel.py``:

- :func:`clamped_segment` — the seq/in-memory segment (with §3.1 micro
  batching via the ``sample_batched`` chunk-key schedule);
- :func:`sample_segment_clamped` — the DP shard_map segment, a clone of
  the unclamped dp cell with (mask, vals) as extra traced operands and
  ``log_prob`` as an extra sharded carry.  TP schemes route through this
  dp walk over the mesh's non-model axes (the repo's §4.1 contract makes
  every schedule draw-identical per seed, so there is nothing a clamped
  tp cell would compute differently — see ``api/backends.py``).

The site body is the reference XLA arithmetic (``contract_parallel`` /
``measure_probs_xla`` / ``draw_from_uniform`` — the same cells the
dispatched ops reduce to); ``kernels="pallas"`` plans fall back to it
when clamped, like born-TP measurement does by design.

An *empty* clamp never reaches this module: ``normalize_clamp`` turns it
into ``None`` and None-clamp plans run the unchanged unclamped paths —
empty-clamp bit-identity holds by construction, not by test luck (though
the tests assert it anyway).
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import precision
from repro.core.mps import MPS
from repro.core.parallel import ParallelConfig, _tp_rescale
from repro.core.sampler import SamplerConfig, init_state
from repro.kernels.site_impls import (contract_parallel, draw_from_uniform,
                                      measure_probs_xla, site_probs_dtype)

Array = jax.Array


def _clamped_site_update(env, gamma, lam, u, mask_i, vals_i,
                         config: SamplerConfig):
    """One site: contract → measure → (draw | force) → collapse → rescale.

    Returns ``(env', samples, dlog_scale, dlog_prob)`` where ``dlog_prob``
    is ``ln P(s_i | s_{<i})`` for clamped sites and 0 elsewhere.
    """
    temp = contract_parallel(env, gamma, config.compute_dtype)  # (N, χ, d)
    probs = measure_probs_xla(temp, lam, config.semantics)      # (N, d) ≥ 0
    drawn = draw_from_uniform(probs, u)
    samples = jnp.where(mask_i, vals_i, drawn)
    env_new = jnp.take_along_axis(
        temp, samples[:, None, None], axis=2)[:, :, 0]
    if config.semantics == "born":
        env_new = env_new * lam[None, :]
    env_new, dlog = _tp_rescale(env_new, config.scaling)

    rdt = precision.real_dtype_of(env.dtype)
    total = jnp.sum(probs, axis=1).astype(rdt)
    psel = jnp.take_along_axis(probs, samples[:, None],
                               axis=1)[:, 0].astype(rdt)
    cond = jnp.clip(psel / total, jnp.finfo(rdt).tiny)
    dlogp = jnp.where(mask_i, jnp.log(cond), jnp.zeros((), dtype=rdt))
    return env_new, samples, dlog, dlogp


def _chain_scan(gammas, lambdas, env, key, log_scale, log_prob, mask, vals,
                config: SamplerConfig, start_site):
    """Scan sites [start, start+L): the clamped twin of ``sample_chain``.

    Draws site i's uniform from ``fold_in(key, i)`` with the dispatch
    layer's dtype rule — the clamped walk consumes the same PRNG stream
    as every unclamped schedule.
    """
    L = gammas.shape[0]
    sites = (jnp.asarray(start_site, dtype=jnp.int32)
             + jnp.arange(L, dtype=jnp.int32))

    def body(carry, xs):
        e, ls, lp = carry
        g, lam, i, m, v = xs
        sub = jax.random.fold_in(key, i)
        u = jax.random.uniform(
            sub, (e.shape[0], 1),
            dtype=site_probs_dtype(e, g, lam, config.semantics,
                                   config.compute_dtype))
        e2, smp, dlog, dlogp = _clamped_site_update(e, g, lam, u, m, v,
                                                    config)
        return (e2, ls + dlog, lp + dlogp.astype(lp.dtype)), smp

    (env, ls, lp), samples = jax.lax.scan(
        body, (env, log_scale, log_prob),
        (gammas, lambdas, sites, mask, vals))
    return samples, env, ls, lp


@partial(jax.jit, static_argnames=("config",))
def _chain_whole(gammas, lambdas, env, key, log_scale, log_prob, mask, vals,
                 config: SamplerConfig, start_site=0):
    return _chain_scan(gammas, lambdas, env, key, log_scale, log_prob,
                       mask, vals, config, start_site)


@partial(jax.jit, static_argnames=("config", "n_micro"))
def _chain_micro(gammas, lambdas, env, key, log_scale, log_prob, mask, vals,
                 config: SamplerConfig, n_micro: int, start_site=0):
    """§3.1 micro batching: chunk keys ``split(key, n_micro)`` — the exact
    ``sampler.sample_batched`` schedule, clamped."""
    L, n = vals.shape
    n2 = n // n_micro
    chi = env.shape[1]
    keys = jax.random.split(key, n_micro)
    vals_c = jnp.transpose(vals.reshape(L, n_micro, n2), (1, 0, 2))

    def one(xs):
        k, e, ls, lp, v = xs
        return _chain_scan(gammas, lambdas, e, k, ls, lp, mask, v,
                           config, start_site)

    smp, env_o, ls_o, lp_o = jax.lax.map(
        one, (keys, env.reshape(n_micro, n2, chi),
              log_scale.reshape(n_micro, n2),
              log_prob.reshape(n_micro, n2), vals_c))
    samples = jnp.transpose(smp, (1, 0, 2)).reshape(L, n)
    return (samples, env_o.reshape(n, chi), ls_o.reshape(n),
            lp_o.reshape(n))


def clamped_segment(gammas, lambdas, env, key, start_site, mask, vals,
                    config: SamplerConfig,
                    log_scale: Optional[Array] = None,
                    log_prob: Optional[Array] = None,
                    micro_batch: Optional[int] = None):
    """Run one clamped seq segment from a full (N, χ) environment.

    ``mask (L,) bool`` / ``vals (L, N) int32`` come from
    ``workloads.clamp.segment_clamp_arrays``.  Returns
    ``(samples (L, N), env', log_scale', log_prob')``.
    """
    n = env.shape[0]
    rdt = precision.real_dtype_of(env.dtype)
    if log_scale is None:
        log_scale = jnp.zeros((n,), dtype=rdt)
    if log_prob is None:
        log_prob = jnp.zeros((n,), dtype=rdt)
    mask = jnp.asarray(mask, dtype=bool)
    vals = jnp.asarray(vals, dtype=jnp.int32)
    start = jnp.asarray(start_site, dtype=jnp.int32)
    if micro_batch is not None:
        # chunk even when n_micro == 1: the chunk key is split(key, 1)[0],
        # not key — the sample_batched schedule, kept draw-for-draw
        assert n % micro_batch == 0, (n, micro_batch)
        return _chain_micro(gammas, lambdas, env, key, log_scale, log_prob,
                            mask, vals, config, n // micro_batch, start)
    return _chain_whole(gammas, lambdas, env, key, log_scale, log_prob,
                        mask, vals, config, start)


def sample_clamped(mps: MPS, n_samples: int, key: Array,
                   config: SamplerConfig, mask, vals,
                   micro_batch: Optional[int] = None
                   ) -> tuple[Array, Array]:
    """Whole-chain clamped walk.  Returns ``(samples (N, M), log_prob (N,))``."""
    state = init_state(mps, n_samples, key, config)
    samples, _, _, log_prob = clamped_segment(
        mps.gammas, mps.lambdas, state.env, state.key, 0, mask, vals,
        config, log_scale=state.log_scale, micro_batch=micro_batch)
    return samples.T, log_prob


# ---------------------------------------------------------------------------
# DP segment runner — the clamped clone of parallel._segment_callable's dp
# cell: (mask, vals) ride as traced operands (vals sample-sharded alongside
# the environment), log_prob as a fourth sharded carry.
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _clamped_segment_callable(mesh: Mesh, pconfig: ParallelConfig,
                              config: SamplerConfig):
    d_axes = pconfig.data_axes
    n2 = pconfig.micro_batch

    def shard_fn(keys_local, env_l, ls_l, lp_l, gammas, lambdas, mask,
                 vals_l, start_r):
        base = jax.random.wrap_key_data(keys_local[0].astype(jnp.uint32))
        L = gammas.shape[0]
        n_loc = env_l.shape[0]

        def chain(k, e, ls, lp, v):
            return _chain_scan(gammas, lambdas, e, k, ls, lp, mask, v,
                               config, start_r)

        if n2 is None:
            return chain(base, env_l, ls_l, lp_l, vals_l)
        n_micro = n_loc // n2
        keys_c = jax.random.split(base, n_micro)
        vals_c = jnp.transpose(vals_l.reshape(L, n_micro, n2), (1, 0, 2))

        def one(xs):
            k, e, ls, lp, v = xs
            return chain(k, e, ls, lp, v)

        smp, env_o, ls_o, lp_o = jax.lax.map(
            one, (keys_c, env_l.reshape(n_micro, n2, -1),
                  ls_l.reshape(n_micro, n2), lp_l.reshape(n_micro, n2),
                  vals_c))
        samples = jnp.transpose(smp, (1, 0, 2)).reshape(L, n_loc)
        return (samples, env_o.reshape(n_loc, -1), ls_o.reshape(n_loc),
                lp_o.reshape(n_loc))

    return jax.jit(shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(d_axes), P(d_axes), P(d_axes), P(d_axes), P(), P(),
                  P(), P(None, d_axes), P()),
        out_specs=(P(None, d_axes), P(d_axes), P(d_axes), P(d_axes)),
        check_vma=False,
    ))


def sample_segment_clamped(mesh: Mesh, mps: MPS, env: Array, key: Array,
                           start_site, mask, vals,
                           pconfig: ParallelConfig,
                           config: SamplerConfig,
                           log_scale: Optional[Array] = None,
                           log_prob: Optional[Array] = None
                           ) -> tuple[Array, Array, Array, Array]:
    """Clamped twin of ``parallel.sample_segment`` (dp placement only;
    backends route tp plans here over the mesh's non-model axes).

    Returns ``(samples (L, N), env', log_scale', log_prob')``.
    """
    assert pconfig.scheme == "dp", pconfig.scheme
    p1 = 1
    for ax in pconfig.data_axes:
        p1 *= mesh.shape[ax]
    n_samples = env.shape[0]
    assert n_samples % p1 == 0, (n_samples, p1)
    if pconfig.micro_batch is not None:
        assert (n_samples // p1) % pconfig.micro_batch == 0, \
            (n_samples, p1, pconfig.micro_batch)
    rdt = precision.real_dtype_of(env.dtype)
    if log_scale is None:
        log_scale = jnp.zeros((n_samples,), dtype=rdt)
    if log_prob is None:
        log_prob = jnp.zeros((n_samples,), dtype=rdt)
    mask = jnp.asarray(mask, dtype=bool)
    vals = jnp.asarray(vals, dtype=jnp.int32)
    start = jnp.asarray(start_site, dtype=jnp.int32)
    dp_keys = jax.random.key_data(jax.random.split(key, p1))
    f = _clamped_segment_callable(mesh, pconfig, config)
    return f(dp_keys, env, log_scale, log_prob, mps.gammas, mps.lambdas,
             mask, vals, start)


def dp_equivalent_pconfig(pconfig: ParallelConfig) -> ParallelConfig:
    """The dp placement a clamped tp plan routes through: batch sharded
    over the same data axes, model axis left replicated.  Valid because
    every schedule draws the same randoms per (shard, site) — §4.1 — so
    the clamped dp walk emits exactly what a clamped tp walk would."""
    if pconfig.scheme == "dp":
        return pconfig
    return ParallelConfig(scheme="dp", data_axes=pconfig.data_axes,
                          model_axis=pconfig.model_axis,
                          micro_batch=pconfig.micro_batch)


__all__ = ["clamped_segment", "dp_equivalent_pconfig", "sample_clamped",
           "sample_segment_clamped"]
