"""Executable performance models (paper Eqs. 1, 2, 3, 4, 7).

These drive (a) the scheme selector (data-parallel vs. the [19] site
pipeline; single- vs. double-site TP), (b) macro/micro batch sizing against
memory and overlap thresholds, and (c) the benchmark harness's derived
columns.  All times in seconds, sizes in bytes, rates in units/s.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Hardware:
    """Per-chip capabilities.  Defaults: TPU v5e (the roofline target)."""
    peak_flops: float = 197e12          # bf16 MXU
    hbm_bw: float = 819e9               # bytes/s
    ici_bw: float = 50e9                # bytes/s per link
    io_bw: float = 5e9                  # storage read (paper's NVMe figure)
    mem_capacity: float = 16e9          # HBM bytes
    allreduce_bw: float | None = None   # measured override (Eq. 7 selector)
    reducescatter_bw: float | None = None

    @property
    def b_allreduce(self) -> float:
        return self.allreduce_bw or self.ici_bw

    @property
    def b_reducescatter(self) -> float:
        return self.reducescatter_bw or self.ici_bw


A100 = Hardware(peak_flops=156e12, hbm_bw=2039e9, ici_bw=300e9, io_bw=5e9,
                mem_capacity=80e9)
TPU_V5E = Hardware()


@dataclasses.dataclass(frozen=True)
class Workload:
    n_samples: int          # N
    n_sites: int            # M
    chi: int                # bond dimension
    d: int = 3              # physical dimension
    macro_batch: int = 20_000   # N₁
    micro_batch: int = 5_000    # N₂
    bytes_per_elt: int = 8      # fp64 real / complex64; paper uses 16 for c128

    @property
    def n_macro(self) -> int:           # n₁
        return max(1, self.n_samples // self.macro_batch)


def t_site_compute(w: Workload, hw: Hardware, n: int | None = None,
                   efficiency: float = 0.5) -> float:
    """T_{i,N}: one site's contraction+measure for an n-sample batch.

    2·N·χ²·d FLOPs (GEMM) + 2·N·χ·d (measure), at `efficiency`×peak.
    """
    n = w.macro_batch if n is None else n
    flops = 2.0 * n * w.chi * w.chi * w.d + 2.0 * n * w.chi * w.d
    return flops / (hw.peak_flops * efficiency)


def t_gamma_io(w: Workload, hw: Hardware, storage_bytes: int | None = None) -> float:
    """Read one Γ (χ²·d elements) from storage."""
    b = storage_bytes if storage_bytes is not None else w.bytes_per_elt
    return (w.chi * w.chi * w.d * b) / hw.io_bw


def eq1_model_parallel(w: Workload, hw: Hardware, efficiency: float = 0.5,
                       imbalance: float = 0.1) -> float:
    """Eq. 1 — the [19] pipeline: p = M processes, one site each.

    T = T_read + n₁·max_i T_{i,N₁} + Σ_i (T_{i,N₁} + T_comm).
    `imbalance` models max_i/mean_i − 1 (startup/straggler spread).
    """
    t_comp = t_site_compute(w, hw, w.macro_batch, efficiency)
    t_comm = (w.macro_batch * w.chi * w.bytes_per_elt) / hw.ici_bw
    t_read = t_gamma_io(w, hw)
    return (t_read + w.n_macro * t_comp * (1 + imbalance)
            + w.n_sites * (t_comp + t_comm))


def eq2_data_parallel(w: Workload, hw: Hardware, p: int,
                      efficiency: float = 0.5,
                      overlapped: bool = True,
                      storage_bytes: int | None = None) -> float:
    """Eq. 2 — FastMPS data parallel with I/O+bcast overlapped behind compute.

    T = T_read + T_bcast + (n₁/p)·Σ_i T_{i,N₁}   (ideal, overlap holds when
    T_comp > T_IO per site; otherwise I/O leaks into the critical path).
    """
    t_comp = t_site_compute(w, hw, w.macro_batch, efficiency)
    t_io = t_gamma_io(w, hw, storage_bytes)
    t_bcast = (w.chi * w.chi * w.d * (storage_bytes or w.bytes_per_elt)) / hw.ici_bw
    per_site = t_comp if (overlapped and t_comp >= t_io) else t_comp + (t_io - t_comp if overlapped else t_io)
    # continuous rounds (the paper's ideal n₁/p; in practice n₁ ≫ p and the
    # work queue balances the remainder — runtime/elastic.py)
    n_rounds = max(1.0, w.n_macro / p)
    return t_io + t_bcast + n_rounds * w.n_sites * per_site


def eq3_memory(w: Workload, bytes_per_elt: int | None = None) -> float:
    """Eq. 3 — resident bytes: left env (N₁·χ·d… reduced to N₁·χ by micro
    batching) + Γ (χ²·d).  Paper counts the unmeasured micro intermediate
    separately; with N₁ ≫ N₂·d it is negligible."""
    b = bytes_per_elt or w.bytes_per_elt
    return (w.macro_batch * w.chi + w.chi * w.chi * w.d
            + w.micro_batch * w.chi * w.d) * b


def site_hbm_bytes(n: int, chi: int, d: int, bytes_per_elt: int = 8,
                   fused: bool = False) -> float:
    """Modeled per-site HBM traffic of the sampling hot loop (§Roofline).

    *Unfused* (separate XLA ops): the unmeasured ``temp[N, χ, d]`` makes
    three HBM trips — written by the contraction GEMM, read back by the
    measurement, read again by the collapse — on top of the operands
    (env, Γ) and results (probs, env').

    *Fused* (``kernels/site_step.py``): temp lives in VMEM for the whole
    pipeline; HBM carries only env + Γ + u in and env' + samples + dlog
    out.  The 3·N·χ·d term — the dominant one for d ≥ 2 — vanishes, which
    is the ≥ 2× byte reduction ``bench_site_step.py`` records.
    """
    operands = n * chi + chi * chi * d            # env read + Γ read
    env_out = n * chi                             # env' write
    if fused:
        # + uniforms in, samples (int32≈elt) + dlog out
        return (operands + env_out + 3 * n) * bytes_per_elt
    temp = 3 * n * chi * d                        # write + 2 reads
    probs = 2 * n * d                             # write + read for the draw
    return (operands + env_out + temp + probs) * bytes_per_elt


def site_fusion_byte_reduction(n: int, chi: int, d: int,
                               bytes_per_elt: int = 8) -> float:
    """HBM bytes(unfused) / bytes(fused) for one site — the paper-facing
    derived column of the site-step bench."""
    return (site_hbm_bytes(n, chi, d, bytes_per_elt, fused=False)
            / site_hbm_bytes(n, chi, d, bytes_per_elt, fused=True))


def eq4_tp_site(w: Workload, hw: Hardware, p2: int, scheme: str,
                efficiency: float = 0.5, t_measure: float | None = None) -> float:
    """Eq. 4 — one TP site step: GEMM + measure + comm_volume/bandwidth."""
    n2 = w.micro_batch
    gemm_flops = 2.0 * n2 * w.chi * (w.chi / p2) * w.d
    t_gemm = gemm_flops / (hw.peak_flops * efficiency)
    t_meas = t_measure if t_measure is not None else (
        2.0 * n2 * w.chi * w.d) / (hw.hbm_bw)      # bandwidth-bound reduction
    if scheme == "single":
        vol = n2 * (w.chi / p2) * (p2 - 1) / p2 * w.bytes_per_elt * p2  # RS of (N₂,χ)
        t_comm = vol / hw.b_reducescatter
        t_meas = t_meas * p2                        # replicated measurement η=p₂… no:
        # single-site measures partial probs then collapses locally; the paper's
        # η=p₂ refers to the *non-distributed* measurement overhead.
    elif scheme == "double":
        vol = 2 * n2 * w.chi * w.d * (p2 - 1) / p2 * w.bytes_per_elt    # AR of (N₂,χ,d) every 2 sites
        t_comm = vol / hw.b_allreduce / 2.0         # amortized per site
    else:
        raise ValueError(scheme)
    return t_gemm + t_meas + t_comm


def eq7_tp_overhead(w: Workload, hw: Hardware, p2: int, scheme: str,
                    efficiency: float = 0.5) -> float:
    """Eq. 7 — Overhead = (CommVolume/B + η·T_measure) / T_{i,N₂}.

    single: ships the *measured* (N₂, χ) env (d× smaller — §3.2's
            measure-before-communicate) via ReduceScatter; η = p₂
            (non-distributed measurement).
    double: ships the unmeasured (N₂, χ, d) via AllReduce every *two*
            sites (per-site volume N₂χd/2); η = 1.
    """
    n2 = w.micro_batch
    t_meas = (2.0 * n2 * w.chi * w.d) / hw.hbm_bw
    if scheme == "double":
        eta = 1.0
        comm = (n2 * w.chi * w.d * w.bytes_per_elt / 2.0) / hw.b_allreduce
    else:
        eta = float(p2)
        comm = (n2 * w.chi * w.bytes_per_elt) / hw.b_reducescatter
    t_site = t_site_compute(w, hw, n2, efficiency) / p2
    return (comm + eta * t_meas) / t_site


def choose_tp_scheme(w: Workload, hw: Hardware, p2: int) -> str:
    """Paper §4.3: pick the scheme with the lower Eq. 7 overhead."""
    od = eq7_tp_overhead(w, hw, p2, "double")
    os_ = eq7_tp_overhead(w, hw, p2, "single")
    return "double" if od <= os_ else "single"


def min_macro_batch_for_overlap(w: Workload, hw: Hardware,
                                efficiency: float = 0.5,
                                storage_bytes: int | None = None) -> int:
    """Smallest N₁ with T_comp ≥ T_IO (§3.1's computation-I/O ratio = N₁)."""
    t_io = t_gamma_io(w, hw, storage_bytes)
    per_sample_flops = 2.0 * w.chi * w.chi * w.d
    per_sample_t = per_sample_flops / (hw.peak_flops * efficiency)
    return int(t_io / per_sample_t) + 1


def shard_wire_bytes(w: Workload, hosts: int, *, block: int,
                     storage_bytes: int = 2, env_bytes: int = 8,
                     sample_bytes: int = 4) -> dict:
    """Interconnect bytes of a full chain walk: §3.1 broadcast vs the
    chain-sharded data plane (block-cyclic Γ, pipelined env handoff).

    broadcast ships every Γ segment from the root to hosts−1 peers —
    O(hosts × chain).  Sharded ships NO Γ at all (each host reads only the
    blocks it owns) and instead hands the tiny (N, χ) env across each of
    the n_blocks−1 block boundaries, plus one final sample allgather —
    O(chain-boundaries × N·χ), independent of per-site Γ size.  The
    crossover is immediate for χ² ≫ N, which is exactly the large-χ regime
    the paper targets."""
    gamma_site = w.chi * w.chi * w.d * storage_bytes
    broadcast = (hosts - 1) * w.n_sites * gamma_site
    n_blocks = -(-w.n_sites // block)
    boundaries = n_blocks - 1 if hosts > 1 else 0
    handoff = boundaries * w.n_samples * w.chi * env_bytes
    gather = ((hosts - 1) * w.n_samples * w.n_sites * sample_bytes
              if hosts > 1 else 0)
    return {
        "broadcast_bytes": broadcast,
        "handoff_bytes": handoff,
        "gather_bytes": gather,
        "sharded_bytes": handoff + gather,
    }


def job_admission_cost(w: Workload, hw: Hardware, n_batches: int = 1,
                       efficiency: float = 0.5) -> dict:
    """Modeled footprint of one service job, for admission control.

    ``resident_bytes`` is Eq. 3 for ONE active macro batch — what the job
    pins on a device while any of its batches runs; batches of one job run
    one-at-a-time per lane, so concurrency across *jobs*, not batches, is
    what the admission budget must bound.  ``compute_s`` is the modeled
    chain-walk time summed over the job's live batches — the scheduler
    surfaces it so queued-job backpressure is interpretable (seconds of
    modeled work waiting, not just a count)."""
    return {
        "resident_bytes": eq3_memory(w),
        "compute_s": n_batches * w.n_sites * t_site_compute(
            w, hw, w.macro_batch, efficiency),
    }
