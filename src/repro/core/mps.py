"""Matrix Product State data structures and exact oracles.

Two semantics are supported throughout the framework (see DESIGN.md §1):

- ``linear``: the MPS carries non-negative weights and the measurement of
  Algorithm 1 in the paper is *linear* in the left environment
  (``probs = temp · Λ``).  This is the paper-faithful mode and is
  mathematically a hidden-Markov / non-negative Born machine, so exact
  marginals are cheap — we use it as the test oracle.
- ``born``: the MPS carries complex amplitudes in Vidal canonical form
  (Γ, λ) and ``p(s) = Σ_r |temp[n, r, s]|² λ_r²``.

An MPS here is a stacked array of site tensors ``gammas[M, chi, chi, d]``
plus per-bond coefficient vectors ``lambdas[M, chi]`` (the Λ of Alg. 1).
Boundary sites use row/column 0 conventions: the left environment starts as
``gammas[0, 0, :, :]`` measured at site 0.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MPS:
    """Uniform-χ stacked MPS.

    gammas : (M, chi, chi, d) site tensors.  ``gammas[i][l, r, s]`` maps the
        left bond ``l`` to the right bond ``r`` when the physical outcome at
        site ``i`` is ``s``.
    lambdas : (M, chi) measurement coefficient vector Λ_i used by Alg. 1
        (``linear``) or the Schmidt weights of the right bond (``born``).
    semantics : "linear" | "born".
    """

    gammas: Array
    lambdas: Array
    semantics: str = "linear"

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.gammas, self.lambdas), self.semantics

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    # -- shapes ------------------------------------------------------------
    @property
    def n_sites(self) -> int:
        return self.gammas.shape[0]

    @property
    def chi(self) -> int:
        return self.gammas.shape[1]

    @property
    def phys_dim(self) -> int:
        return self.gammas.shape[3]

    def astype(self, dtype) -> "MPS":
        return MPS(self.gammas.astype(dtype), self.lambdas.astype(dtype), self.semantics)


# ---------------------------------------------------------------------------
# Random MPS generation
# ---------------------------------------------------------------------------

def random_linear_mps(key: Array, n_sites: int, chi: int, d: int,
                      decay: float = 0.0, dtype=jnp.float64) -> MPS:
    """Random non-negative ("linear" semantics) MPS, i.e. an HMM.

    ``decay`` reproduces the paper's Fig. 5/6 magnitude phenomenon: each site
    shrinks the environment magnitude by roughly ``10**-decay`` with a large
    *per-sample variance*, so unnormalized environments span many orders of
    magnitude across samples — the regime where a global auto-scale fails and
    the per-sample scale of §3.3 is required.
    """
    kg, kl, kd = jax.random.split(key, 3)
    gammas = jax.random.uniform(kg, (n_sites, chi, chi, d), dtype=dtype, minval=0.0, maxval=1.0)
    # Row-normalise so that summing over (r, s) with Λ=1 yields a stochastic
    # map; then apply a per-site random magnitude factor to create the
    # dynamic-range spread.
    gammas = gammas / jnp.sum(gammas, axis=(2, 3), keepdims=True)
    if decay:
        site_scale = 10.0 ** (-decay * (1.0 + jax.random.uniform(kd, (n_sites, 1, 1, 1), dtype=dtype)))
        gammas = gammas * site_scale
    lambdas = jnp.ones((n_sites, chi), dtype=dtype) + jax.random.uniform(kl, (n_sites, chi), dtype=dtype)
    return MPS(gammas, lambdas, "linear")


def random_born_mps(key: Array, n_sites: int, chi: int, d: int,
                    dtype=jnp.complex128) -> MPS:
    """Random complex-amplitude MPS in (approximate) right-canonical Vidal form.

    Built by QR-orthogonalising random site tensors from the right so that
    ``Σ_s Γ^s Γ^{s†} ≈ I`` and the conditional probabilities from left-to-right
    sampling are normalized up to the boundary vector.  Exactness of the
    sampler is *not* assumed from canonical form — tests always compare
    against :func:`enumerate_probabilities`, which needs no canonicity.
    """
    real_dtype = jnp.float64 if dtype == jnp.complex128 else jnp.float32
    keys = jax.random.split(key, n_sites)

    def one_site(k):
        kr, ki = jax.random.split(k)
        a = (jax.random.normal(kr, (chi, chi * d), dtype=real_dtype)
             + 1j * jax.random.normal(ki, (chi, chi * d), dtype=real_dtype)).astype(dtype)
        # Right-canonicalise: rows orthonormal.
        q, _ = jnp.linalg.qr(a.conj().T, mode="reduced")  # (chi*d, chi)
        b = q.conj().T.reshape(chi, chi, d)
        return b

    gammas = jax.vmap(one_site)(keys)
    lambdas = jnp.ones((n_sites, chi), dtype=real_dtype)
    return MPS(gammas, lambdas, "born")


def gbs_like_mps(key: Array, n_sites: int, chi: int, d: int,
                 photon_decay: float = 0.002, dtype=jnp.float64) -> MPS:
    """Synthetic GBS-flavoured MPS (linear semantics).

    Mean photon number per site decays from the chain centre following the
    area-law-like entanglement profile, so that dynamic bond dimension
    (§3.4.2) has real structure to exploit, and the environment magnitude
    decays with site index as in Eq. (5) of the paper.
    """
    base = random_linear_mps(key, n_sites, chi, d, decay=photon_decay * 50, dtype=dtype)
    # Bias outcome 0 (vacuum) increasingly towards the edges.
    pos = jnp.arange(n_sites, dtype=dtype)
    centre = (n_sites - 1) / 2.0
    edge = jnp.abs(pos - centre) / centre  # 1 at edges, 0 at centre
    vac_boost = 1.0 + 4.0 * edge[:, None, None]  # (M,1,1)
    g = base.gammas.at[:, :, :, 0].multiply(vac_boost)
    g = g / jnp.sum(g, axis=(2, 3), keepdims=True)
    return MPS(g, base.lambdas, "linear")


# ---------------------------------------------------------------------------
# Exact oracles (for tests and validation — exponential in M, keep M small)
# ---------------------------------------------------------------------------

def enumerate_probabilities(mps: MPS) -> np.ndarray:
    """Exact joint distribution over all d**M outcomes.

    The sequential sampler draws each site from a *normalised per-site
    conditional* (Alg. 1).  The joint it targets is therefore the product of
    those conditionals — this oracle mirrors the sampler's arithmetic exactly
    (in float64), so it is valid for arbitrary (non-canonical) Γ/Λ.

    linear: cond(s | prefix) ∝ (env · Γ_i^s) · Λ_i ;  env' = env · Γ_i^s
    born:   cond(s | prefix) ∝ Σ_r |(env · Γ_i^s)_r λ_i[r]|² ; env' = env·Γ_i^s·λ_i
    """
    g = np.asarray(mps.gammas)
    lam = np.asarray(mps.lambdas)
    M, chi, _, d = g.shape
    outcomes = np.stack(np.meshgrid(*([np.arange(d)] * M), indexing="ij"), axis=-1).reshape(-1, M)

    linear = mps.semantics == "linear"
    probs = np.zeros(len(outcomes))
    for idx, s in enumerate(outcomes):
        env = np.zeros(chi, dtype=complex)
        env[0] = 1.0
        logp = 0.0
        for i in range(M):
            temp = np.einsum("l,lrs->rs", env, g[i])  # (chi, d)
            if linear:
                cond = np.real(temp.T @ lam[i])  # (d,)
            else:
                cond = np.sum(np.abs(temp.T * lam[i][None, :]) ** 2, axis=1)  # (d,)
            total = cond.sum()
            logp += np.log(cond[s[i]] / total)
            env = temp[:, s[i]]
            if not linear:
                env = env * lam[i]
            # renormalise env for numeric stability (does not change conds)
            nrm = np.abs(env).sum()
            if nrm > 0:
                env = env / nrm
        probs[idx] = np.exp(logp)
    return probs / probs.sum()


def exact_site_marginals(mps: MPS) -> np.ndarray:
    """Per-site marginal distribution, (M, d), via the joint (small M only)."""
    g = np.asarray(mps.gammas)
    M, chi, _, d = g.shape
    joint = enumerate_probabilities(mps)
    outcomes = np.stack(np.meshgrid(*([np.arange(d)] * M), indexing="ij"), axis=-1).reshape(-1, M)
    marg = np.zeros((M, d))
    for i in range(M):
        for s in range(d):
            marg[i, s] = joint[outcomes[:, i] == s].sum()
    return marg
