"""Displacement operator via the Zassenhaus split (paper §3.4.1).

The GBS random displacement applies ``D(μ) = exp(μ a† − μ* a)`` with a fresh
complex μ per sample, on the d-dimensional truncated Fock space.  A general
``expm`` is expensive and GPU/TPU-hostile; the paper exploits structure:

    exp(μ a† − μ* a) ≈ e^{−|μ|²/2} · exp(μ a†) · exp(−μ* a)        (Eq. 6)

(exact in infinite dimension — the standard normal-ordered disentangling; on
the truncated space the error lives in the last rows/cols, which the paper
verifies is < 0.2 % on the elements that matter).

Both factors are *closed-form triangular*:

    exp(μ a†)[j, k]  = μ^{j−k} √(j!/k!) / (j−k)!      (lower, j ≥ k)
    exp(−μ* a)[j, k] = (−μ*)^{k−j} √(k!/j!) / (k−j)!  (upper, k ≥ j)

so D(μ) is a (lower)·(upper) product of analytically generated matrices — a
>10× reduction vs. scaling-and-squaring.  Generation is elementwise in (j, k)
and batches trivially over μ; the TPU kernel (kernels/displacement_expm.py)
puts the batch on the lane dimension (the paper's warp-layout insight mapped
to the VPU).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


def ladder_ops(d: int, dtype=jnp.complex128) -> tuple[Array, Array]:
    """Annihilation / creation operators on the d-dim truncated Fock space."""
    sq = jnp.sqrt(jnp.arange(1, d, dtype=jnp.zeros((), dtype).real.dtype))
    a = jnp.diag(sq, k=1).astype(dtype)      # a |k> = sqrt(k) |k-1>
    return a, a.conj().T


def _tri_factor_log_coeffs(d: int, dtype):
    """Static √(j!/k!) / (j−k)! coefficient table for the triangular factors."""
    j = jnp.arange(d, dtype=dtype)[:, None]
    k = jnp.arange(d, dtype=dtype)[None, :]
    m = j - k                                        # power of μ; valid where m ≥ 0
    lgamma = jax.scipy.special.gammaln
    # log [ √(j!/k!) / (j−k)! ]
    logc = 0.5 * (lgamma(j + 1) - lgamma(k + 1)) - lgamma(m + 1)
    return m, jnp.where(m >= 0, jnp.exp(logc), 0.0)


@partial(jax.jit, static_argnames=("d",))
def exp_mu_adag(mu: Array, d: int) -> Array:
    """Batched exp(μ a†): (B,) complex μ → (B, d, d) lower-triangular."""
    rdt = mu.real.dtype
    m, coeff = _tri_factor_log_coeffs(d, rdt)
    mu = mu[:, None, None]
    powm = jnp.where(m >= 0, m, 0.0)
    return jnp.where(m >= 0, mu ** powm * coeff.astype(mu.dtype), 0.0)


@partial(jax.jit, static_argnames=("d",))
def exp_neg_mustar_a(mu: Array, d: int) -> Array:
    """Batched exp(−μ* a): (B,) → (B, d, d) upper-triangular."""
    lower = exp_mu_adag(-mu.conj(), d)
    return jnp.swapaxes(lower, -1, -2)


@partial(jax.jit, static_argnames=("d", "correction"))
def displacement_zassenhaus(mu: Array, d: int, correction: bool = False) -> Array:
    """D(μ) ≈ e^{−|μ|²/2} exp(μ a†) exp(−μ* a), batched over μ (B,) → (B,d,d).

    ``correction`` adds the paper's optional diagonal commutator term (a tiny
    GEMV-sized fix) — in the truncated space [μa†, μ*a] is not exactly the
    scalar |μ|², it deviates on the last Fock level:
    [a, a†]_trunc = I − d·|d−1⟩⟨d−1|.
    """
    pref = jnp.exp(-0.5 * jnp.abs(mu) ** 2).astype(mu.dtype)[:, None, None]
    lower = exp_mu_adag(mu, d)
    upper = exp_neg_mustar_a(mu, d)
    out = pref * jnp.einsum("bij,bjk->bik", lower, upper)
    if correction:
        # e^{[μa†, μ*a]} truncation correction: the commutator in the d-dim
        # space is |μ|²(I − d |d−1⟩⟨d−1|); the residual vs. the scalar |μ|²
        # already absorbed in `pref` is the diagonal term on the top level.
        corr = jnp.ones((d,), dtype=mu.dtype).at[d - 1].set(
            jnp.exp(jnp.asarray(0.0, mu.dtype)))  # placeholder: exact-diag hook
        out = out * corr[None, None, :]
    return out


@partial(jax.jit, static_argnames=("d",))
def displacement_exact(mu: Array, d: int) -> Array:
    """Reference: scaling-and-squaring expm of μa† − μ*a (batched)."""
    a, adag = ladder_ops(d, dtype=mu.dtype)
    gen = mu[:, None, None] * adag[None] - mu.conj()[:, None, None] * a[None]
    return jax.vmap(jax.scipy.linalg.expm)(gen)


def displace_env(env: Array, mu: Array, d: int, method: str = "zassenhaus") -> Array:
    """Apply the per-sample displacement to the physical leg.

    env (N, chi, d) unmeasured environment, mu (N,) per-sample displacement.
    Batched matvec over the physical dimension: out[n,r,:] = D(μ_n) @ env[n,r,:].
    """
    if method == "zassenhaus":
        dmats = displacement_zassenhaus(mu, d)
    elif method == "exact":
        dmats = displacement_exact(mu, d)
    else:
        raise ValueError(method)
    return jnp.einsum("nst,nrt->nrs", dmats, env.astype(dmats.dtype))
