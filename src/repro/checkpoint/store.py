"""Sharding-aware checkpointing: per-leaf npz shards + a JSON manifest.

Fault-tolerance contract (the large-scale-runnability requirement):
  * atomic: written to ``step_XXXX.tmp`` then renamed — a crash mid-write
    never corrupts the latest checkpoint;
  * durable: every leaf file and the manifest are fsync'd (and the parent
    directory after the rename) — the rename is only atomic against
    crashes if the bytes it points at actually reached the platter;
  * verified: the manifest carries a sha256 per leaf file, checked on
    load — a restore from rotted or torn bytes raises
    :class:`~repro.runtime.faults.CorruptSegment` instead of silently
    resuming from garbage (old digest-less checkpoints still load);
  * sharded: each host writes only the leaves (or leaf-shards) it owns —
    here single-process, the shard split is by leaf;
  * self-describing: the manifest stores the treedef, shapes, dtypes, and
    the mesh/PartitionSpec layout so a *differently sized* restart can
    re-shard (runtime/elastic.py).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.faults import CorruptSegment, Fault


def _fsync_path(path: str) -> None:
    """fsync a file or directory by path (directory fsync is what makes a
    rename/create durable, not just ordered)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _leaf_paths(tree):
    return jax.tree_util.tree_flatten_with_path(tree)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def save_checkpoint(root: str, step: int, tree, extra_meta: dict | None = None):
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _leaf_paths(tree)
    manifest = {"step": step, "leaves": [], "extra": extra_meta or {}}
    for path, leaf in leaves:
        name = _path_str(path)
        fn = name.replace("/", "__") + ".npy"
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            save_arr, dtype = arr.view(np.uint16), "bfloat16"
        else:
            save_arr, dtype = arr, str(arr.dtype)
        buf = io.BytesIO()
        np.save(buf, save_arr)
        data = buf.getvalue()
        with open(os.path.join(tmp, fn), "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append({"path": name, "file": fn,
                                   "shape": list(arr.shape), "dtype": dtype,
                                   "sha256": hashlib.sha256(data).hexdigest()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_path(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_path(root)          # the rename itself must survive a crash
    # prune older checkpoints, keep last 3
    kept = sorted(d for d in os.listdir(root) if d.startswith("step_")
                  and not d.endswith(".tmp"))
    for d in kept[:-3]:
        shutil.rmtree(os.path.join(root, d))
    return final


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(root)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(root: str, template, step: int | None = None):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (tree, step, extra_meta)."""
    step = step if step is not None else latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {e["path"]: e for e in manifest["leaves"]}
    leaves, treedef = _leaf_paths(template)
    out = []
    for path, leaf in leaves:
        e = by_name[_path_str(path)]
        with open(os.path.join(d, e["file"]), "rb") as f:
            data = f.read()
        want = e.get("sha256")             # absent in pre-digest checkpoints
        if want is not None:
            got = hashlib.sha256(data).hexdigest()
            if got != want:
                raise CorruptSegment(Fault(
                    kind="corruption", store=d,
                    message=f"checkpoint leaf {e['file']} digest mismatch "
                            f"(manifest {want[:12]}…, file {got[:12]}…) — "
                            f"refusing to resume from rotted bytes"))
        arr = np.load(io.BytesIO(data))
        if e["dtype"] == "bfloat16":
            arr = jnp.asarray(arr).view(jnp.bfloat16)
        out.append(jnp.asarray(arr).astype(leaf.dtype).reshape(leaf.shape))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)
    return tree, manifest["step"], manifest["extra"]
