"""Sharding-aware checkpointing: per-leaf npz shards + a JSON manifest.

Fault-tolerance contract (the large-scale-runnability requirement):
  * atomic: written to ``step_XXXX.tmp`` then renamed — a crash mid-write
    never corrupts the latest checkpoint;
  * sharded: each host writes only the leaves (or leaf-shards) it owns —
    here single-process, the shard split is by leaf;
  * self-describing: the manifest stores the treedef, shapes, dtypes, and
    the mesh/PartitionSpec layout so a *differently sized* restart can
    re-shard (runtime/elastic.py).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_paths(tree):
    return jax.tree_util.tree_flatten_with_path(tree)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def save_checkpoint(root: str, step: int, tree, extra_meta: dict | None = None):
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _leaf_paths(tree)
    manifest = {"step": step, "leaves": [], "extra": extra_meta or {}}
    for path, leaf in leaves:
        name = _path_str(path)
        fn = name.replace("/", "__") + ".npy"
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            np.save(os.path.join(tmp, fn), arr.view(np.uint16))
            dtype = "bfloat16"
        else:
            np.save(os.path.join(tmp, fn), arr)
            dtype = str(arr.dtype)
        manifest["leaves"].append({"path": name, "file": fn,
                                   "shape": list(arr.shape), "dtype": dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # prune older checkpoints, keep last 3
    kept = sorted(d for d in os.listdir(root) if d.startswith("step_")
                  and not d.endswith(".tmp"))
    for d in kept[:-3]:
        shutil.rmtree(os.path.join(root, d))
    return final


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(root)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(root: str, template, step: int | None = None):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (tree, step, extra_meta)."""
    step = step if step is not None else latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {e["path"]: e for e in manifest["leaves"]}
    leaves, treedef = _leaf_paths(template)
    out = []
    for path, leaf in leaves:
        e = by_name[_path_str(path)]
        arr = np.load(os.path.join(d, e["file"]))
        if e["dtype"] == "bfloat16":
            arr = jnp.asarray(arr).view(jnp.bfloat16)
        out.append(jnp.asarray(arr).astype(leaf.dtype).reshape(leaf.shape))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)
    return tree, manifest["step"], manifest["extra"]
