from repro.checkpoint.store import save_checkpoint, load_checkpoint, latest_step
from repro.checkpoint.sampler_state import save_sampler_state, load_sampler_state
