"""Mid-chain sampler checkpointing (paper §4.1 seed-consistency).

The unit of restart is (site index, left environment, PRNG key, emitted
samples so far).  Because every random draw after ``site`` depends only on
the carried key, a resumed chain emits **bit-identical** samples to an
uninterrupted one — asserted in tests/test_checkpoint.py.
"""
from __future__ import annotations

import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sampler import SamplerState
from repro.runtime.faults import CorruptSegment, Fault


def _state_digest(env, key, log_scale, samples) -> str:
    """sha256 over the checkpoint's logical payload bytes — embedded at
    save, verified at load, so a resume never proceeds from rotted state."""
    h = hashlib.sha256()
    for a in (env, key, log_scale, samples):
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def save_sampler_state(root: str, site: int, state: SamplerState,
                       samples_so_far: np.ndarray, keep: int = 3):
    """Atomic per-site checkpoint; prunes to the ``keep`` newest sites so a
    checkpoint-per-segment streaming walk doesn't accumulate the whole
    chain's history (keep-last-3, matching checkpoint/store.py)."""
    os.makedirs(root, exist_ok=True)
    # the temp name must NOT match the site_*.npz pattern: a kill between
    # savez and replace would otherwise leave a truncated file that the
    # loader's sorted()[-1] (and the prune filter) would pick up
    tmp = os.path.join(root, f".tmp_site_{site:06d}.npz")
    final = os.path.join(root, f"site_{site:06d}.npz")
    env = np.asarray(state.env)
    key = np.asarray(jax.random.key_data(state.key))
    log_scale = np.asarray(state.log_scale)
    samples = np.asarray(samples_so_far)
    digest = _state_digest(env, key, log_scale, samples)
    with open(tmp, "wb") as f:
        np.savez(f, env=env, key=key, log_scale=log_scale, samples=samples,
                 site=site,
                 sha256=np.frombuffer(digest.encode(), dtype=np.uint8))
        f.flush()
        os.fsync(f.fileno())       # the bytes must hit the platter BEFORE
    os.replace(tmp, final)         # the rename makes them the checkpoint
    dfd = os.open(root, os.O_RDONLY)
    try:
        os.fsync(dfd)              # …and the rename itself must survive
    finally:
        os.close(dfd)
    if keep:
        files = sorted(f for f in os.listdir(root)
                       if f.startswith("site_") and f.endswith(".npz"))
        for f in files[:-keep]:
            os.remove(os.path.join(root, f))
    return final


def newest_checkpoint_site(root: str) -> int:
    """Site index of the newest checkpoint under ``root``, or 0 when none
    exist (site 0 — the chain start — IS "nothing durable yet": resuming
    from it recomputes everything, which is always safe).

    This is each process's vote in the cluster-synchronized resume
    agreement: ``runtime.allreduce_min(newest_checkpoint_site(dir))`` is
    the newest boundary EVERY process can resume from.  For the min to be
    loadable, multi-process walks checkpoint with ``keep=0`` (full
    history) — pruning could delete the very boundary a slower process
    needs the cluster to agree on."""
    if not os.path.isdir(root):
        return 0
    files = sorted(f for f in os.listdir(root)
                   if f.startswith("site_") and f.endswith(".npz"))
    if not files:
        return 0
    return int(files[-1].split("_")[1].split(".")[0])


def load_sampler_state(root: str, site: int | None = None):
    files = sorted(f for f in os.listdir(root)
                   if f.startswith("site_") and f.endswith(".npz"))
    if not files:
        raise FileNotFoundError(root)
    if site is None:
        fn = files[-1]
        site = int(fn.split("_")[1].split(".")[0])
    else:
        fn = f"site_{site:06d}.npz"
    with np.load(os.path.join(root, fn)) as z:
        env, key, log_scale = z["env"], z["key"], z["log_scale"]
        samples = z["samples"]
        if "sha256" in z.files:    # absent in pre-digest checkpoints
            want = bytes(z["sha256"]).decode()
            got = _state_digest(env, key, log_scale, samples)
            if got != want:
                raise CorruptSegment(Fault(
                    kind="corruption", site=int(z["site"]), store=root,
                    message=f"sampler checkpoint {fn} digest mismatch "
                            f"(embedded {want[:12]}…, recomputed "
                            f"{got[:12]}…) — refusing to resume from "
                            f"rotted state"))
        state = SamplerState(
            jnp.asarray(env),
            jax.random.wrap_key_data(jnp.asarray(key)),
            jnp.asarray(log_scale))
        return int(z["site"]), state, samples
