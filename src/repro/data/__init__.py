from repro.data.gamma_store import GammaStore
from repro.data.tokens import synthetic_token_stream
