"""Γ tensor store with double-buffered background prefetch (paper §3.1/§3.3.2).

The paper's data-parallel revival hinges on hiding Γ I/O behind compute:
process 0 reads Γᵢ₊₁ from disk while every process contracts Γᵢ.  Here the
store owns an on-disk directory of per-site tensors (written in bf16 — the
paper's FP16-storage trick, halving I/O and broadcast bytes) and a one-slot
prefetch thread; ``get(i)`` returns site i (upcast to the compute dtype) and
immediately schedules site i+1.

This is the host-side path for MPS chains too big for device memory; the
all-in-memory path simply stacks Γ and ``lax.scan``s over it.
"""
from __future__ import annotations

import os
import queue
import threading
from typing import Optional

import jax.numpy as jnp
import numpy as np


class GammaStore:
    def __init__(self, root: str, storage_dtype=jnp.bfloat16,
                 compute_dtype=jnp.float32):
        self.root = root
        self.storage_dtype = storage_dtype
        self.compute_dtype = compute_dtype
        os.makedirs(root, exist_ok=True)
        self._prefetched: dict[int, np.ndarray] = {}
        self._queue: "queue.Queue[Optional[int]]" = queue.Queue()
        self._results: "queue.Queue[tuple[int, np.ndarray]]" = queue.Queue()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        self.io_bytes = 0          # instrumentation for the benches

    # -- write path ---------------------------------------------------------
    def put(self, i: int, gamma: np.ndarray, lam: np.ndarray) -> None:
        g16 = np.asarray(jnp.asarray(gamma).astype(self.storage_dtype))
        np.savez(self._path(i), gamma=g16.view(np.uint16)
                 if g16.dtype.itemsize == 2 else g16,
                 gshape=np.array(gamma.shape), lam=np.asarray(lam),
                 two_byte=np.array(g16.dtype.itemsize == 2))

    def write_mps(self, mps) -> None:
        for i in range(mps.n_sites):
            self.put(i, np.asarray(mps.gammas[i]), np.asarray(mps.lambdas[i]))

    # -- read path ----------------------------------------------------------
    def _path(self, i: int) -> str:
        return os.path.join(self.root, f"site_{i:06d}.npz")

    def _read(self, i: int):
        with np.load(self._path(i)) as z:
            raw, lam = z["gamma"], z["lam"]
            self.io_bytes += raw.nbytes + lam.nbytes
            if bool(z["two_byte"]):
                g = jnp.asarray(raw.view(np.uint16)).view(self.storage_dtype)
                g = g.reshape(tuple(z["gshape"]))
            else:
                g = jnp.asarray(raw)
        return np.asarray(g.astype(self.compute_dtype)), lam

    def _worker(self):
        while True:
            i = self._queue.get()
            if i is None:
                return
            try:
                self._results.put((i, self._read(i)))
            except Exception as e:          # surfaced on the consumer side
                self._results.put((i, e))

    def prefetch(self, i: int) -> None:
        self._queue.put(i)

    def get(self, i: int, prefetch_next: bool = True):
        """Blocking read of site i (served from the prefetch buffer when the
        background thread already has it); schedules i+1."""
        hit = self._prefetched.pop(i, None)
        while hit is None:
            try:
                j, payload = self._results.get_nowait()
            except queue.Empty:
                break
            if j == i:
                hit = payload
            else:
                self._prefetched[j] = payload
        if hit is None:
            hit = self._read(i)
        if isinstance(hit, Exception):
            raise hit
        if prefetch_next and os.path.exists(self._path(i + 1)):
            self.prefetch(i + 1)
        return hit

    def close(self):
        self._queue.put(None)
