"""Γ tensor store with double-buffered background prefetch (paper §3.1/§3.3.2).

The paper's data-parallel revival hinges on hiding Γ I/O behind compute:
process 0 reads Γᵢ₊₁ from disk while every process contracts Γᵢ.  Here the
store owns an on-disk directory of per-site tensors (written in bf16 — the
paper's FP16-storage trick, halving I/O and broadcast bytes) and a one-slot
prefetch thread; ``get(i)`` returns site i (upcast to the compute dtype) and
immediately schedules site i+1.

Three consumers build on the per-site path:

* the all-in-memory sampler simply stacks Γ and ``lax.scan``s over it;
* the streaming engine (``repro.engine``) walks the chain in fixed-size
  *segments* — :meth:`prefetch_segment` schedules a whole segment on the
  worker thread, :meth:`get_segment` blocks until it is read and returns the
  stacked host arrays, and :meth:`get_segment_on_device` additionally hands
  the buffers to the accelerator (``jax.device_put``) so the transfer of
  segment k+1 overlaps the contraction of segment k;
* the multihost runtime (``repro.api.runtime``) broadcasts Γ in the
  **storage format**: :meth:`get_segment_raw` returns a wire payload of the
  packed on-disk bytes (bf16 when the store is bf16 — the same §3.3.2 trick
  that halves disk I/O halves the broadcast), and the module-level
  :func:`decode_segment` turns a payload back into compute-dtype arrays.
  The local read path (:meth:`get`) decodes through the *same* function, so
  a broadcast-received segment is bit-identical to a locally-read one.

``get(i)`` never re-reads a site whose prefetch is already in flight: it
blocks on the worker's result queue instead (the old fall-back issued a
duplicate synchronous read and leaked the prefetched copy into
``_prefetched`` forever — asserted against in tests/test_gamma_store.py).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import queue
import threading
import time
import zipfile
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.faults import CorruptSegment, Fault

#: per-store digest manifest (``write_digest_manifest``): maps each site
#: file name to its leaf digest so a host holding only a *slice* of the
#: chain (repro.shard) can still reproduce the whole store's digest — the
#: key the serving gateway's ResultCache addresses results by.  The name
#: deliberately does not match the ``site_*.npz`` glob.
MANIFEST_NAME = "digests.json"


def site_filename(i: int) -> str:
    """Canonical site-file name — shared with repro.shard so a sliced store
    and a whole store agree on the Merkle leaf set."""
    return f"site_{i:06d}.npz"


def leaf_digest(fname: str, data: bytes) -> str:
    """Merkle leaf: sha256 over the site file's name + bytes (the name binds
    the leaf to its chain position; bytes alone would let two permuted
    stores collide)."""
    h = hashlib.sha256()
    h.update(fname.encode())
    h.update(data)
    return h.hexdigest()


def merkle_root(leaves: dict[str, str]) -> str:
    """Combine per-site leaf digests into the store digest: sha256 over the
    sorted ``name:leaf`` lines.  Computable from the leaves alone — which is
    the point: a sharded store hashes only the files it holds and takes the
    rest from the manifest."""
    h = hashlib.sha256()
    for f in sorted(leaves):
        h.update(f"{f}:{leaves[f]}\n".encode())
    return h.hexdigest()


def decode_gamma(raw: np.ndarray, gshape: tuple[int, ...], two_byte: bool,
                 storage_dtype, compute_dtype) -> np.ndarray:
    """Storage-format Γ bytes → a compute-dtype host array.

    THE decode path: the store's local reads and the multihost broadcast
    receive both go through here, so the two are bit-identical by
    construction.  ``raw`` may carry a leading stack axis (a whole segment
    decodes in one call)."""
    lead = raw.shape[:max(0, raw.ndim - len(gshape))]
    if two_byte:
        g = jnp.asarray(raw.view(np.uint16)).view(storage_dtype)
        g = g.reshape(lead + tuple(gshape))
    else:
        g = jnp.asarray(raw)
    return np.asarray(g.astype(compute_dtype))


def segment_checksum(gamma: np.ndarray, lam: np.ndarray) -> int:
    """CRC32 over a segment payload's packed Γ + Λ bytes — stamped by
    :meth:`GammaStore.get_segment_raw`, verified by :func:`decode_segment`,
    so a corrupt broadcast/RPC payload is rejected at decode instead of
    sampled from."""
    return zlib.crc32(np.ascontiguousarray(lam).tobytes(),
                      zlib.crc32(np.ascontiguousarray(gamma).tobytes()))


def decode_segment(payload: dict, compute_dtype=None
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Wire payload (see :meth:`GammaStore.get_segment_raw`) → stacked
    (gammas (L, χ, χ, d), lambdas (L, χ)) compute-dtype host arrays.

    Payloads stamped with a ``crc`` (every ``get_segment_raw`` payload)
    are verified here; a mismatch raises :class:`CorruptSegment` —
    kind=corruption, carrying the segment start site."""
    if payload.get("crc") is not None:
        want = int(np.asarray(payload["crc"]))
        got = segment_checksum(payload["gamma"], payload["lam"])
        if got != want:
            start = int(np.asarray(payload.get("start", -1)))
            raise CorruptSegment(Fault(
                kind="corruption", site=start,
                message=f"segment payload at site {start} failed its wire "
                        f"checksum (crc {got:#010x} != {want:#010x}) — "
                        f"rejected at decode, not sampled from"))
    compute = payload["compute_dtype"] if compute_dtype is None \
        else compute_dtype
    g = decode_gamma(payload["gamma"], tuple(payload["gshape"]),
                     bool(payload["two_byte"]), payload["storage_dtype"],
                     compute)
    return g, payload["lam"]


class GammaStore:
    def __init__(self, root: str, storage_dtype=jnp.bfloat16,
                 compute_dtype=jnp.float32, verify: bool = False):
        self.root = root
        self.storage_dtype = storage_dtype
        self.compute_dtype = compute_dtype
        #: verify every payload read against the digest manifest
        #: (digests.json) when one is present.  The streaming engine turns
        #: this on automatically for multi-host / sharded runs; structural
        #: corruption (a torn npz) is caught on every read regardless.
        self.verify = verify
        os.makedirs(root, exist_ok=True)
        self._prefetched: dict[int, np.ndarray] = {}
        self._inflight: set[int] = set()
        self._lock = threading.Lock()
        self._queue: "queue.Queue[Optional[int]]" = queue.Queue()
        self._results: "queue.Queue[tuple[int, np.ndarray]]" = queue.Queue()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        self.io_bytes = 0          # instrumentation for the benches
        self.io_seconds = 0.0      # worker+sync read wall time
        self.payload_reads = 0     # Γ payload reads (meta() probes excluded)
        self.verified_reads = 0    # payload reads digest-checked vs manifest
        self.quarantined_sites = 0
        self.repaired_sites = 0
        self.repair_read_bytes = 0  # bytes served to peers for repair
        self._digest: Optional[str] = None
        # per-file leaf cache keyed by (st_mtime_ns, st_size, st_ino): an
        # unchanged file never re-hashes, a rewritten/rotted one always does
        self._sigleaves: dict[str, tuple[tuple, str]] = {}
        self._manifest: Optional[tuple[tuple, dict]] = None
        self._n_sites = sum(1 for f in os.listdir(root)
                            if f.startswith("site_") and f.endswith(".npz"))

    # -- write path ---------------------------------------------------------
    def put(self, i: int, gamma: np.ndarray, lam: np.ndarray) -> None:
        fresh = not os.path.exists(self._path(i))
        g16 = np.asarray(jnp.asarray(gamma).astype(self.storage_dtype))
        np.savez(self._path(i), gamma=g16.view(np.uint16)
                 if g16.dtype.itemsize == 2 else g16,
                 gshape=np.array(gamma.shape), lam=np.asarray(lam),
                 two_byte=np.array(g16.dtype.itemsize == 2))
        if fresh:
            self._n_sites += 1
        self._digest = None            # content changed: recompute lazily
        self._sigleaves.pop(site_filename(i), None)

    def write_mps(self, mps) -> None:
        for i in range(mps.n_sites):
            self.put(i, np.asarray(mps.gammas[i]), np.asarray(mps.lambdas[i]))

    # -- read path ----------------------------------------------------------
    def _path(self, i: int) -> str:
        return os.path.join(self.root, site_filename(i))

    @property
    def n_sites(self) -> int:
        """Cached count (kept current by put()) — a listdir per call would be
        O(M) filenames on every segment walk of an M-site chain."""
        return self._n_sites

    def _site_files(self) -> list[str]:
        return sorted(f for f in os.listdir(self.root)
                      if f.startswith("site_") and f.endswith(".npz"))

    def _stat_sig(self, path: str) -> tuple:
        st = os.stat(path)
        return (st.st_mtime_ns, st.st_size, st.st_ino)

    def _leaf_for(self, f: str) -> str:
        """Leaf digest of one site file, cached per stat signature — the
        same ``(st_mtime_ns, st_size, st_ino)`` scheme the gateway's store
        identity cache uses.  Repeated ``digest()`` calls and per-read
        verification hash each file once until it changes on disk."""
        path = os.path.join(self.root, f)
        sig = self._stat_sig(path)
        cached = self._sigleaves.get(f)
        if cached is not None and cached[0] == sig:
            return cached[1]
        with open(path, "rb") as fh:
            leaf = leaf_digest(f, fh.read())
        self._sigleaves[f] = (sig, leaf)
        return leaf

    def site_digests(self) -> dict[str, str]:
        """Per-site Merkle leaves (``{file name: leaf_digest}``) for every
        site file this store holds.  Leaves are cached per file stat
        signature (see :meth:`_leaf_for`), so only changed files re-hash."""
        return {f: self._leaf_for(f) for f in self._site_files()}

    def digest(self) -> str:
        """Content digest of the materialized store: the Merkle root
        (:func:`merkle_root`) over the per-site leaf digests.  This
        identifies *these tensor files* — npz archives embed zip
        timestamps, so re-writing identical tensors yields a new digest;
        that is conservative in the right direction for result caching (a
        stale hit is impossible, a spurious miss just recomputes).  The
        tree shape is what lets a *sharded* store (repro.shard) reproduce
        the same digest from its owned leaves plus the manifest's.
        Cached; invalidated by :meth:`put`."""
        if self._digest is None:
            self._digest = merkle_root(self.site_digests())
        return self._digest

    def write_digest_manifest(self) -> str:
        """Persist the per-site leaves as ``digests.json`` in the store
        root (atomic).  A sharded slice carries this file so each host can
        answer for the GLOBAL digest while holding only its own sites."""
        path = os.path.join(self.root, MANIFEST_NAME)
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self.site_digests(), fh, indent=0, sort_keys=True)
        os.replace(tmp, path)
        self._manifest = None
        return path

    def manifest_leaves(self) -> dict[str, str]:
        """The digest manifest's leaves (``{}`` when no ``digests.json``),
        cached per manifest file signature.  These are what verified reads
        compare against — the manifest is the store's ground truth."""
        path = os.path.join(self.root, MANIFEST_NAME)
        try:
            sig = self._stat_sig(path)
        except OSError:
            self._manifest = None
            return {}
        if self._manifest is not None and self._manifest[0] == sig:
            return self._manifest[1]
        with open(path) as fh:
            data = json.load(fh)
        self._manifest = (sig, data)
        return data

    def meta(self, i: int = 0) -> tuple[int, ...]:
        """Γ shape of site i from the npz header — no tensor payload read."""
        with np.load(self._path(i)) as z:
            return tuple(int(x) for x in z["gshape"])

    def quarantine_site(self, i: int) -> Optional[str]:
        """Move a corrupt site file aside (rename to ``*.quarantine``) so
        no later read can consume the bad bytes; returns the quarantine
        path (None when the file is already gone)."""
        path = self._path(i)
        qpath = path + ".quarantine"
        try:
            os.replace(path, qpath)
        except OSError:
            return None
        with self._lock:
            self.quarantined_sites += 1
        self._sigleaves.pop(site_filename(i), None)
        self._digest = None
        return qpath

    def _read_raw(self, i: int) -> tuple[np.ndarray, np.ndarray,
                                         tuple[int, ...], bool]:
        """One site's storage-format payload: (packed Γ, Λ, gshape, two_byte).
        This is the only place Γ payload bytes leave the disk — the I/O
        counters here are what the only-root-reads contract asserts on.

        Verification happens here, at the choke point: when :attr:`verify`
        is on and the manifest carries a leaf for site i, the file bytes
        are digest-checked before decode; a torn/truncated npz is caught
        structurally on every read regardless.  Bad bytes get one bounded
        re-read (a transient torn read heals; real rot fails twice), then
        the file is quarantined and :class:`CorruptSegment` raised — no
        caller ever sees garbage tensors."""
        t0 = time.perf_counter()
        path = self._path(i)
        fname = site_filename(i)
        fault = None
        checked = False
        raw = lam = gshape = two_byte = None
        for _attempt in range(2):
            fault = None
            with open(path, "rb") as fh:   # FileNotFoundError propagates
                data = fh.read()
            if self.verify:
                expected = self.manifest_leaves().get(fname)
                if expected is not None:
                    checked = True
                    if leaf_digest(fname, data) != expected:
                        fault = Fault(
                            kind="corruption", site=i, store=self.root,
                            message=f"Γ site {i} failed digest verification "
                                    f"against {MANIFEST_NAME} in {self.root}")
                        continue
            try:
                with np.load(io.BytesIO(data)) as z:
                    raw, lam = z["gamma"], z["lam"]
                    gshape = tuple(int(x) for x in z["gshape"])
                    two_byte = bool(z["two_byte"])
            except (zipfile.BadZipFile, ValueError, KeyError, EOFError,
                    OSError) as e:
                fault = Fault(
                    kind="corruption", site=i, store=self.root,
                    message=f"Γ site {i} is structurally corrupt "
                            f"({type(e).__name__}: {e})")
                continue
            break
        if fault is not None:
            self.quarantine_site(i)
            raise CorruptSegment(fault)
        # the worker thread and a caller's synchronous fall-back read can
        # race here — unsynchronized += would lose counts
        with self._lock:
            self.io_bytes += raw.nbytes + lam.nbytes
            self.io_seconds += time.perf_counter() - t0
            self.payload_reads += 1
            if checked:
                self.verified_reads += 1
        return raw, lam, gshape, two_byte

    def verify_sites(self, sites=None) -> list[int]:
        """Verify site files against the digest manifest; quarantine any
        that fail and return their indices.  Cheap on a healthy store —
        leaves are cached per stat signature, so unchanged files hash
        once.  Sites with no file or no manifest entry are skipped
        (nothing to verify against)."""
        manifest = self.manifest_leaves()
        if sites is None:
            sites = [int(f[len("site_"):-len(".npz")])
                     for f in self._site_files()]
        bad = []
        for i in sites:
            f = site_filename(i)
            expected = manifest.get(f)
            if expected is None or not os.path.exists(
                    os.path.join(self.root, f)):
                continue
            try:
                ok = self._leaf_for(f) == expected
            except OSError:
                ok = False
            if not ok:
                self.quarantine_site(i)
                bad.append(i)
        return bad

    def has_healthy_copy(self, i: int) -> bool:
        """Does this root hold site i's file with bytes matching the
        manifest?  The peer-repair eligibility probe — a metadata read,
        never a Γ payload read."""
        f = site_filename(i)
        if not os.path.exists(os.path.join(self.root, f)):
            return False
        expected = self.manifest_leaves().get(f)
        if expected is None:
            return False
        try:
            return self._leaf_for(f) == expected
        except OSError:
            return False

    def read_repair_bytes(self, i: int) -> bytes:
        """Raw file bytes of site i for serving a peer repair, verified
        against the manifest before leaving this host — never ship rot to
        a peer.  This is the recovery path: it deliberately bypasses shard
        ownership enforcement (a healthy replica of a *foreign* site is
        exactly what repair needs) and is counted separately from payload
        reads (:attr:`repair_read_bytes`)."""
        f = site_filename(i)
        with open(os.path.join(self.root, f), "rb") as fh:
            data = fh.read()
        expected = self.manifest_leaves().get(f)
        if expected is not None and leaf_digest(f, data) != expected:
            raise CorruptSegment(Fault(
                kind="corruption", site=i, store=self.root,
                message=f"repair source for Γ site {i} is itself corrupt"))
        with self._lock:
            self.repair_read_bytes += len(data)
        return data

    def restore_site(self, i: int, data: bytes) -> None:
        """Atomically re-materialize site i from repair bytes (verified
        against the manifest when one is present) and clear any
        quarantined copy — the receiving end of a peer repair."""
        f = site_filename(i)
        expected = self.manifest_leaves().get(f)
        if expected is not None and leaf_digest(f, data) != expected:
            raise CorruptSegment(Fault(
                kind="corruption", site=i, store=self.root,
                message=f"repair payload for Γ site {i} failed "
                        f"verification — refusing to install it"))
        path = self._path(i)
        tmp = path + ".repair_tmp"
        with open(tmp, "wb") as fh:
            fh.write(data)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
        try:
            os.unlink(path + ".quarantine")
        except OSError:
            pass
        self._sigleaves.pop(f, None)
        self._digest = None
        with self._lock:
            self.repaired_sites += 1

    def _read(self, i: int):
        raw, lam, gshape, two_byte = self._read_raw(i)
        return decode_gamma(raw, gshape, two_byte, self.storage_dtype,
                            self.compute_dtype), lam

    def _worker(self):
        while True:
            i = self._queue.get()
            if i is None:
                return
            try:
                self._results.put((i, self._read(i)))
            except Exception as e:          # surfaced on the consumer side
                self._results.put((i, e))

    def prefetch(self, i: int) -> None:
        with self._lock:
            if i in self._inflight or i in self._prefetched:
                return
            self._inflight.add(i)
        self._queue.put(i)

    def prefetch_segment(self, start: int, length: int) -> None:
        """Schedule sites [start, start+length) on the worker thread."""
        for i in range(start, min(start + length, self.n_sites)):
            self.prefetch(i)

    def _drain(self, block: bool) -> bool:
        """Move one worker result into ``_prefetched``; True if one arrived."""
        try:
            j, payload = self._results.get(block=block,
                                           timeout=60.0 if block else None)
        except queue.Empty:
            if block:
                raise TimeoutError("prefetch worker stalled >60s")
            return False
        with self._lock:
            self._inflight.discard(j)
            self._prefetched[j] = payload
        return True

    def get(self, i: int, prefetch_next: bool = True):
        """Blocking read of site i (served from the prefetch buffer when the
        background thread already has it); schedules i+1.

        If a prefetch for i is *in flight*, block on the worker's result
        instead of issuing a duplicate synchronous read — each site is read
        from disk exactly once along a sequential walk.
        """
        while True:
            with self._lock:
                hit = self._prefetched.pop(i, None)
                wait = i in self._inflight
            if hit is not None:
                break
            if wait:
                self._drain(block=True)
                continue
            if not self._drain(block=False):
                hit = self._read(i)
                break
        if isinstance(hit, Exception):
            raise hit
        if prefetch_next and os.path.exists(self._path(i + 1)):
            self.prefetch(i + 1)
        return hit

    def get_segment(self, start: int, length: int,
                    prefetch_next_segment: bool = True):
        """Blocking stacked read of sites [start, start+length):
        returns (gammas (L, χ, χ, d), lambdas (L, χ)) host arrays.

        Schedules the *next* segment on the worker before collecting this one
        so a segment-striding consumer always has the next buffer in flight.
        """
        stop = min(start + length, self.n_sites)
        self.prefetch_segment(start, stop - start)
        if prefetch_next_segment:
            self.prefetch_segment(stop, length)
        gs, ls = [], []
        for i in range(start, stop):
            g, lam = self.get(i, prefetch_next=False)
            gs.append(g)
            ls.append(lam)
        return np.stack(gs), np.stack(ls)

    def get_segment_on_device(self, start: int, length: int,
                              prefetch_next_segment: bool = True,
                              device=None):
        """Segment read + device hand-off: the returned jax arrays are already
        on (or being transferred to) the accelerator.  ``device_put`` is
        asynchronous, so callers can overlap this transfer with compute on the
        previous segment simply by calling this from a background thread."""
        g, lam = self.get_segment(start, length, prefetch_next_segment)
        return jax.device_put(g, device), jax.device_put(lam, device)

    def get_segment_raw(self, start: int, length: int) -> dict:
        """Storage-format wire payload for sites [start, start+length).

        This is what the multihost runtime broadcasts (paper §3.1): the
        packed on-disk bytes — bf16 when the store is bf16, so the §3.3.2
        compression that halves disk I/O halves the interconnect bytes too —
        plus the metadata a receiver needs to :func:`decode_segment` them.
        Reads synchronously on the caller's thread (the streaming engine
        calls this from its prefetch pool, which already overlaps the read
        and the broadcast with compute on the previous segment)."""
        stop = min(start + length, self.n_sites)
        raws, lams, gshape, two_byte = [], [], None, False
        for i in range(start, stop):
            raw, lam, gshape, two_byte = self._read_raw(i)
            raws.append(raw)
            lams.append(lam)
        gamma, lam = np.stack(raws), np.stack(lams)
        return {"start": start, "gamma": gamma, "lam": lam, "gshape": gshape,
                "two_byte": two_byte, "storage_dtype": self.storage_dtype,
                "compute_dtype": self.compute_dtype,
                "crc": np.uint32(segment_checksum(gamma, lam))}

    def close(self):
        self._queue.put(None)
        self._thread.join()

    # context-manager support: sessions and tests that open a store inline
    # can never leak the prefetch thread
    def __enter__(self) -> "GammaStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
