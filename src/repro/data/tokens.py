"""Synthetic LM data pipeline: deterministic, shardable, restart-exact.

Batches are derived from (seed, step) so any worker can regenerate any batch
— the same idempotent work-queue property the sampler's macro batches have
(runtime/elastic.py relies on this for both).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def synthetic_token_stream(seed: int, vocab: int, batch: int, seq: int):
    """Returns batch_at(step) -> {"tokens", "labels"} (labels = shifted)."""
    def batch_at(step: int):
        key = jax.random.fold_in(jax.random.key(seed), step)
        toks = jax.random.randint(key, (batch, seq + 1), 0, vocab, jnp.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    return batch_at
