"""Chain-sharded data plane (ROADMAP item 3): block-cyclic Γ distribution.

Every runtime before this package replicated the whole chain's Γ stream on
every process (paper §3.1 root-reads-then-broadcast) — O(hosts × chain)
wire bytes, and the chain's *store* had to fit one host's disk.  Following
Adamski & Brown ("Tensor-Parallel Emulation of Quantum Circuits with
Block-Cyclic Distributed MPS", PAPERS.md), the chain itself is a third
parallelism axis next to DP-over-samples and TP-over-bond:

* :class:`ShardMap` — the ownership algebra: site ``i`` belongs to host
  ``(i // block) % n_hosts``;
* :class:`ShardedGammaStore` — a :class:`~repro.data.gamma_store.GammaStore`
  view that refuses to read (or prefetch) any site its host does not own,
  so store capacity scales with hosts and the no-foreign-reads contract is
  *enforced*, not just asserted;
* :func:`materialize_shard` — pack one host's slice (plus the digest
  manifest that lets the slice still answer for the global store digest);
* :mod:`repro.shard.walk` — the wire codecs for the pipelined walk: the
  owner of segment k ships only the tiny (N, χ) environment to the owner
  of k+1 (``ClusterRuntime.send/recv``), then every host's sample blocks
  meet in one final all-gather.

The driver lives in :class:`repro.engine.streaming.StreamingEngine`
(``shard=``), reached through the front door as
``SamplerConfig(shard=<block sites>|"auto")``.
"""
from repro.shard.shardmap import ShardMap, chain_segments
from repro.shard.store import (ShardedGammaStore, ShardViolation,
                               materialize_shard)

__all__ = ["ShardMap", "ShardedGammaStore", "ShardViolation",
           "chain_segments", "materialize_shard"]
