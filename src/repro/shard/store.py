"""A GammaStore view that *enforces* block-cyclic site ownership.

The acceptance contract for the sharded data plane is "no host read or
received a Γ segment it does not own".  Rather than asserting that after
the fact, the store refuses up front: :meth:`ShardedGammaStore._read_raw`
— the single choke point through which every Γ payload byte leaves disk —
raises :class:`ShardViolation` for a foreign site *before* touching the
file.  The engine's sharded walk therefore cannot silently fall back to
reading a neighbour's sites, and the per-engine ``io_bytes``/
``payload_reads`` counters count owned traffic only, by construction.

Two deployment shapes share the class:

* **shared root** (tests, single-filer clusters): every site file is
  visible to every host; the view only *restricts* what this host may
  read.  The streaming engine wraps a plain session store in this view
  automatically when a shard map is active.
* **materialized slice** (:func:`materialize_shard`): each host's root
  holds only its owned files (store capacity scales with hosts) plus the
  digest manifest, so :meth:`digest` still reproduces the whole store's
  Merkle root — the key the serving gateway's ResultCache addresses
  results by.
"""
from __future__ import annotations

import json
import os
import shutil

import jax.numpy as jnp

from repro.data.gamma_store import (MANIFEST_NAME, GammaStore, leaf_digest,
                                    merkle_root, site_filename)
from repro.shard.shardmap import ShardMap


class ShardViolation(RuntimeError):
    """A host touched (read, prefetched-with-force, wrote) a foreign site."""


class ShardedGammaStore(GammaStore):
    """One host's ownership-scoped view of a (possibly sliced) store."""

    def __init__(self, root: str, shard: ShardMap, host: int,
                 storage_dtype=jnp.bfloat16, compute_dtype=jnp.float32,
                 verify: bool = False):
        if not 0 <= host < shard.n_hosts:
            raise ValueError(f"host {host} outside the shard map's "
                             f"[0, {shard.n_hosts}) hosts")
        self.shard = shard
        self.host = int(host)
        super().__init__(root, storage_dtype=storage_dtype,
                         compute_dtype=compute_dtype, verify=verify)
        # n_sites is the GLOBAL chain length: schedules, identity padding
        # and digests are all chain-wide notions even when this root holds
        # only a slice of the files
        self._n_sites = int(shard.n_sites)

    # -- ownership enforcement ----------------------------------------------
    def _read_raw(self, i: int):
        if not self.shard.owns(self.host, i):
            raise ShardViolation(
                f"host {self.host} tried to read Γ site {i}, owned by host "
                f"{self.shard.owner(i)} (block={self.shard.block}, "
                f"hosts={self.shard.n_hosts}) — only the (N, χ) env crosses "
                f"hosts, never Γ")
        return super()._read_raw(i)

    def prefetch(self, i: int) -> None:
        # advisory, not a violation: blanket "schedule the next segment"
        # calls from the shared walk code may overrun an ownership boundary
        if self.shard.owns(self.host, i):
            super().prefetch(i)

    def put(self, i: int, gamma, lam) -> None:
        if not self.shard.owns(self.host, i):
            raise ShardViolation(
                f"host {self.host} tried to write Γ site {i}, owned by host "
                f"{self.shard.owner(i)}")
        super().put(i, gamma, lam)
        self._n_sites = int(self.shard.n_sites)   # global, not file count

    def meta(self, i: int = 0):
        """Shape probe (header only, no payload read).  A foreign site
        redirects to this host's first owned site — chains stream through
        one fixed (χ, χ, d) site shape, which is what callers probe for."""
        if not self.shard.owns(self.host, i):
            owned = self.shard.owned_sites(self.host)
            if not owned:
                raise ShardViolation(
                    f"host {self.host} owns no sites of the "
                    f"{self.shard.n_sites}-site chain "
                    f"(block={self.shard.block} × {self.shard.n_hosts} "
                    f"hosts) and cannot probe a site shape")
            i = owned[0]
        return super().meta(i)

    # -- global digest from a slice -----------------------------------------
    def digest(self) -> str:
        """The WHOLE store's Merkle root, computed from this host's owned
        leaves plus the manifest's (or, on a shared root with no manifest,
        by hashing the present foreign files directly — a metadata read,
        not a Γ payload read; the enforcement path is :meth:`_read_raw`)."""
        if self._digest is None:
            owned_leaves = self.site_digests()
            manifest = {}
            mpath = os.path.join(self.root, MANIFEST_NAME)
            if os.path.exists(mpath):
                with open(mpath) as fh:
                    manifest = json.load(fh)
            leaves = {}
            for i in range(self.shard.n_sites):
                f = site_filename(i)
                if f in owned_leaves:
                    leaves[f] = owned_leaves[f]
                elif f in manifest:
                    leaves[f] = manifest[f]
                elif os.path.exists(os.path.join(self.root, f)):
                    leaves[f] = self._leaf_for(f)
                else:
                    raise FileNotFoundError(
                        f"sharded digest needs {MANIFEST_NAME} covering "
                        f"foreign site {i} (host {self.host} does not hold "
                        f"{f}) — materialize_shard writes the manifest")
            self._digest = merkle_root(leaves)
        return self._digest

    def site_digests(self) -> dict[str, str]:
        """Leaves for this host's OWNED files only (foreign files on a
        shared root are not this host's to answer for — and hashing them
        would defeat the capacity-scaling story).  Leaves are cached per
        file stat signature (see :meth:`GammaStore._leaf_for`)."""
        leaves = {}
        for f in self._site_files():
            i = int(f[len("site_"):-len(".npz")])
            if self.shard.owns(self.host, i):
                leaves[f] = self._leaf_for(f)
        return leaves

    def verify_sites(self, sites=None) -> list[int]:
        """Pre-walk verification of this host's OWNED slice only — the
        engine's repair round calls this before the lockstep walk so a
        rotted site surfaces while a healthy peer can still serve it."""
        if sites is None:
            sites = list(self.shard.owned_sites(self.host))
        return super().verify_sites(sites)


def materialize_shard(src_root: str, dst_root: str, shard: ShardMap,
                      host: int, link: bool = True) -> str:
    """Pack host ``host``'s slice of the store at ``src_root`` into
    ``dst_root``: only the owned site files (hard-linked when the
    filesystem allows, else copied) plus the full digest manifest, so the
    slice still reproduces the global :meth:`GammaStore.digest`.  Per-host
    disk is O(chain / hosts) — the capacity axis the broadcast plane does
    not have."""
    os.makedirs(dst_root, exist_ok=True)
    leaves = {}
    for i in range(shard.n_sites):
        f = site_filename(i)
        src = os.path.join(src_root, f)
        with open(src, "rb") as fh:
            leaves[f] = leaf_digest(f, fh.read())
        if shard.owns(host, i):
            dst = os.path.join(dst_root, f)
            if os.path.exists(dst):
                os.remove(dst)
            if link:
                try:
                    os.link(src, dst)
                    continue
                except OSError:       # cross-device / unsupported: copy
                    pass
            shutil.copyfile(src, dst)
    mpath = os.path.join(dst_root, MANIFEST_NAME)
    tmp = mpath + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(leaves, fh, indent=0, sort_keys=True)
    os.replace(tmp, mpath)
    return dst_root


__all__ = ["ShardViolation", "ShardedGammaStore", "materialize_shard"]
