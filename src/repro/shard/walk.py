"""Wire codecs for the sharded walk's two traffic classes.

The pipelined walk (``StreamingEngine._sample_sharded``) moves exactly two
kinds of payload between hosts, both dicts of numpy arrays so every
``ClusterRuntime`` transport (in-process queues, npz-framed
``broadcast_one_to_all``) carries them unchanged:

* **env handoff** — at each ownership boundary the finishing owner ships
  ``(env (N, χ), log_scale (N,), base-key data, boundary site)`` to the
  next owner.  The key never advances along the chain (per-site keys are
  ``fold_in(base, global_site)``), so shipping it is purely a desync
  cross-check: a receiver whose base key differs is sampling a different
  job and must fail loudly, not emit a chimera batch.
* **sample blocks** — after the walk, each host's computed ``(L, N)``
  blocks meet in one all-gather so every process returns the identical
  ``(N, M)`` batch (the same contract the broadcast plane gets for free).

Bit-identity argument: the env crosses the wire as raw host-array bytes
(no recompression, no dtype cast), and the receiving owner applies the
same ``fit_env`` → segment-compute sequence the unsharded loop applies to
the very same array — so a sharded walk IS the unsharded walk, merely
executed on rotating hosts.
"""
from __future__ import annotations

import jax
import numpy as np


def payload_nbytes(payload: dict) -> int:
    """Wire size of a dict-of-arrays payload (what the runtimes count)."""
    return sum(int(v.nbytes) for v in payload.values()
               if isinstance(v, np.ndarray))


# -- env handoff -------------------------------------------------------------

def encode_handoff(env, log_scale, key, site: int, log_prob=None) -> dict:
    """``log_prob`` rides only on clamped walks (repro.workloads): the
    accumulated per-sample conditional weight is part of the carry, so it
    crosses ownership boundaries exactly like ``log_scale`` does."""
    payload = {"env": np.asarray(env), "log_scale": np.asarray(log_scale),
               "key": np.asarray(jax.random.key_data(key)),
               "site": np.asarray(int(site), dtype=np.int64)}
    if log_prob is not None:
        payload["log_prob"] = np.asarray(log_prob)
    return payload


def decode_handoff(payload: dict
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """→ (env, log_scale, base-key data, boundary site)."""
    return (np.asarray(payload["env"]), np.asarray(payload["log_scale"]),
            np.asarray(payload["key"]), int(payload["site"]))


def decode_handoff_log_prob(payload: dict):
    """The clamped-walk carry, or ``None`` on an unclamped handoff."""
    lp = payload.get("log_prob")
    return None if lp is None else np.asarray(lp)


# -- sample-block gather ------------------------------------------------------

_BLK = "blk_"


def encode_blocks(blocks: dict[int, np.ndarray]) -> dict:
    """{start_site: (L, N) block} → a flat savez-able payload."""
    return {f"{_BLK}{start:06d}": np.asarray(blk)
            for start, blk in sorted(blocks.items())}


def decode_blocks(payload: dict) -> dict[int, np.ndarray]:
    out = {}
    for k, v in payload.items():
        if k.startswith(_BLK):
            out[int(k[len(_BLK):])] = np.asarray(v)
    return out


def assemble_blocks(merged: dict[int, np.ndarray], n_sites: int,
                    n_samples: int) -> np.ndarray:
    """Gathered {start: (L, N)} blocks → the walk's (N, M) int32 output.
    Coverage must tile [0, n_sites) exactly — a hole or overlap means an
    owner's blocks went missing, which must fail loudly (a short batch
    would silently corrupt downstream statistics)."""
    out, cursor = [], 0
    for start in sorted(merged):
        blk = merged[start]
        if start != cursor:
            raise RuntimeError(
                f"sharded gather hole: sites [{cursor}, {start}) missing "
                f"(an owner's sample blocks never arrived)")
        if blk.shape[1] != n_samples:
            raise RuntimeError(
                f"sharded gather block at site {start} carries "
                f"{blk.shape[1]} samples, expected {n_samples}")
        out.append(blk)
        cursor += blk.shape[0]
    if cursor != n_sites:
        raise RuntimeError(f"sharded gather covers [0, {cursor}) of "
                           f"[0, {n_sites})")
    return np.concatenate(out, axis=0).T.astype(np.int32)


__all__ = ["assemble_blocks", "decode_blocks", "decode_handoff",
           "decode_handoff_log_prob", "encode_blocks", "encode_handoff",
           "payload_nbytes"]
