"""Block-cyclic site→owner assignment and its handoff algebra.

The whole sharded data plane reduces to one pure function::

    owner(site) = (site // block) % n_hosts

Everything else — which segments a host fetches, where the environment
crosses the wire, why every site is computed exactly once — is derived
from it here, in plain host-side arithmetic, so the invariants are
property-testable without touching jax (tests/test_shard.py):

* every site has exactly one owner, and the owners' ``owned_sites`` sets
  partition the chain;
* a scheduled segment never straddles two owners
  (:meth:`ShardMap.segment_owner` raises otherwise — the planner checks
  this at resolve time, the engine re-checks against the *real* schedule);
* the handoff sequence follows chain order: boundaries are strictly
  increasing and each handoff's source is the owner on the left, its
  destination the owner on the right.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


def chain_segments(n_sites: int, segment_len: int,
                   stages: Optional[Sequence] = None) -> list[tuple]:
    """The streamed walk's segment boundaries: ``segment_len``-sized chunks
    that never cross a χ-stage boundary.

    This is THE schedule shape shared by the engine
    (``StreamingEngine._segment_schedule`` attaches each stage's χ) and the
    planner's shard validation — deriving it twice independently is how a
    plan-time "every segment is single-owner" proof could silently diverge
    from the walk the engine actually runs.  ``stages`` entries are
    ``(start, stop, chi)``; ``None`` means one fixed-χ stage."""
    if stages is None:
        stages = [(0, n_sites, None)]
    out = []
    for s0, s1, chi_s in stages:
        c = s0
        while c < s1:
            out.append((c, min(c + segment_len, s1), chi_s))
            c = min(c + segment_len, s1)
    return out


@dataclasses.dataclass(frozen=True)
class ShardMap:
    """Block-cyclic chain sharding: site ``i`` → host ``(i//block) % H``."""
    n_sites: int
    n_hosts: int
    block: int          # contiguous sites per ownership block

    def __post_init__(self):
        if self.n_sites < 1:
            raise ValueError(f"n_sites must be ≥ 1, got {self.n_sites}")
        if self.n_hosts < 1:
            raise ValueError(f"n_hosts must be ≥ 1, got {self.n_hosts}")
        if self.block < 1:
            raise ValueError(f"block must be ≥ 1 site, got {self.block}")

    # -- ownership -----------------------------------------------------------
    def owner(self, site: int) -> int:
        if not 0 <= site < self.n_sites:
            raise IndexError(f"site {site} outside chain [0, {self.n_sites})")
        return (site // self.block) % self.n_hosts

    def owns(self, host: int, site: int) -> bool:
        return self.owner(site) == host

    def owned_sites(self, host: int) -> list[int]:
        if not 0 <= host < self.n_hosts:
            raise IndexError(f"host {host} outside [0, {self.n_hosts})")
        return [i for i in range(self.n_sites) if self.owner(i) == host]

    @property
    def n_blocks(self) -> int:
        return -(-self.n_sites // self.block)

    # -- schedule algebra ----------------------------------------------------
    def segment_owner(self, start: int, stop: int) -> int:
        """The single owner of sites [start, stop); raises if the segment
        straddles an ownership boundary (the walk contracts a segment on
        exactly one host — a split segment has no well-defined owner)."""
        if not 0 <= start < stop <= self.n_sites:
            raise IndexError(f"segment [{start}, {stop}) outside chain "
                             f"[0, {self.n_sites}]")
        own = self.owner(start)
        if self.n_hosts > 1 and self.owner(stop - 1) != own:
            raise ValueError(
                f"segment [{start}, {stop}) straddles an ownership boundary "
                f"(block={self.block}, hosts={self.n_hosts}): sites {start} "
                f"and {stop - 1} belong to hosts {own} and "
                f"{self.owner(stop - 1)} — align segment_len/χ-stage "
                f"boundaries to the shard block")
        return own

    def owners_for(self, schedule: Sequence) -> list[int]:
        """Per-segment owners for a ``chain_segments``-shaped schedule
        (extra tuple entries beyond (start, stop) are ignored)."""
        return [self.segment_owner(s[0], s[1]) for s in schedule]

    def handoffs(self, schedule: Sequence) -> list[tuple[int, int, int]]:
        """[(boundary_site, src_host, dst_host)] — the walk's wire plan:
        one (N, χ) env transfer wherever consecutive segments change owner.
        Chain order by construction (boundaries strictly increase)."""
        owners = self.owners_for(schedule)
        out = []
        for k in range(1, len(owners)):
            if owners[k] != owners[k - 1]:
                out.append((schedule[k][0], owners[k - 1], owners[k]))
        return out


__all__ = ["ShardMap", "chain_segments"]
