"""JAX version compatibility shims.

The repo targets the modern ``jax.shard_map`` API (keyword ``check_vma``);
on jax 0.4.x the function lives in ``jax.experimental.shard_map`` and the
replication-check keyword is ``check_rep``.  Everything routes through
:func:`shard_map` here so call sites stay version-agnostic.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def axis_size(axis_name) -> int:
    """Static size of a mapped axis, usable inside shard_map bodies (the
    result sizes slices, so it must be a Python int, not a traced psum(1))."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.core.axis_frame(axis_name)   # 0.4.x: returns the frame size
