"""jit'd public wrappers around the Pallas kernels.

Routing goes through :mod:`repro.kernels.dispatch`: ``kernels="auto"``
resolves to the compiled Pallas cell on a real TPU backend and the XLA
reference everywhere else (where the Pallas cells run with
``interpret=True`` when requested explicitly — ``tests/test_kernels.py``
sweeps shapes/dtypes against ``ref.py`` that way).

Historical note: ``collapse_rescale`` used to take the materialized
``temp[N, χ, d]`` and unconditionally call the pure-jnp reference — the
collapse never reached the ``collapse_select`` Pallas kernel on TPU *and*
forced the caller to keep the very intermediate the kernel exists to
avoid.  It now takes ``(env, Γ, samples)`` and dispatches the
sample-selected collapse GEMM + §3.3 per-sample rescale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dispatch import get_site_op, resolve_kernels
from repro.kernels.displacement_expm import displacement_expm as _de_kernel

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("kernels",))
def contract_measure(env: Array, gamma: Array, lam: Array,
                     kernels: str = "auto"):
    """Fused site contraction + linear measurement. Returns (temp, probs)."""
    op = get_site_op("contract_measure", "linear", kernels)
    return op(env, gamma, lam, semantics="linear", compute_dtype=None)


@functools.partial(jax.jit, static_argnames=("d", "use_kernel"))
def displacement_matrices(mu: Array, d: int, use_kernel: bool = True) -> Array:
    """Batched D(μ) (B, d, d) complex from complex μ (B,)."""
    mre, mim = jnp.real(mu), jnp.imag(mu)
    if not use_kernel:
        ore, oim = _ref.displacement_zassenhaus_ref(mre, mim, d)
    else:
        bb = 128 if mu.shape[0] % 128 == 0 else (
            mu.shape[0] if mu.shape[0] < 128 else 1)
        ore, oim = _de_kernel(mre, mim, d, bb=bb, interpret=not _on_tpu())
    return ore + 1j * oim


@functools.partial(jax.jit, static_argnames=("kernels",))
def collapse_rescale(env: Array, gamma: Array, samples: Array,
                     kernels: str = "auto"):
    """Sample-selected collapse + per-sample rescale (§3.3), dispatched:
    env (N, L) · Γ[:, :, sₙ] → env' (N, R), rescaled to unit per-row max.

    The Pallas cell (``collapse_select``) keeps the masked operand
    VMEM-resident so the (N, χ, d) temp never exists; the XLA cell runs the
    d masked GEMMs.  Resolution follows :func:`dispatch.resolve_kernels`.
    """
    op = get_site_op("collapse", "linear", kernels)
    env_new = op(env, gamma, samples, compute_dtype=None)
    m = jnp.max(jnp.abs(env_new), axis=1, keepdims=True)
    return env_new / jnp.where(m > 0, m, 1.0)


def site_step(env: Array, gamma: Array, lam: Array, u: Array,
              kernels: str = "auto", semantics: str = "linear",
              scaling: str = "per_sample"):
    """The whole fused pipeline for one site (see ``kernels/site_step.py``):
    contract → measure → inverse-CDF draw with the given uniforms u (N, 1)
    → collapse → rescale.  Returns (env', samples, dlog)."""
    op = get_site_op("site_step", semantics, kernels)
    return op(env, gamma, lam, u, scaling=scaling, compute_dtype=None)


__all__ = ["contract_measure", "displacement_matrices", "collapse_rescale",
           "site_step", "resolve_kernels"]
