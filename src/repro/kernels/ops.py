"""jit'd public wrappers around the Pallas kernels.

On a real TPU backend the kernels run compiled; everywhere else (this
container) they run with ``interpret=True`` against the same BlockSpecs, and
``tests/test_kernels.py`` sweeps shapes/dtypes against ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.contract_measure import contract_measure as _cm_kernel
from repro.kernels.displacement_expm import displacement_expm as _de_kernel

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def contract_measure(env: Array, gamma: Array, lam: Array,
                     use_kernel: bool = True):
    """Fused site contraction + linear measurement. Returns (temp, probs)."""
    if not use_kernel:
        return _ref.contract_measure_ref(env, gamma, lam)
    n, chi = env.shape
    d = gamma.shape[2]
    # MXU-aligned tiles when shapes allow; fall back to whole-array blocks.
    def tile(sz, pref):
        for t in (pref, 256, 128, 64, 32, 16, 8, 4, 2, 1):
            if t <= sz and sz % t == 0:
                return t
        return sz
    bn, br, bl = tile(n, 256), tile(gamma.shape[1], 256), tile(chi, 256)
    return _cm_kernel(env, gamma, lam, bn=bn, br=br, bl=bl,
                      interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("d", "use_kernel"))
def displacement_matrices(mu: Array, d: int, use_kernel: bool = True) -> Array:
    """Batched D(μ) (B, d, d) complex from complex μ (B,)."""
    mre, mim = jnp.real(mu), jnp.imag(mu)
    if not use_kernel:
        ore, oim = _ref.displacement_zassenhaus_ref(mre, mim, d)
    else:
        bb = 128 if mu.shape[0] % 128 == 0 else (
            mu.shape[0] if mu.shape[0] < 128 else 1)
        ore, oim = _de_kernel(mre, mim, d, bb=bb, interpret=not _on_tpu())
    return ore + 1j * oim


def collapse_rescale(temp: Array, samples: Array) -> Array:
    """Collapse + per-sample rescale (bandwidth-bound; XLA fuses this fine)."""
    return _ref.collapse_rescale_ref(temp, samples)
