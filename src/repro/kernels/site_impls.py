"""Registered implementations behind ``kernels/dispatch.py``.

Every stage has an ``xla`` implementation that is *literally the shipping
math* the data planes ran before the dispatch layer existed (moved here,
not rewritten — the ``kernels="xla"`` cell of every schedule must stay
bit-identical to the pre-dispatch code), plus a ``pallas`` implementation
routing to the fused kernels with autotuned block sizes
(``interpret=True`` off-TPU, so CI runs the same program the TPU compiles).

Uniform stage signatures (semantics is part of the registry key):

* ``site_step(env, gamma, lam, u, *, scaling, compute_dtype)``
  → ``(env', samples, dlog)``
* ``contract_measure(env, gamma, lam, *, compute_dtype)`` → ``(temp, probs)``
* ``measure(env, w, *, compute_dtype)`` → partial probs ``(N, d)``
* ``collapse(env, gamma, samples, *, compute_dtype)`` → ``env' (N, R)``
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import precision
from repro.kernels import collapse_select as CS
from repro.kernels import contract_measure as CM
from repro.kernels import site_step as SS
from repro.kernels.dispatch import autotune, on_tpu, register_site_op

Array = jax.Array


def draw_from_uniform(probs: Array, u: Array) -> Array:
    """Alg. 1 lines 2-4 given the per-sample uniforms: normalise, cumsum,
    threshold draw.  probs (N, d) ≥ 0; u (N, 1) in [0, 1)."""
    probs = jnp.clip(probs, 0.0, None)
    total = jnp.sum(probs, axis=1, keepdims=True)
    # Guard fully-underflowed rows: fall back to uniform (paper Fig. 6 failure
    # mode — with per-sample scaling this should never trigger).
    safe = jnp.where(total > 0, probs / jnp.where(total > 0, total, 1.0),
                     jnp.ones_like(probs) / probs.shape[1])
    cdf = jnp.cumsum(safe, axis=1)
    return jnp.sum((u > cdf).astype(jnp.int32), axis=1).clip(
        0, probs.shape[1] - 1)


# ---------------------------------------------------------------------------
# site_step — the whole Alg. 1 pipeline for one site
# ---------------------------------------------------------------------------

def _contract_site(env: Array, gamma: Array, compute_dtype,
                   semantics: str) -> Array:
    """The contraction exactly as ``core/sampler.site_step`` ran it."""
    if compute_dtype is not None and semantics == "linear":
        return jax.lax.dot_general(
            env.astype(compute_dtype),
            gamma.reshape(gamma.shape[0], -1).astype(compute_dtype),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(env.shape[0], gamma.shape[1],
                  gamma.shape[2]).astype(env.dtype)
    return jnp.einsum("nl,lrs->nrs", env, gamma)


def measure_probs_xla(temp: Array, lam: Array, semantics: str) -> Array:
    """Alg. 1 line 1 for either semantics (shared by sampler & parallel)."""
    if semantics == "linear":
        return jnp.einsum("nrs,r->ns", temp, lam)
    scaled = temp * lam[None, :, None]
    return jnp.sum(jnp.abs(scaled) ** 2, axis=1)


def site_probs_dtype(env: Array, gamma: Array, lam: Array, semantics: str,
                     compute_dtype) -> jnp.dtype:
    """The dtype the measurement probabilities (and hence the inverse-CDF
    uniforms) come out as — callers pre-draw ``u`` with exactly this dtype
    so the fused path consumes the same bits the XLA path would."""
    out = jax.eval_shape(
        lambda e, g, l: measure_probs_xla(
            _contract_site(e, g, compute_dtype, semantics), l, semantics),
        env, gamma, lam)
    return out.dtype


def _site_step_xla(env, gamma, lam, u, *, semantics, scaling, compute_dtype):
    temp = _contract_site(env, gamma, compute_dtype, semantics)
    probs = measure_probs_xla(temp, lam, semantics)
    samples = draw_from_uniform(probs, u)
    new_env = jnp.take_along_axis(
        temp, samples[:, None, None].astype(jnp.int32), axis=2)[:, :, 0]
    if semantics == "born":
        new_env = new_env * lam[None, :]
    new_env, dlog = precision.rescale(new_env, mode=scaling)
    return new_env, samples, dlog


@register_site_op("site_step", "linear", "xla")
def site_step_linear_xla(env, gamma, lam, u, *, scaling, compute_dtype):
    return _site_step_xla(env, gamma, lam, u, semantics="linear",
                          scaling=scaling, compute_dtype=compute_dtype)


@register_site_op("site_step", "born", "xla")
def site_step_born_xla(env, gamma, lam, u, *, scaling, compute_dtype):
    return _site_step_xla(env, gamma, lam, u, semantics="born",
                          scaling=scaling, compute_dtype=compute_dtype)


def _fused_blocks(stage, env, gamma, planes):
    n, chi_l = env.shape
    chi_r, d = gamma.shape[1], gamma.shape[2]
    return autotune(stage, n=n, chi_l=chi_l, chi_r=chi_r, d=d,
                    dtype=env.dtype, planes=planes)


@register_site_op("site_step", "linear", "pallas")
def site_step_linear_pallas(env, gamma, lam, u, *, scaling, compute_dtype):
    cfg = _fused_blocks("site_step", env, gamma, planes=1)
    fused_scaling = scaling if scaling in ("per_sample", "none") else "none"
    env2, samples, dlog = SS.site_step_linear(
        env, gamma, lam, u[:, 0], bn=cfg.bn, br=cfg.br, bl=cfg.bl,
        scaling=fused_scaling, compute_dtype=compute_dtype,
        interpret=not on_tpu())
    if scaling == "global":            # the global max crosses n-tiles
        env2, dlog = precision.rescale(env2, "global")
    return env2, samples.astype(jnp.int_), dlog


@register_site_op("site_step", "born", "pallas")
def site_step_born_pallas(env, gamma, lam, u, *, scaling, compute_dtype):
    del compute_dtype                  # born runs in the amplitudes' dtype
    cfg = _fused_blocks("site_step", env, gamma, planes=2)
    fused_scaling = scaling if scaling in ("per_sample", "none") else "none"
    env2, samples, dlog = SS.site_step_born(
        env, gamma, lam, u[:, 0], bn=cfg.bn, br=cfg.br, bl=cfg.bl,
        scaling=fused_scaling, interpret=not on_tpu())
    if scaling == "global":
        env2, dlog = precision.rescale(env2, "global")
    return env2, samples.astype(jnp.int_), dlog


# ---------------------------------------------------------------------------
# contract_measure — the split-K TP schedules' (temp, probs) pair
# ---------------------------------------------------------------------------

def contract_parallel(env: Array, gamma: Array, compute_dtype) -> Array:
    """The segment-runner contraction (compute_dtype applies to both
    semantics, unlike the seq-scan one above) — ``core/parallel._contract``
    delegates here so the dispatched xla cells and the born split-K paths
    share ONE implementation."""
    n = env.shape[0]
    r, d = gamma.shape[1], gamma.shape[2]
    if compute_dtype is not None:
        out = jax.lax.dot_general(
            env.astype(compute_dtype),
            gamma.reshape(gamma.shape[0], -1).astype(compute_dtype),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(env.dtype)
        return out.reshape(n, r, d)
    return jnp.einsum("nl,lrs->nrs", env, gamma)


@register_site_op("contract_measure", "*", "xla")
def contract_measure_xla(env, gamma, lam, *, semantics, compute_dtype):
    temp = contract_parallel(env, gamma, compute_dtype)
    return temp, measure_probs_xla(temp, lam, semantics)


@register_site_op("contract_measure", "linear", "pallas")
def contract_measure_pallas(env, gamma, lam, *, semantics, compute_dtype):
    del semantics                      # registry key guarantees "linear"
    cfg = _fused_blocks("contract_measure", env, gamma, planes=1)
    e, g = env, gamma
    if compute_dtype is not None:
        e, g = env.astype(compute_dtype), gamma.astype(compute_dtype)
    temp, probs = CM.contract_measure(e, g, lam, bn=cfg.bn, br=cfg.br,
                                      bl=cfg.bl, interpret=not on_tpu())
    if temp.dtype != env.dtype and env.dtype not in (jnp.bfloat16,
                                                     jnp.float16):
        temp, probs = temp.astype(env.dtype), probs.astype(env.dtype)
    return temp, probs


# ---------------------------------------------------------------------------
# measure — the tp-3 measure-first partial probs (linear only)
# ---------------------------------------------------------------------------

@register_site_op("measure", "linear", "xla")
def measure_xla(env, w, *, compute_dtype):
    if compute_dtype is not None:
        return jax.lax.dot_general(
            env.astype(compute_dtype), w.astype(compute_dtype),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(jnp.float32)
    return env @ w


@register_site_op("measure", "linear", "pallas")
def measure_pallas(env, w, *, compute_dtype):
    n, L = env.shape
    cfg = autotune("measure", n=n, chi_l=L, chi_r=L, d=w.shape[1],
                   dtype=env.dtype)
    out = SS.measure_probs(env, w, bn=cfg.bn, bl=cfg.bl,
                           compute_dtype=compute_dtype,
                           interpret=not on_tpu())
    if compute_dtype is not None:
        out = out.astype(jnp.float32)
    return out


# ---------------------------------------------------------------------------
# collapse — the sample-selected collapse GEMM (linear only)
# ---------------------------------------------------------------------------

@register_site_op("collapse", "linear", "xla")
def collapse_xla(env, gamma, samples, *, compute_dtype):
    """d masked GEMMs — the XLA analogue of the fused select."""
    d = gamma.shape[2]
    acc = None
    for s in range(d):
        mask = (samples == s).astype(env.dtype)[:, None]
        part = measure_xla(env * mask, gamma[:, :, s],
                           compute_dtype=compute_dtype)
        acc = part if acc is None else acc + part
    return acc


@register_site_op("collapse", "linear", "pallas")
def collapse_pallas(env, gamma, samples, *, compute_dtype):
    cfg = _fused_blocks("collapse", env, gamma, planes=1)
    e, g = env, gamma
    if compute_dtype is not None:
        e, g = env.astype(compute_dtype), gamma.astype(compute_dtype)
    return CS.collapse_select(e, g, samples, bn=cfg.bn, br=cfg.br,
                              bl=cfg.bl, interpret=not on_tpu())


# ---------------------------------------------------------------------------
# Autotuner warm-up (the timed TPU sweep must run OUTSIDE any jit trace)
# ---------------------------------------------------------------------------

def warm_site_step(n: int, chi: int, d: int, dtype, *, semantics: str,
                   scaling: str = "per_sample", compute_dtype=None) -> None:
    """Populate the autotuner cache for one site-step shape.

    Off-TPU this just seeds the heuristic entry (no compilation).  On TPU
    it runs the timed sweep with concrete zero operands, so the in-trace
    ``autotune`` lookups that follow are pure cache hits — which is why
    the session backends call this *before* jitting the chain walk.
    """
    planes = 2 if semantics == "born" else 1
    rdt = jnp.zeros((), dtype=dtype).real.dtype
    probe = None
    if on_tpu():
        env = jnp.zeros((n, chi), dtype=dtype)
        gamma = jnp.zeros((chi, chi, d), dtype=dtype)
        lam = jnp.zeros((chi,), dtype=rdt)
        u = jnp.zeros((n,), dtype=rdt)
        kern = (SS.site_step_born if semantics == "born"
                else SS.site_step_linear)
        kw = {} if semantics == "born" else {"compute_dtype": compute_dtype}
        fused_scaling = (scaling if scaling in ("per_sample", "none")
                         else "none")

        def probe(cfg):
            return lambda: kern(env, gamma, lam, u, bn=cfg.bn, br=cfg.br,
                                bl=cfg.bl, scaling=fused_scaling, **kw)

    autotune("site_step", n=n, chi_l=chi, chi_r=chi, d=d, dtype=dtype,
             planes=planes, probe=probe)


def _env_dtype_of(gamma_dtype):
    """The dtype the walk's environment carries (what the in-trace autotune
    lookups are keyed on): Γ storage may be half-precision, environments
    never are (§3.3.2 storage ≠ compute)."""
    dt = jnp.dtype(gamma_dtype)
    return jnp.float32 if dt in (jnp.bfloat16, jnp.float16) else dt


def warm_tp_stages(n: int, chi: int, d: int, dtype, *, p2: int, scheme: str,
                   measure_first: bool = False, compute_dtype=None) -> None:
    """Populate the autotuner cache for the sharded TP stage shapes.

    The TP schedules never run the fused ``site_step`` — their per-site
    work is the dispatched ``contract_measure`` / ``measure`` / ``collapse``
    stages over bond-sharded operands (χ/p₂ splits), so warming the
    seq/dp site-step shape alone leaves every TP lookup a cold miss (and on
    TPU the timed sweep cannot run inside the shard_map trace at all).
    Shapes mirror ``core/parallel`` exactly:

    * ``tp_single``        — contract_measure(env (N₂, χ/p₂), Γ (χ/p₂, χ, d))
    * ``tp_single`` + tp-3 — measure(env (N₂, χ/p₂), W (χ/p₂, d)) and
                             collapse(env (N₂, χ/p₂), Γ (χ/p₂, χ, d))
    * ``tp_double``        — the odd half-site's (χ/p₂ → χ) contract_measure
                             plus the even half-site's (χ → χ/p₂) one

    Linear semantics only: the Born split-K TP cells keep their XLA
    implementations by design (|Σ·|² ≠ Σ|·|²), so there is nothing to warm.
    """
    assert chi % p2 == 0, (chi, p2)
    env_dt = _env_dtype_of(dtype)
    chi_shard = chi // p2
    itp = not on_tpu()

    def _warm(stage, chi_l, chi_r, kern_probe):
        probe = None
        if on_tpu():
            env = jnp.zeros((n, chi_l), dtype=env_dt)

            def probe(cfg, _env=env, _chi_r=chi_r, _kp=kern_probe):
                return lambda: _kp(_env, _chi_r, cfg)
        autotune(stage, n=n, chi_l=chi_l, chi_r=chi_r, d=d, dtype=env_dt,
                 planes=1, probe=probe)

    def _cm(env, chi_r, cfg):
        gamma = jnp.zeros((env.shape[1], chi_r, d), dtype=env_dt)
        lam = jnp.zeros((chi_r,), dtype=env_dt)
        e, g = env, gamma
        if compute_dtype is not None:
            e, g = env.astype(compute_dtype), gamma.astype(compute_dtype)
        return CM.contract_measure(e, g, lam, bn=cfg.bn, br=cfg.br,
                                   bl=cfg.bl, interpret=itp)

    def _ms(env, chi_r, cfg):
        w = jnp.zeros((env.shape[1], d), dtype=env_dt)
        return SS.measure_probs(env, w, bn=cfg.bn, bl=cfg.bl,
                                compute_dtype=compute_dtype, interpret=itp)

    def _cl(env, chi_r, cfg):
        gamma = jnp.zeros((env.shape[1], chi_r, d), dtype=env_dt)
        samples = jnp.zeros((n,), dtype=jnp.int32)
        e, g = env, gamma
        if compute_dtype is not None:
            e, g = env.astype(compute_dtype), gamma.astype(compute_dtype)
        return CS.collapse_select(e, g, samples, bn=cfg.bn, br=cfg.br,
                                  bl=cfg.bl, interpret=itp)

    if scheme == "tp_single" and measure_first:
        _warm("measure", chi_shard, chi_shard, _ms)
        _warm("collapse", chi_shard, chi, _cl)
    elif scheme == "tp_single":
        _warm("contract_measure", chi_shard, chi, _cm)
    elif scheme == "tp_double":
        _warm("contract_measure", chi_shard, chi, _cm)   # odd half-site
        _warm("contract_measure", chi, chi_shard, _cm)   # even half-site
    else:
        raise ValueError(f"warm_tp_stages covers the TP schemes, "
                         f"got {scheme!r}")
