"""Fused MPS site-step pipeline — Pallas TPU kernels (§Perf iteration ks-4).

One site of Alg. 1 is contract → measure → normalise/cumsum/draw →
collapse(+λ) → per-sample rescale.  Run as separate XLA ops the unmeasured
``temp[N, χ, d]`` intermediate makes **three** HBM round trips per site
(write after the GEMM, read for the measurement, read again for the
collapse) — exactly the traffic ``bench_roofline.py`` models as the
memory-bound term at large χ.  These kernels keep ``temp`` VMEM-resident
for the whole pipeline: per n-tile the full ``(BN, χ_r, d)`` slab lives in
a VMEM scratch across the (r, l) tile sweep, the inverse-CDF draw and the
collapse happen on-chip, and only ``env'[N, χ_r]``, ``samples[N]`` and
``dlog[N]`` are ever written back — the ``(N, χ, d)`` intermediate never
touches HBM.

Kernels (all dispatched through ``kernels/dispatch.py``):

* :func:`site_step_linear` — the full fused pipeline, linear semantics
  (paper Alg. 1).  Grid ``(n_tiles, r_tiles, l_tiles)``, l innermost
  (sequential split-K on TPU); the draw/collapse/rescale epilogue runs once
  per n-tile on the last (r, l) program.
* :func:`site_step_born` — same pipeline for Born semantics.  Complex
  amplitudes ride as split re/im planes (the MXU has no complex type):
  two GEMMs per plane, ``probs = Σ_r (re² + im²)·λ²``, collapse ×λ, and
  the per-sample max over ``|env'| = √(re² + im²)``.
* :func:`measure_probs` — measure-only variant for the TP split-K
  schedules: the tp-3 ``probs_partial = env_shard @ W_shard`` GEMM whose
  (N, d) output is what crosses the wire *before* the big collective.
* the collapse-only variant is :func:`kernels.collapse_select.collapse_select`
  (sample-selected GEMM, masked operand VMEM-resident).

Randomness stays outside: the caller passes the per-site uniforms
``u[N]`` (drawn from the same folded key as the XLA path), so the fused
path is draw-for-draw identical to ``core/sampler.site_step`` — the §4.1
seed contract extends across the kernel boundary and is asserted in
``tests/test_site_step.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _acc_dtype_for(dtype) -> jnp.dtype:
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def _draw(probs: Array, u: Array) -> Array:
    """Alg. 1 lines 2-4 on a (BN, d) tile: normalise → cumsum → threshold.

    Mirrors ``core.sampler.draw_from_uniform`` op-for-op so interpret-mode
    runs stay bit-compatible with the XLA path.
    """
    d = probs.shape[1]
    probs = jnp.clip(probs, 0.0, None)
    total = jnp.sum(probs, axis=1, keepdims=True)
    safe = jnp.where(total > 0, probs / jnp.where(total > 0, total, 1.0),
                     jnp.ones_like(probs) / d)
    cdf = jnp.cumsum(safe, axis=1)
    return jnp.sum((u[:, None] > cdf).astype(jnp.int32), axis=1).clip(0, d - 1)


def _collapse(temp: Array, samples: Array, d: int) -> Array:
    """temp (BN, χr, d) → temp[n, :, s_n] via d masked adds (VPU-local)."""
    acc = jnp.zeros(temp.shape[:2], dtype=temp.dtype)
    for s in range(d):
        mask = (samples == s).astype(temp.dtype)[:, None]
        acc = acc + mask * temp[:, :, s]
    return acc


def _rescale(env: Array, mag: Array, scaling: str):
    """Per-sample §3.3 rescale on a full (BN, χr) row; ``mag`` = |env|.

    Returns (factor (BN, 1), dlog (BN,)).  ``scaling == "global"`` cannot be
    fused (the max crosses n-tiles) — the wrapper rejects it.
    """
    if scaling == "none":
        n = env.shape[0]
        return jnp.ones((n, 1), dtype=mag.dtype), jnp.zeros((n,), mag.dtype)
    m = jnp.max(mag, axis=1, keepdims=True)
    factor = jnp.where(m > 0, m, 1.0)
    return factor, jnp.log10(factor[:, 0])


# ---------------------------------------------------------------------------
# Linear semantics: the paper-faithful Alg. 1 pipeline
# ---------------------------------------------------------------------------

def _linear_kernel(env_ref, gamma_ref, lam_ref, u_ref,
                   env_out_ref, samples_ref, dlog_ref,
                   temp_ref, acc_ref, probs_ref,
                   *, n_r: int, n_l: int, br: int, d: int,
                   scaling: str, out_dtype, compute_dtype):
    j = pl.program_id(1)      # r tile
    k = pl.program_id(2)      # l tile (sequential reduction)
    acc_dtype = acc_ref.dtype

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    env = env_ref[...]                              # (BN, BL)
    gam = gamma_ref[...]                            # (BL, BR, d)
    bl = gam.shape[0]
    if compute_dtype is not None:
        env = env.astype(compute_dtype)
        gam = gam.astype(compute_dtype)
    acc_ref[...] += jax.lax.dot_general(
        env, gam.reshape(bl, br * d),
        (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    ).reshape(env.shape[0], br, d)

    @pl.when(k == n_l - 1)
    def _measured():
        temp = acc_ref[...]
        # park this r tile of temp in the VMEM slab (never leaves the chip)
        temp_ref[:, pl.ds(j * br, br), :] = temp
        contrib = jax.lax.dot_general(
            temp.swapaxes(1, 2).reshape(-1, br),        # (BN·d, BR)
            lam_ref[...].astype(acc_dtype),
            (((1,), (0,)), ((), ())),
            preferred_element_type=acc_dtype,
        ).reshape(temp.shape[0], d)

        @pl.when(j == 0)
        def _set():
            probs_ref[...] = contrib

        @pl.when(j > 0)
        def _add():
            probs_ref[...] += contrib

    @pl.when((j == n_r - 1) & (k == n_l - 1))
    def _epilogue():
        # whole-site state for this n tile is on-chip: draw, collapse, rescale
        samples = _draw(probs_ref[...].astype(out_dtype), u_ref[...])
        env_new = _collapse(temp_ref[...].astype(out_dtype), samples, d)
        factor, dlog = _rescale(env_new, jnp.abs(env_new), scaling)
        env_out_ref[...] = env_new / factor
        samples_ref[...] = samples.astype(jnp.int32)
        dlog_ref[...] = dlog.astype(dlog_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "br", "bl", "scaling",
                                             "compute_dtype", "interpret"))
def site_step_linear(env: Array, gamma: Array, lam: Array, u: Array,
                     bn: int = 256, br: int = 256, bl: int = 256,
                     scaling: str = "per_sample",
                     compute_dtype=None,
                     interpret: bool = False):
    """Fused site step: env (N, χl), Γ (χl, χr, d), Λ (χr), u (N,) →
    (env' (N, χr), samples (N,) int32, dlog (N,)).

    VMEM working set ≈ BN·BL + BL·BR·d + 2·BN·BR·d + **BN·χr·d** (the
    resident temp slab) + BN·χr words — the autotuner sizes BN so the slab
    fits; χr itself is never tiled out of VMEM, which is the whole point.
    """
    n, chi_l = env.shape
    _, chi_r, d = gamma.shape
    if scaling not in ("per_sample", "none"):
        raise ValueError(f"fused site step cannot do scaling={scaling!r} "
                         "(the max crosses n-tiles); rescale outside")
    bn, br, bl = min(bn, n), min(br, chi_r), min(bl, chi_l)
    assert n % bn == 0 and chi_r % br == 0 and chi_l % bl == 0, \
        (n, chi_l, chi_r, bn, br, bl)
    grid = (n // bn, chi_r // br, chi_l // bl)
    out_dtype = (jnp.float32 if env.dtype in (jnp.bfloat16, jnp.float16)
                 else env.dtype)
    acc_dtype = _acc_dtype_for(env.dtype)

    kern = functools.partial(
        _linear_kernel, n_r=grid[1], n_l=grid[2], br=br, d=d,
        scaling=scaling, out_dtype=out_dtype, compute_dtype=compute_dtype)
    env_new, samples, dlog = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bl), lambda i, j, k: (i, k)),
            pl.BlockSpec((bl, br, d), lambda i, j, k: (k, j, 0)),
            pl.BlockSpec((br,), lambda i, j, k: (j,)),
            pl.BlockSpec((bn,), lambda i, j, k: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn, chi_r), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bn,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, chi_r), out_dtype),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), out_dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bn, chi_r, d), acc_dtype),    # the resident temp slab
            pltpu.VMEM((bn, br, d), acc_dtype),       # split-K accumulator
            pltpu.VMEM((bn, d), acc_dtype),           # probs accumulator
        ],
        interpret=interpret,
    )(env, gamma, lam, u)
    return env_new, samples, dlog


# ---------------------------------------------------------------------------
# Born semantics: complex amplitudes as split re/im planes
# ---------------------------------------------------------------------------

def _born_kernel(ere_ref, eim_ref, gre_ref, gim_ref, lam_ref, u_ref,
                 ore_ref, oim_ref, samples_ref, dlog_ref,
                 sre_ref, sim_ref, acc_re_ref, acc_im_ref, probs_ref,
                 *, n_r: int, n_l: int, br: int, d: int,
                 scaling: str, out_dtype):
    j = pl.program_id(1)
    k = pl.program_id(2)
    acc_dtype = acc_re_ref.dtype

    @pl.when(k == 0)
    def _init_acc():
        acc_re_ref[...] = jnp.zeros_like(acc_re_ref)
        acc_im_ref[...] = jnp.zeros_like(acc_im_ref)

    ere, eim = ere_ref[...], eim_ref[...]           # (BN, BL)
    gre, gim = gre_ref[...], gim_ref[...]           # (BL, BR, d)
    bl = gre.shape[0]

    def mm(a, b):
        return jax.lax.dot_general(
            a, b.reshape(bl, br * d),
            (((1,), (0,)), ((), ())),
            preferred_element_type=acc_dtype,
        ).reshape(a.shape[0], br, d)

    # (ere + i·eim)(gre + i·gim): four real GEMMs per tile
    acc_re_ref[...] += mm(ere, gre) - mm(eim, gim)
    acc_im_ref[...] += mm(ere, gim) + mm(eim, gre)

    @pl.when(k == n_l - 1)
    def _measured():
        lam = lam_ref[...].astype(acc_dtype)         # (BR,)
        # the slab holds temp·λ: it IS the measurement operand *and* the
        # born-collapsed environment (env' = temp[:, :, s]·λ), so no second
        # λ pass is needed in the epilogue
        sre = acc_re_ref[...] * lam[None, :, None]
        sim = acc_im_ref[...] * lam[None, :, None]
        sre_ref[:, pl.ds(j * br, br), :] = sre
        sim_ref[:, pl.ds(j * br, br), :] = sim
        contrib = jnp.sum(sre * sre + sim * sim, axis=1)   # (BN, d)

        @pl.when(j == 0)
        def _set():
            probs_ref[...] = contrib

        @pl.when(j > 0)
        def _add():
            probs_ref[...] += contrib

    @pl.when((j == n_r - 1) & (k == n_l - 1))
    def _epilogue():
        samples = _draw(probs_ref[...].astype(out_dtype), u_ref[...])
        ore = _collapse(sre_ref[...].astype(out_dtype), samples, d)
        oim = _collapse(sim_ref[...].astype(out_dtype), samples, d)
        factor, dlog = _rescale(ore, jnp.sqrt(ore * ore + oim * oim), scaling)
        ore_ref[...] = ore / factor
        oim_ref[...] = oim / factor
        samples_ref[...] = samples.astype(jnp.int32)
        dlog_ref[...] = dlog.astype(dlog_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "br", "bl", "scaling",
                                             "interpret"))
def site_step_born(env: Array, gamma: Array, lam: Array, u: Array,
                   bn: int = 256, br: int = 256, bl: int = 256,
                   scaling: str = "per_sample",
                   interpret: bool = False):
    """Fused Born site step on complex operands via split re/im planes.

    env (N, χl) complex, Γ (χl, χr, d) complex, λ (χr) real, u (N,) real →
    (env' (N, χr) complex, samples (N,) int32, dlog (N,) real).
    """
    n, chi_l = env.shape
    _, chi_r, d = gamma.shape
    if scaling not in ("per_sample", "none"):
        raise ValueError(f"fused site step cannot do scaling={scaling!r} "
                         "(the max crosses n-tiles); rescale outside")
    bn, br, bl = min(bn, n), min(br, chi_r), min(bl, chi_l)
    assert n % bn == 0 and chi_r % br == 0 and chi_l % bl == 0, \
        (n, chi_l, chi_r, bn, br, bl)
    grid = (n // bn, chi_r // br, chi_l // bl)
    rdt = jnp.zeros((), dtype=env.dtype).real.dtype
    out_dtype = jnp.float32 if rdt in (jnp.bfloat16, jnp.float16) else rdt
    acc_dtype = _acc_dtype_for(out_dtype)

    kern = functools.partial(_born_kernel, n_r=grid[1], n_l=grid[2], br=br,
                             d=d, scaling=scaling, out_dtype=out_dtype)
    plane_spec = pl.BlockSpec((bn, bl), lambda i, j, k: (i, k))
    gamma_spec = pl.BlockSpec((bl, br, d), lambda i, j, k: (k, j, 0))
    ore, oim, samples, dlog = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            plane_spec, plane_spec, gamma_spec, gamma_spec,
            pl.BlockSpec((br,), lambda i, j, k: (j,)),
            pl.BlockSpec((bn,), lambda i, j, k: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn, chi_r), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bn, chi_r), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bn,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, chi_r), out_dtype),
            jax.ShapeDtypeStruct((n, chi_r), out_dtype),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), out_dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bn, chi_r, d), acc_dtype),    # temp·λ slab, re plane
            pltpu.VMEM((bn, chi_r, d), acc_dtype),    # temp·λ slab, im plane
            pltpu.VMEM((bn, br, d), acc_dtype),
            pltpu.VMEM((bn, br, d), acc_dtype),
            pltpu.VMEM((bn, d), acc_dtype),
        ],
        interpret=interpret,
    )(jnp.real(env).astype(out_dtype), jnp.imag(env).astype(out_dtype),
      jnp.real(gamma).astype(out_dtype), jnp.imag(gamma).astype(out_dtype),
      lam.astype(out_dtype), u)
    return (ore + 1j * oim).astype(env.dtype), samples, dlog


# ---------------------------------------------------------------------------
# Measure-only variant (tp-3 split-K schedule): probs_partial = env @ W
# ---------------------------------------------------------------------------

def _measure_kernel(env_ref, w_ref, probs_ref, acc_ref, *, n_l: int,
                    out_dtype, compute_dtype):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    env = env_ref[...]                               # (BN, BL)
    w = w_ref[...]                                   # (BL, d)
    if compute_dtype is not None:
        env = env.astype(compute_dtype)
        w = w.astype(compute_dtype)
    acc_ref[...] += jax.lax.dot_general(
        env, w, (((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(k == n_l - 1)
    def _emit():
        probs_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bl", "compute_dtype",
                                             "interpret"))
def measure_probs(env: Array, w: Array, bn: int = 256, bl: int = 256,
                  compute_dtype=None, interpret: bool = False) -> Array:
    """env (N, L) · W (L, d) → partial probs (N, d) — the tp-3 measure-first
    GEMM for one bond shard (the caller psums over the TP group)."""
    n, L = env.shape
    d = w.shape[1]
    bn, bl = min(bn, n), min(bl, L)
    assert n % bn == 0 and L % bl == 0, (n, L, bn, bl)
    grid = (n // bn, L // bl)
    out_dtype = (jnp.float32 if env.dtype in (jnp.bfloat16, jnp.float16)
                 else env.dtype)
    acc_dtype = _acc_dtype_for(env.dtype)
    kern = functools.partial(_measure_kernel, n_l=grid[1],
                             out_dtype=out_dtype, compute_dtype=compute_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bl), lambda i, k: (i, k)),
            pl.BlockSpec((bl, d), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), out_dtype),
        scratch_shapes=[pltpu.VMEM((bn, d), acc_dtype)],
        interpret=interpret,
    )(env, w)
