"""Fused MPS site contraction + linear measurement — Pallas TPU kernel.

This is the hot spot of the whole framework: per site,
``temp[n,r,s] = Σ_l env[n,l]·Γ[l,r,s]`` (a (N×χ)·(χ×χd) GEMM, ~97 % of
FLOPs) immediately followed by the measurement probabilities
``probs[n,s] = Σ_r temp[n,r,s]·Λ[r]``.  Computing probs *inside* the GEMM's
output tiles means temp never makes a round trip to HBM before measurement —
the paper's "measure before communicate" insight applied to the memory
hierarchy (HBM↔VMEM instead of NIC).

TPU mapping (DESIGN.md §2):
  * grid = (n_tiles, r_tiles, l_tiles), l innermost (sequential reduction on
    TPU, accumulator lives in a VMEM scratch tile).
  * MXU tiles: BN×BL · BL×(BR·d) with fp32 accumulation
    (``preferred_element_type``); inputs may be bf16 (the paper's TF32 tier).
  * probs is accumulated across r-tiles into the same (BN, d) output block —
    legal because TPU grids execute sequentially and the probs BlockSpec
    ignores the r/l grid axes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(env_ref, gamma_ref, lam_ref, temp_ref, probs_ref, acc_ref,
            *, n_l: int, out_dtype, acc_dtype):
    j = pl.program_id(1)      # r tile
    k = pl.program_id(2)      # l tile (reduction)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    env = env_ref[...]                              # (BN, BL)
    gam = gamma_ref[...]                            # (BL, BR, d)
    bl, br, d = gam.shape
    acc_ref[...] += jax.lax.dot_general(
        env, gam.reshape(bl, br * d),
        (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    ).reshape(env.shape[0], br, d)

    @pl.when(k == n_l - 1)
    def _emit():
        temp = acc_ref[...]
        temp_ref[...] = temp.astype(out_dtype)
        # partial measurement over this r tile: (BN, BR, d) · (BR,) → (BN, d)
        contrib = jax.lax.dot_general(
            temp.swapaxes(1, 2).reshape(-1, br),        # (BN·d, BR)
            lam_ref[...].astype(acc_dtype),
            (((1,), (0,)), ((), ())),
            preferred_element_type=acc_dtype,
        ).reshape(temp.shape[0], d)

        @pl.when(j == 0)
        def _set():
            probs_ref[...] = contrib.astype(out_dtype)

        @pl.when(j > 0)
        def _add():
            probs_ref[...] += contrib.astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bn", "br", "bl", "interpret"))
def contract_measure(env: Array, gamma: Array, lam: Array,
                     bn: int = 256, br: int = 256, bl: int = 256,
                     interpret: bool = False):
    """env (N, χ), Γ (χ, χ, d), Λ (χ) → (temp (N, χ, d), probs (N, d)).

    Block sizes default to MXU-aligned 256 (multiples of 128); VMEM working
    set ≈ BN·BL + BL·BR·d + BN·BR·d fp32 words ≈ 1.3 MB at defaults, d=4.
    """
    n, chi = env.shape
    _, chir, d = gamma.shape
    bn = min(bn, n)
    br = min(br, chir)
    bl = min(bl, chi)
    assert n % bn == 0 and chir % br == 0 and chi % bl == 0, (n, chi, bn, br, bl)
    grid = (n // bn, chir // br, chi // bl)
    out_dtype = jnp.float32 if env.dtype in (jnp.bfloat16, jnp.float16) else env.dtype
    acc_dtype = jnp.float64 if env.dtype == jnp.float64 else jnp.float32

    kern = functools.partial(_kernel, n_l=grid[2], out_dtype=out_dtype,
                             acc_dtype=acc_dtype)
    temp, probs = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bl), lambda i, j, k: (i, k)),
            pl.BlockSpec((bl, br, d), lambda i, j, k: (k, j, 0)),
            pl.BlockSpec((br,), lambda i, j, k: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bn, br, d), lambda i, j, k: (i, j, 0)),
            pl.BlockSpec((bn, d), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, chir, d), out_dtype),
            jax.ShapeDtypeStruct((n, d), out_dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bn, br, d), acc_dtype)],
        interpret=interpret,
    )(env, gamma, lam)
    return temp, probs
