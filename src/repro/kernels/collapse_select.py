"""Sample-selected collapse GEMM — Pallas TPU kernel (§Perf iteration tp-3).

The measure-first reformulation: by associativity of Alg. 1's linear
measurement,

    probs[n, s] = Σ_r (Σ_l env[n,l] Γ[l,r,s]) Λ[r] = env @ W,
    W[l, s]     = Σ_r Γ[l,r,s] Λ[r]                       (tiny, per site)

so the (N, χ, d) unmeasured temp is never needed to *draw*.  After drawing
s_n, the new environment is

    env'[n, r] = Σ_l env[n, l] · Γ[l, r, s_n]

— a GEMM whose rhs differs per sample only through the physical index.
This kernel computes it with the per-sample select fused *inside* the MXU
loop: per (n, r, l) tile it keeps an (BN, BR) accumulator in VMEM and adds
``dot(env ⊙ [s_n = s], Γ[:, :, s])`` for each of the d outcomes.  The
masked operand lives only in VMEM/registers, so HBM traffic is env + Γ +
out — the (N, χ, d) temp round-trip of the naive path is gone entirely
(the memory term of the tp_single roofline drops ~20× at χ=10⁴; see
EXPERIMENTS.md §Perf).

FLOPs are unchanged (2NΧ²d — each outcome's dot still runs); the win is
pure memory traffic, which is what dominates the baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(env_ref, gamma_ref, samples_ref, out_ref, acc_ref,
            *, n_l: int, d: int, out_dtype):
    k = pl.program_id(2)      # l tile (sequential reduction)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    env = env_ref[...]                         # (BN, BL)
    gam = gamma_ref[...]                       # (BL, BR, d)
    s_n = samples_ref[...]                     # (BN,) int32
    acc_dtype = acc_ref.dtype

    for s in range(d):                         # d ≤ ~6: unrolled, VMEM-local
        mask = (s_n == s).astype(env.dtype)[:, None]
        acc_ref[...] += jax.lax.dot_general(
            env * mask, gam[:, :, s],
            (((1,), (0,)), ((), ())),
            preferred_element_type=acc_dtype,
        )

    @pl.when(k == n_l - 1)
    def _emit():
        out_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bn", "br", "bl", "interpret"))
def collapse_select(env: Array, gamma: Array, samples: Array,
                    bn: int = 256, br: int = 256, bl: int = 256,
                    interpret: bool = False) -> Array:
    """env (N, L), Γ (L, R, d), samples (N,) → env' (N, R).

    L is the (possibly sharded) left bond, R the right bond.  Block sizes
    MXU-aligned; VMEM working set ≈ BN·BL + BL·BR·d + BN·BR fp32 words.
    """
    n, L = env.shape
    _, R, d = gamma.shape
    bn, br, bl = min(bn, n), min(br, R), min(bl, L)
    assert n % bn == 0 and R % br == 0 and L % bl == 0, (n, L, R, bn, br, bl)
    grid = (n // bn, R // br, L // bl)
    out_dtype = (jnp.float32 if env.dtype in (jnp.bfloat16, jnp.float16)
                 else env.dtype)
    acc_dtype = jnp.float64 if env.dtype == jnp.float64 else jnp.float32

    kern = functools.partial(_kernel, n_l=grid[2], d=d, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bl), lambda i, j, k: (i, k)),
            pl.BlockSpec((bl, br, d), lambda i, j, k: (k, j, 0)),
            pl.BlockSpec((bn,), lambda i, j, k: (i,)),
        ],
        out_specs=pl.BlockSpec((bn, br), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, R), out_dtype),
        scratch_shapes=[pltpu.VMEM((bn, br), acc_dtype)],
        interpret=interpret,
    )(env, gamma, samples.astype(jnp.int32))


def measure_weights(gamma: Array, lam: Array) -> Array:
    """W[l, s] = Σ_r Γ[l,r,s]·Λ[r] — the per-site measure-first operator."""
    return jnp.einsum("lrs,r->ls", gamma, lam)
