"""Flash attention (forward) — Pallas TPU kernel (§Perf iteration attn-1).

The prefill/train attention cells are memory-bound because the naive path
materializes the (B, H, S, T) score matrix in HBM several times per layer
(qwen1.5-4b prefill_32k: 85 GB/layer/device, t_memory = 52 s vs t_compute
= 5.3 s).  This kernel runs the online-softmax recurrence with all
intermediates in VMEM: HBM traffic is Q + K + V + O only.

TPU mapping:
  * grid = (B·H, S/BQ, T/BK), key-block innermost (sequential on TPU, so
    the running max/denominator/accumulator live in VMEM scratch);
  * Q/O blocks are (BQ, Dh); K/V blocks (BK, Dh) — all MXU-aligned;
  * GQA: the KV block index is the query-head block index divided by the
    group size (no KV duplication in HBM);
  * causal masking by absolute indices; fully-masked key blocks skip their
    MXU work under ``pl.when`` (the paper's "only the region under the
    profile is computed" idea, applied to the causal triangle).

Backward is intentionally not provided: the serving path (prefill/decode)
is forward-only; training keeps the XLA path (see DESIGN.md §Perf notes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, n_k: int, bq: int, bk: int, scale: float, causal: bool):
    i = pl.program_id(1)          # query block
    j = pl.program_id(2)          # key block (sequential reduction)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * bq
    k_start = j * bk
    # skip key blocks entirely above the causal diagonal
    live = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(live)
    def _update():
        q = q_ref[0]                                  # (BQ, Dh)
        k = k_ref[0]                                  # (BK, Dh)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)               # (BQ,)
        p = jnp.exp(s - m_new[:, None])               # (BQ, BK)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
        acc_ref[...] = (alpha[:, None] * acc_ref[...]
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _emit():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...]
                    / jnp.where(l > 0, l, 1.0)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q: Array, k: Array, v: Array, causal: bool = True,
                    bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> Array:
    """q (B, S, H, Dh), k/v (B, T, KVH, Dh) → out (B, S, H, Dh).

    H must be a multiple of KVH (GQA group broadcast happens via the KV
    BlockSpec index map — KV is never duplicated in HBM).
    """
    b, s, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0
    g = h // kvh
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    grid = (b * h, s // bq, t // bk)
    scale = 1.0 / math.sqrt(dh)

    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kvh, t, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kvh, t, dh)

    kern = functools.partial(_kernel, n_k=grid[2], bq=bq, bk=bk,
                             scale=scale, causal=causal)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda bh, i, j, g=g: (bh // g, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda bh, i, j, g=g: (bh // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denominator
            pltpu.VMEM((bq, dh), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
