"""Kernel dispatch layer: one registry for every site-step stage.

The sampling data planes never call a Pallas kernel (or its XLA fallback)
directly — they ask this registry for the implementation of a *stage*:

=================  ==========================================================
stage              semantics of the op
=================  ==========================================================
``site_step``      the fully fused contract → measure → draw → collapse →
                   rescale pipeline (``kernels/site_step.py``); temp stays
                   VMEM-resident, only (N, χ) + two (N,) vectors hit HBM
``contract_measure``  contract + measure emitting (temp, probs) — the TP
                   schedules that must ship the unmeasured temp through a
                   collective use this (``kernels/contract_measure.py``)
``measure``        the tp-3 measure-first partial-probs GEMM env @ W
``collapse``       the sample-selected collapse GEMM env·Γ[:, :, sₙ]
                   (``kernels/collapse_select.py``)
=================  ==========================================================

Implementations register under ``(stage, semantics, backend)`` where
``backend`` is ``"pallas"`` or ``"xla"``.  Lookup order for
``backend="pallas"`` is ``(stage, semantics, "pallas")`` then the XLA entry
— a cell with no Pallas kernel (e.g. Born split-K TP, whose collective
forces the temp to HBM anyway) silently keeps its XLA implementation, so
``kernels="pallas"`` is always safe to request globally.

``SamplerConfig.kernels ∈ {"auto", "pallas", "xla"}`` is resolved by the
session planner through :func:`resolve_kernels`: AUTO means Pallas on a
real TPU backend and XLA elsewhere (tests force ``"pallas"`` explicitly
and the kernels run under ``interpret=True``).

The **autotuner** picks Pallas block sizes per shape: on TPU a timed sweep
over MXU-aligned candidates (cached per process), elsewhere a deterministic
heuristic table (largest divisors under a VMEM budget) — interpret-mode
numerics do not depend on the block choice, so CI exercises the same code
path the TPU runs.  ``autotune_cache_stats()`` reports cache behaviour
(surfaced by ``launch/sample.py --kernels``).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax

STAGES = ("site_step", "contract_measure", "measure", "collapse")
KERNEL_MODES = ("auto", "pallas", "xla")

_REGISTRY: dict[tuple[str, str, str], Callable] = {}


def register_site_op(stage: str, semantics: str, backend: str):
    """Decorator: register ``fn`` as the ``backend`` implementation of
    ``stage`` under ``semantics`` ("linear" | "born" | "*" for both)."""
    assert stage in STAGES, stage

    def deco(fn: Callable) -> Callable:
        sems = ("linear", "born") if semantics == "*" else (semantics,)
        for s in sems:
            _REGISTRY[(stage, s, backend)] = fn
        return fn
    return deco


def get_site_op(stage: str, semantics: str, backend: str) -> Callable:
    """The implementation for a stage; Pallas requests fall back to XLA
    when the cell has no kernel (see module docstring)."""
    if backend == "auto":
        backend = resolve_kernels("auto")
    if backend == "pallas":
        impl = _REGISTRY.get((stage, semantics, "pallas"))
        if impl is not None:
            return impl
        backend = "xla"
    try:
        return _REGISTRY[(stage, semantics, backend)]
    except KeyError:
        raise ValueError(
            f"no implementation for stage={stage!r} semantics={semantics!r} "
            f"backend={backend!r}; registered: {sorted(_REGISTRY)}") from None


def registered_ops() -> list[tuple[str, str, str]]:
    return sorted(_REGISTRY)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_kernels(requested: str) -> str:
    """``"auto" | "pallas" | "xla"`` → a concrete backend name."""
    if requested not in KERNEL_MODES:
        raise ValueError(f"kernels must be one of {KERNEL_MODES}, "
                         f"got {requested!r}")
    if requested == "auto":
        return "pallas" if on_tpu() else "xla"
    return requested


# ---------------------------------------------------------------------------
# Block-size autotuner
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """Pallas tile sizes for one (stage, shape) cell."""
    bn: int
    br: int
    bl: int


# heuristic VMEM budget: a v5e core has ~16 MB; leave headroom for the
# compiler's own double buffering of the streamed operand tiles
_VMEM_BUDGET_BYTES = 12 * 2 ** 20

_cache: dict[tuple, BlockConfig] = {}
_stats = {"hits": 0, "misses": 0, "swept": 0}


def autotune_cache_stats() -> dict:
    """Cache behaviour counters + current entries (per process)."""
    return {"entries": len(_cache), **_stats}


def clear_autotune_cache() -> None:
    _cache.clear()
    _stats.update(hits=0, misses=0, swept=0)


def _divisor_tile(size: int, pref: int) -> int:
    """Largest divisor of ``size`` that is ≤ ``pref`` — non-power-of-two and
    prime dimensions degrade gracefully (worst case: the whole dimension,
    which is always a legal Pallas block)."""
    for t in range(min(pref, size), 0, -1):
        if size % t == 0:
            return t
    return size


def _working_set_bytes(stage: str, cfg: BlockConfig, chi_r: int, d: int,
                       elt: int, planes: int) -> int:
    """VMEM model of a block choice (the site_step slab dominates)."""
    bn, br, bl = cfg.bn, cfg.br, cfg.bl
    if stage == "site_step":
        # env tile + Γ tile + split-K acc + resident temp slab + env' row
        per_plane = bn * bl + bl * br * d + bn * br * d + bn * chi_r * d
        return (planes * per_plane + bn * chi_r + bn * d) * elt
    if stage == "contract_measure":
        return (bn * bl + bl * br * d + 2 * bn * br * d + bn * d) * elt
    if stage == "collapse":
        return (bn * bl + bl * br * d + 2 * bn * br) * elt
    if stage == "measure":
        return (bn * bl + bl * d + 2 * bn * d) * elt
    raise ValueError(stage)


def _heuristic(stage: str, n: int, chi_l: int, chi_r: int, d: int,
               elt: int, planes: int) -> BlockConfig:
    """Deterministic block choice: MXU-preferred divisors, then shrink BN
    (the only axis the site_step slab scales with) until the VMEM model
    fits.  Correctness never depends on the choice — any divisors work."""
    cfg = BlockConfig(bn=_divisor_tile(n, 256), br=_divisor_tile(chi_r, 256),
                      bl=_divisor_tile(chi_l, 256))
    while (_working_set_bytes(stage, cfg, chi_r, d, elt, planes)
           > _VMEM_BUDGET_BYTES):
        if cfg.bn > 1:                       # the slab scales with BN first
            cfg = dataclasses.replace(cfg, bn=_divisor_tile(n, cfg.bn // 2))
        elif cfg.br > 1:
            cfg = dataclasses.replace(cfg, br=_divisor_tile(chi_r,
                                                            cfg.br // 2))
        elif cfg.bl > 1:
            cfg = dataclasses.replace(cfg, bl=_divisor_tile(chi_l,
                                                            cfg.bl // 2))
        else:                                # χ itself exceeds the model —
            break                            # compile anyway, VMEM will tell
    return cfg


def _sweep_candidates(stage: str, n: int, chi_l: int, chi_r: int, d: int,
                      elt: int, planes: int) -> list[BlockConfig]:
    """MXU-aligned candidate grid for the timed TPU sweep (budget-filtered)."""
    seen, out = set(), []
    for pn in (512, 256, 128, 64):
        for pr in (512, 256, 128):
            for plb in (512, 256, 128):
                cfg = BlockConfig(bn=_divisor_tile(n, pn),
                                  br=_divisor_tile(chi_r, pr),
                                  bl=_divisor_tile(chi_l, plb))
                if cfg in seen:
                    continue
                seen.add(cfg)
                if (_working_set_bytes(stage, cfg, chi_r, d, elt, planes)
                        <= _VMEM_BUDGET_BYTES):
                    out.append(cfg)
    return out or [_heuristic(stage, n, chi_l, chi_r, d, elt, planes)]


def _time_call(fn: Callable, *args, iters: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def autotune(stage: str, *, n: int, chi_l: int, chi_r: int, d: int,
             dtype, planes: int = 1,
             probe: Optional[Callable[[BlockConfig], Callable]] = None
             ) -> BlockConfig:
    """Block sizes for one (stage, shape, dtype) cell, cached per process.

    Off-TPU (and whenever no ``probe`` is supplied) the heuristic table
    answers immediately.  On TPU, ``probe(cfg)`` must return a zero-arg
    thunk running the kernel at ``cfg``; the fastest candidate wins and is
    cached, so a production sampler pays the sweep once per distinct
    (χ-bucket, N₂) shape.
    """
    elt = jax.numpy.dtype(dtype).itemsize
    key = (stage, n, chi_l, chi_r, d, str(jax.numpy.dtype(dtype)), planes,
           on_tpu())
    hit = _cache.get(key)
    if hit is not None:
        _stats["hits"] += 1
        return hit
    _stats["misses"] += 1
    if probe is not None and on_tpu():
        best_cfg, best_t = None, float("inf")
        for cfg in _sweep_candidates(stage, n, chi_l, chi_r, d, elt, planes):
            _stats["swept"] += 1
            try:
                t = _time_call(probe(cfg))
            except Exception:       # a candidate the compiler rejects
                continue
            if t < best_t:
                best_cfg, best_t = cfg, t
        cfg = best_cfg or _heuristic(stage, n, chi_l, chi_r, d, elt, planes)
    else:
        cfg = _heuristic(stage, n, chi_l, chi_r, d, elt, planes)
    _cache[key] = cfg
    return cfg


# ---------------------------------------------------------------------------
# Implementations (imported last so the registry decorators see the helpers)
# ---------------------------------------------------------------------------

from repro.kernels import site_impls  # noqa: E402,F401  (registers the ops)
