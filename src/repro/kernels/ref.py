"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def contract_measure_ref(env: Array, gamma: Array, lam: Array):
    """Fused site contraction + linear measurement (paper Fig. 1 + Alg. 1 l.1).

    env (N, χ) · Γ (χ, χ, d) → temp (N, χ, d);  probs (N, d) = temp · Λ.
    """
    temp = jnp.einsum("nl,lrs->nrs", env, gamma)
    probs = jnp.einsum("nrs,r->ns", temp, lam)
    return temp, probs


def collapse_rescale_ref(temp: Array, samples: Array):
    """Collapse the physical leg at the drawn outcome + per-sample rescale
    (§3.3): env'[n, r] = temp[n, r, s_n] / max_r |temp[n, r, s_n]|."""
    env = jnp.take_along_axis(temp, samples[:, None, None].astype(jnp.int32),
                              axis=2)[:, :, 0]
    m = jnp.max(jnp.abs(env), axis=1, keepdims=True)
    return env / jnp.where(m > 0, m, 1.0)


def displacement_zassenhaus_ref(mu_re: Array, mu_im: Array, d: int):
    """Batched D(μ) ≈ e^{−|μ|²/2} e^{μa†} e^{−μ*a} as split re/im planes.

    Inputs (B,) real pairs; outputs (B, d, d) re and im planes.  Matches
    core.displacement.displacement_zassenhaus on the complex assembly.
    """
    from repro.core.displacement import displacement_zassenhaus
    mu = mu_re.astype(jnp.float64) + 1j * mu_im.astype(jnp.float64)
    out = displacement_zassenhaus(mu.astype(jnp.complex128), d)
    return out.real.astype(mu_re.dtype), out.imag.astype(mu_re.dtype)


def collapse_select_ref(env, gamma, samples):
    """env (N,L), Γ (L,R,d), samples (N,) → env' (N,R) = env·Γ[:,:,s_n]."""
    temp = jnp.einsum("nl,lrs->nrs", env, gamma)
    return jnp.take_along_axis(
        temp, samples[:, None, None].astype(jnp.int32), axis=2)[:, :, 0]


def site_step_ref(env, gamma, lam, u, semantics="linear",
                  scaling="per_sample"):
    """Oracle for the fused site-step pipeline (kernels/site_step.py):
    contract → measure → normalise/cumsum/draw with the supplied uniforms
    u (N,) → collapse(+λ for born) → per-sample rescale.

    Returns (env' (N, χ), samples (N,) int, dlog (N,)).
    """
    temp = jnp.einsum("nl,lrs->nrs", env, gamma)
    if semantics == "linear":
        probs = jnp.einsum("nrs,r->ns", temp, lam)
    else:
        scaled = temp * lam[None, :, None]
        probs = jnp.sum(jnp.abs(scaled) ** 2, axis=1)
    probs = jnp.clip(probs, 0.0, None)
    total = jnp.sum(probs, axis=1, keepdims=True)
    safe = jnp.where(total > 0, probs / jnp.where(total > 0, total, 1.0),
                     jnp.ones_like(probs) / probs.shape[1])
    cdf = jnp.cumsum(safe, axis=1)
    samples = jnp.sum((u[:, None] > cdf).astype(jnp.int32), axis=1).clip(
        0, probs.shape[1] - 1)
    env_new = jnp.take_along_axis(
        temp, samples[:, None, None].astype(jnp.int32), axis=2)[:, :, 0]
    if semantics == "born":
        env_new = env_new * lam[None, :]
    rdt = jnp.zeros((), env_new.dtype).real.dtype
    if scaling == "per_sample":
        m = jnp.max(jnp.abs(env_new), axis=1, keepdims=True)
        factor = jnp.where(m > 0, m, 1.0).astype(rdt)
        return env_new / factor, samples, jnp.log10(factor[:, 0])
    return env_new, samples, jnp.zeros((env.shape[0],), rdt)


def measure_first_probs_ref(env, gamma, lam):
    """probs via the associativity trick: env @ (Γ·Λ) — must equal
    contract_measure_ref(...)[1]."""
    w = jnp.einsum("lrs,r->ls", gamma, lam)
    return env @ w


def flash_attention_ref(q, k, v, causal=True):
    """Naive softmax attention oracle for the flash kernel (GQA-aware)."""
    b, s, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((s, t), bool))
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return out.reshape(b, s, h, dh)
