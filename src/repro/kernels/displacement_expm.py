"""Batched triangular displacement operator — Pallas TPU kernel (§3.4.1).

Per sample n we need D(μₙ) = e^{−|μₙ|²/2}·exp(μₙ a†)·exp(−μₙ* a), a d×d
complex matrix with d ≤ 16.  The factors are closed-form triangular
(generated elementwise), so the whole batch is embarrassingly parallel.

TPU adaptation of the paper's CUDA layout trick: the paper transposes the
batch to the last (contiguous) position so warp lanes touch interleaved
memory.  On TPU the analogue is putting the **batch on the lane (last,
128-wide) dimension**: all tensors in the kernel are (d, d, BB) with BB a
multiple of 128, so the tiny (j, k) loops broadcast across sublanes and the
VPU vectorizes over samples.  Complex numbers are carried as split re/im
planes (the MXU/VPU have no complex type; DESIGN.md §2).

The (L·U) product is a fori-loop of d rank-1 updates — d ≤ 16 so this is
d² FMA passes over (d, BB) vectors, entirely in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

Array = jax.Array


def _coeff_table(d: int) -> np.ndarray:
    """√(j!/k!)/(j−k)! for j ≥ k else 0, and the μ-power matrix m = j−k."""
    j = np.arange(d)[:, None].astype(np.float64)
    k = np.arange(d)[None, :].astype(np.float64)
    m = j - k
    from scipy.special import gammaln
    logc = 0.5 * (gammaln(j + 1) - gammaln(k + 1)) - gammaln(np.where(m >= 0, m, 0) + 1)
    coeff = np.where(m >= 0, np.exp(logc), 0.0)
    return m, coeff, (m >= 0)


def _kernel(mure_ref, muim_ref, mpow_ref, coeff_ref, outre_ref, outim_ref,
            *, d: int):
    mre = mure_ref[...]                     # (BB,)
    mim = muim_ref[...]
    bb = mre.shape[0]
    m_pow = mpow_ref[...]
    coeff = coeff_ref[...]
    mask = m_pow >= 0

    # polar form for μ^m: r^m·(cos mθ, sin mθ); guard μ=0 (m=0 ⇒ 1).
    r2 = mre * mre + mim * mim
    r = jnp.sqrt(r2)
    theta = jnp.arctan2(mim, mre)
    logr = jnp.log(jnp.where(r > 0, r, 1.0))

    mp = jnp.where(mask, m_pow, 0.0)[:, :, None]   # (d, d, 1)
    co = coeff[:, :, None]
    mk = mask[:, :, None]
    rm = jnp.exp(mp * logr[None, None, :])  # (d, d, BB)
    rm = jnp.where((mp == 0) | (r[None, None, :] > 0), rm, 0.0)
    ang = mp * theta[None, None, :]
    # exp(μ a†): entries μ^{j−k}·coeff  (lower triangular)
    lre = jnp.where(mk, co * rm * jnp.cos(ang), 0.0)
    lim = jnp.where(mk, co * rm * jnp.sin(ang), 0.0)
    # exp(−μ* a) = transpose of exp((−μ*)·a†)-style factor: entries
    # (−μ*)^{k−j}·coeff[k,j] — build from the lower factor of (−μ*) and
    # transpose the matrix dims (batch stays on lanes).
    nre, nim = -mre, mim                    # −μ* = (−re, +im)
    nr = jnp.sqrt(nre * nre + nim * nim)
    ntheta = jnp.arctan2(nim, nre)
    nlogr = jnp.log(jnp.where(nr > 0, nr, 1.0))
    nrm = jnp.exp(mp * nlogr[None, None, :])
    nrm = jnp.where((mp == 0) | (nr[None, None, :] > 0), nrm, 0.0)
    nang = mp * ntheta[None, None, :]
    ure = jnp.where(mk, co * nrm * jnp.cos(nang), 0.0).swapaxes(0, 1)
    uim = jnp.where(mk, co * nrm * jnp.sin(nang), 0.0).swapaxes(0, 1)

    pref = jnp.exp(-0.5 * r2)               # (BB,)

    # out = pref · L @ U, batched over lanes: d rank-1 accumulation steps.
    def body(jj, acc):
        are, aim = acc
        lre_j = jax.lax.dynamic_slice_in_dim(lre, jj, 1, axis=1)  # (d, 1, BB)
        lim_j = jax.lax.dynamic_slice_in_dim(lim, jj, 1, axis=1)
        ure_j = jax.lax.dynamic_slice_in_dim(ure, jj, 1, axis=0)  # (1, d, BB)
        uim_j = jax.lax.dynamic_slice_in_dim(uim, jj, 1, axis=0)
        are = are + lre_j * ure_j - lim_j * uim_j
        aim = aim + lre_j * uim_j + lim_j * ure_j
        return are, aim

    zero = jnp.zeros((d, d, bb), dtype=mre.dtype)
    outre, outim = jax.lax.fori_loop(0, d, body, (zero, zero))
    outre_ref[...] = outre * pref[None, None, :]
    outim_ref[...] = outim * pref[None, None, :]


@functools.partial(jax.jit, static_argnames=("d", "bb", "interpret"))
def displacement_expm(mu_re: Array, mu_im: Array, d: int,
                      bb: int = 128, interpret: bool = False):
    """(B,) μ re/im → (B, d, d) re/im planes of D(μ).  B % bb == 0."""
    B = mu_re.shape[0]
    bb = min(bb, B)
    assert B % bb == 0
    m_pow, coeff, _ = _coeff_table(d)
    dt = mu_re.dtype
    kern = functools.partial(_kernel, d=d)

    outre, outim = pl.pallas_call(
        kern,
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb,), lambda i: (i,)),
                  pl.BlockSpec((bb,), lambda i: (i,)),
                  pl.BlockSpec((d, d), lambda i: (0, 0)),
                  pl.BlockSpec((d, d), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((d, d, bb), lambda i: (0, 0, i)),
                   pl.BlockSpec((d, d, bb), lambda i: (0, 0, i))],
        out_shape=[jax.ShapeDtypeStruct((d, d, B), dt),
                   jax.ShapeDtypeStruct((d, d, B), dt)],
        interpret=interpret,
    )(mu_re, mu_im, jnp.asarray(m_pow, dt), jnp.asarray(coeff, dt))
    # user-facing layout (B, d, d); the kernel-internal layout keeps batch on
    # lanes, this transpose is fused into the consumer by XLA.
    return outre.transpose(2, 0, 1), outim.transpose(2, 0, 1)
