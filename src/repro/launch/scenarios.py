"""Scenario-runner CLI: named end-to-end workloads with pass/fail scoring.

Usage:
  PYTHONPATH=src python -m repro.launch.scenarios --list
  PYTHONPATH=src python -m repro.launch.scenarios --scenario all
  PYTHONPATH=src python -m repro.launch.scenarios \
      --scenario conditional_marginals --json benchmarks/BENCH.json

Exit code is nonzero when any scenario fails its threshold — this is
what CI's ``workloads-smoke`` job gates on.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser(
        description="run registered workload scenarios (repro.workloads)")
    ap.add_argument("--scenario", default="all",
                    help="scenario name, or 'all' (default)")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--json", default=None,
                    help="BENCH trajectory file to append rows to "
                         "('' disables, the CI smoke default)")
    ap.add_argument("--samples", type=int, default=0,
                    help="override the per-scenario sample budget")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="inmem",
                    choices=["inmem", "streamed"])
    ap.add_argument("--scheme", default="seq", choices=["seq", "dp"])
    args = ap.parse_args()

    import jax
    # scenarios score against float64 oracles — same reference precision
    # the test suite and benches run at
    jax.config.update("jax_enable_x64", True)

    from repro.workloads import scenarios as SC

    catalogue = SC.available_scenarios()
    if args.list:
        for name, summary in catalogue.items():
            print(f"{name:26s} {summary}")
        return 0

    names = sorted(catalogue) if args.scenario == "all" else [args.scenario]
    for n in names:
        if n not in catalogue:
            print(f"unknown scenario {n!r}; --list shows the registry",
                  file=sys.stderr)
            return 2

    cfg_kwargs = dict(seed=args.seed, backend=args.backend,
                      scheme=args.scheme, json_path=args.json)
    if args.samples:
        cfg_kwargs["n_samples"] = args.samples

    failures = 0
    for name in names:
        result = SC.run_scenario(name, SC.ScenarioConfig(**cfg_kwargs))
        status = "PASS" if result.passed else "FAIL"
        print(f"[{status}] {name}: score={result.score:.6g} "
              f"(threshold {result.threshold:g}) wall={result.wall_s:.2f}s")
        print("        " + json.dumps(result.metrics, default=str))
        failures += 0 if result.passed else 1
    if failures:
        print(f"{failures}/{len(names)} scenarios failed", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
