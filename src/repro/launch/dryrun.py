"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) cell, lower + compile the
appropriate step function against ShapeDtypeStruct inputs (no allocation),
print ``memory_analysis()`` / ``cost_analysis()``, and derive the roofline
terms from the loop-corrected HLO analysis (launch/hloanalysis.py).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell, cached
  PYTHONPATH=src python -m repro.launch.dryrun --gbs            # the paper's own sampler

Results are cached as JSON under experiments/dryrun/; --force recompiles.
"""
# The dry-run needs 512 placeholder devices so jax.make_mesh can build the
# production meshes.  MUST be set before any jax import/init.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import hloanalysis as H
from repro.launch import steps
from repro.launch.mesh import data_axis_names, make_production_mesh
from repro.models import transformer as T
from repro.optim import optimizers

OUT_ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "experiments", "dryrun")


def _sds_with(sharding, sds):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds, sharding,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def model_flops_of(cfg: T.ModelConfig, shape: configs.ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd-only), N = active params."""
    _, active = cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               remat_block: int = 0):
    """Build + lower + compile one cell.  Returns (compiled, meta dict)."""
    cfg = configs.get_config(arch)
    if remat_block:
        cfg = dataclasses.replace(cfg, remat_block=remat_block)
    shape = configs.SHAPES[shape_name]
    ok, why = configs.cell_supported(cfg, shape)
    if not ok:
        return None, {"skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 1
    for ax in mesh.axis_names:
        n_chips *= mesh.shape[ax]

    # pin per-layer activation batch sharding (models/common.py §moe-2)
    from repro.models.common import set_mesh_axes
    set_mesh_axes(data_axis_names(mesh))

    opt = optimizers.optimizer_for(cfg)
    fsdp = cfg.param_count()[0] * 2 > 8e9          # >8 GB of bf16 weights
    params_sds, specs, extra_sds = steps.abstract_state(
        cfg, opt, "train" if shape.kind == "train" else
        ("decode" if shape.kind == "decode" else "prefill"),
        shape.global_batch, shape.seq_len)
    param_sh = steps.param_shardings(mesh, params_sds, specs, fsdp=fsdp)
    batch_sds = configs.input_specs(cfg, shape)
    batch_sh = steps.batch_shardings(mesh, batch_sds)

    with mesh:
        if shape.kind == "train":
            opt_sh = steps.opt_state_shardings(mesh, extra_sds, param_sh)
            step = steps.make_train_step(cfg, opt)
            fn = jax.jit(step, in_shardings=(param_sh, opt_sh, batch_sh),
                         donate_argnums=(0, 1))
            args = (_sds_with(param_sh, params_sds),
                    _sds_with(opt_sh, extra_sds),
                    _sds_with(batch_sh, batch_sds))
        elif shape.kind == "prefill":
            step = steps.make_prefill_step(cfg)
            fn = jax.jit(step, in_shardings=(param_sh, batch_sh))
            args = (_sds_with(param_sh, params_sds),
                    _sds_with(batch_sh, batch_sds))
        else:
            cache_sh = steps.cache_shardings(mesh, cfg, extra_sds.caches)
            state_sh = T.DecodeState(cache_sh, NamedSharding(mesh, P()))
            step = steps.make_serve_step(cfg)
            fn = jax.jit(step, in_shardings=(param_sh, batch_sh, state_sh),
                         donate_argnums=(2,))
            args = (_sds_with(param_sh, params_sds),
                    _sds_with(batch_sh, batch_sds),
                    T.DecodeState(_sds_with(state_sh.caches, extra_sds.caches),
                                  jax.ShapeDtypeStruct(
                                      (), jnp.int32,
                                      sharding=NamedSharding(mesh, P()))))
        t0 = time.time()
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        t1 = time.time()

    return compiled, {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "compile_s": round(t1 - t0, 1),
        "model_flops": model_flops_of(cfg, shape),
    }


def analyze_cell(compiled, meta: dict) -> dict:
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    cost = H.analyze(compiled.as_text())
    rf = H.roofline(cost, meta["n_chips"], meta["model_flops"])
    out = dict(meta)
    out.update({
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "outputs": mem.output_size_in_bytes,
            "temps": mem.temp_size_in_bytes,
            "aliased": mem.alias_size_in_bytes,
            "peak_estimate": (mem.argument_size_in_bytes
                              + mem.output_size_in_bytes
                              + mem.temp_size_in_bytes
                              - mem.alias_size_in_bytes),
        },
        "xla_cost_analysis": {"flops_once": ca.get("flops", 0.0),
                              "bytes_once": ca.get("bytes accessed", 0.0)},
        "hlo": {
            "flops_per_device": cost.flops,
            "memory_bytes_per_device": cost.memory_bytes,
            "collective_wire_bytes_per_device": cost.collective_wire_bytes,
            "per_collective": cost.per_collective,
            "n_collectives": cost.n_collectives,
            "upcast_bytes_per_device": cost.upcast_bytes,
        },
        "roofline": rf.table_row(),
    })
    # TPU-adjusted memory term: the MXU consumes bf16 operands natively, so
    # whole-array convert traffic (a CPU-backend lowering artifact) is
    # removed (see hloanalysis.HLOCost.upcast_bytes).
    out["roofline"]["t_memory_tpu_adj_s"] = (
        (cost.memory_bytes - cost.upcast_bytes) / H.HBM_BW)
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, force: bool = False,
             out_root: str = OUT_ROOT, remat_block: int = 0) -> dict:
    multi = mesh_kind == "multi"
    os.makedirs(out_root, exist_ok=True)
    rb = f"__rb{remat_block}" if remat_block else ""
    path = os.path.join(
        out_root,
        f"{arch}__{shape_name}{rb}__{'multi' if multi else 'single'}.json")
    if not force and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    try:
        compiled, meta = lower_cell(arch, shape_name, multi,
                                    remat_block=remat_block)
        if compiled is None:
            result = {"arch": arch, "shape": shape_name,
                      "mesh": "2x16x16" if multi else "16x16", **meta}
        else:
            result = analyze_cell(compiled, meta)
            del compiled
    except Exception as e:                                    # noqa: BLE001
        result = {"arch": arch, "shape": shape_name,
                  "mesh": "2x16x16" if multi else "16x16",
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:]}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(result, f, indent=1)
    os.replace(tmp, path)
    return result


# ---------------------------------------------------------------------------
# GBS sampler dry-run (the paper's own workload on the production mesh)
# ---------------------------------------------------------------------------

def run_gbs_cell(preset_name: str, scheme: str, mesh_kind: str,
                 force: bool = False, out_root: str = OUT_ROOT,
                 micro_batch: int = 4096, optimized: bool = False) -> dict:
    from repro.configs import gbs
    from repro.core import parallel as PP
    from repro.core.mps import MPS
    from repro.core.sampler import SamplerConfig

    multi = mesh_kind == "multi"
    os.makedirs(out_root, exist_ok=True)
    suffix = "_opt" if optimized else ""
    path = os.path.join(
        out_root,
        f"gbs-{preset_name}__{scheme}{suffix}__"
        f"{'multi' if multi else 'single'}.json")
    if not force and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    p = gbs.PRESETS[preset_name]
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = 1
    for ax in mesh.axis_names:
        n_chips *= mesh.shape[ax]
    # pure DP has no χ split — every mesh axis is a data axis (otherwise the
    # model axis would replicate identical work, a 16× useful-FLOPs waste
    # measured in §Perf iteration dp-1)
    daxes = (tuple(mesh.axis_names) if scheme == "dp"
             else data_axis_names(mesh))
    p1 = 1
    for ax in daxes:
        p1 *= mesh.shape[ax]
    n_samples = micro_batch * p1

    # optimized (§Perf iterations tp-1/tp-2): Γ resident in HBM as bf16
    # (halves weight traffic; upcast in VMEM at the contraction) and bf16
    # collective wire (per-sample scaling bounds the range; bf16 keeps
    # fp32's exponent so the cast cannot under/overflow)
    gdt = jnp.bfloat16 if optimized else jnp.float32
    mps_sds = MPS(
        jax.ShapeDtypeStruct((p.n_sites, p.chi, p.chi, p.d), gdt),
        jax.ShapeDtypeStruct((p.n_sites, p.chi), jnp.float32), "linear")
    key_sds = jax.ShapeDtypeStruct((), jnp.uint32)

    scfg = SamplerConfig(compute_dtype=jnp.bfloat16)
    pcfg = PP.ParallelConfig(
        scheme=scheme, data_axes=daxes,
        wire_dtype=jnp.bfloat16 if optimized else None,
        measure_first=optimized)

    def run(gammas, lambdas, seed):
        m = MPS(gammas, lambdas, "linear")
        return PP._multilevel_sample(mesh, m, n_samples,
                                     jax.random.key(seed), pcfg, scfg)

    try:
        with mesh:
            t0 = time.time()
            lowered = jax.jit(run).lower(mps_sds.gammas, mps_sds.lambdas,
                                         key_sds)
            compiled = lowered.compile()
            t1 = time.time()
        # MODEL_FLOPS: the chain GEMMs = 2·N·M·χ²·d (+measure, lower order)
        mf = 2.0 * n_samples * p.n_sites * p.chi * p.chi * p.d
        meta = {"arch": f"gbs-{preset_name}", "shape": f"{scheme}",
                "mesh": "2x16x16" if multi else "16x16",
                "n_chips": n_chips, "compile_s": round(t1 - t0, 1),
                "model_flops": mf, "n_samples": n_samples,
                "chi": p.chi, "n_sites": p.n_sites, "d": p.d}
        result = analyze_cell(compiled, meta)
    except Exception as e:                                    # noqa: BLE001
        result = {"arch": f"gbs-{preset_name}", "shape": scheme,
                  "mesh": "2x16x16" if multi else "16x16",
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:]}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(result, f, indent=1)
    os.replace(tmp, path)
    return result


def summarize(result: dict) -> str:
    if "skipped" in result:
        return (f"{result['arch']:22s} {result['shape']:12s} "
                f"{result['mesh']:8s} SKIP ({result['skipped'][:48]})")
    if "error" in result:
        return (f"{result['arch']:22s} {result['shape']:12s} "
                f"{result['mesh']:8s} FAIL {result['error'][:80]}")
    rf = result["roofline"]
    mem = result["bytes_per_device"]["peak_estimate"] / 1e9
    return (f"{result['arch']:22s} {result['shape']:12s} {result['mesh']:8s} "
            f"ok  mem/dev={mem:6.1f}GB  "
            f"tc={rf['t_compute_s']:.3e} tm={rf['t_memory_s']:.3e} "
            f"tx={rf['t_collective_s']:.3e} [{rf['bottleneck'][:4]}] "
            f"useful={rf['useful_ratio']:.2f} "
            f"compile={result['compile_s']:.0f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--gbs", action="store_true")
    ap.add_argument("--gbs-opt", action="store_true",
                    help="optimized GBS variants (§Perf iterations)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--remat-block", type=int, default=0,
                    help="sqrt-L block remat size (§Perf mem-1)")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.gbs or args.gbs_opt:
        for preset in ("b-m288", "m8176"):
            for scheme in ("dp", "tp_single", "tp_double"):
                cells.append(("gbs", preset, scheme))
    if args.all or args.arch:
        archs = [args.arch] if args.arch else configs.ARCHS
        shapes = [args.shape] if args.shape else list(configs.SHAPES)
        for a in archs:
            for s in shapes:
                cells.append(("lm", a, s))

    for kind, a, s in cells:
        for mk in meshes:
            if kind == "gbs":
                r = run_gbs_cell(a, s, mk, force=args.force,
                                 optimized=args.gbs_opt)
            else:
                r = run_cell(a, s, mk, force=args.force,
                             remat_block=args.remat_block)
            print(summarize(r), flush=True)


if __name__ == "__main__":
    main()
