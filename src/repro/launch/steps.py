"""train_step / serve_step builders + the sharding policy.

Sharding policy (per leaf):
  1. Resolve the model's logical specs (DATA placeholders → the mesh's
     data-like axes).
  2. Divisibility guard: any dim not divisible by its assigned axis size
     degrades to replicated on that dim (e.g. qwen's 20 heads on a 16-way
     model axis) — recorded so the roofline can show the waste.
  3. FSDP: for models above ``fsdp_threshold`` params, leaves not already
     data-sharded get their largest divisible dim sharded over the data
     axes (storage sharding; XLA all-gathers at use).

Decode caches: batch over data; heads over model when divisible, else the
cache *length* over model (long contexts are sequence-sharded).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axis_names
from repro.models.common import resolve_specs, softmax_xent
from repro.models import transformer as T

Array = jax.Array


# ---------------------------------------------------------------------------
# Sharding policy
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        out = 1
        for a in ax:
            out *= mesh.shape[a]
        return out
    return mesh.shape[ax]


def guard_divisibility(spec: P, shape, mesh: Mesh) -> P:
    out = []
    for i, ax in enumerate(spec):
        if ax is not None and (i >= len(shape) or shape[i] % _axis_size(mesh, ax)):
            out.append(None)
        else:
            out.append(ax)
    while len(out) < len(shape):
        out.append(None)
    return P(*out)


def apply_fsdp(spec: P, shape, mesh: Mesh, data_axes) -> P:
    """Shard the largest unsharded divisible dim over the data axes."""
    if any(ax is not None and (ax in data_axes or
           (isinstance(ax, (tuple, list)) and set(ax) & set(data_axes)))
           for ax in spec):
        return spec                       # already data-sharded
    dsize = 1
    for a in data_axes:
        dsize *= mesh.shape[a]
    cands = [i for i, ax in enumerate(spec)
             if ax is None and shape[i] % dsize == 0]
    if not cands:
        return spec
    best = max(cands, key=lambda i: shape[i])
    out = list(spec)
    out[best] = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    return P(*out)


def param_shardings(mesh: Mesh, params_sds, specs, fsdp: bool) -> Any:
    data_axes = data_axis_names(mesh)
    specs = resolve_specs(specs, data_axes)

    def leaf(sds, spec):
        spec = guard_divisibility(spec, sds.shape, mesh)
        if fsdp:
            spec = apply_fsdp(spec, sds.shape, mesh, data_axes)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(
        leaf, params_sds, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def batch_shardings(mesh: Mesh, batch_sds) -> Any:
    data_axes = data_axis_names(mesh)
    dspec = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]

    def leaf(sds):
        spec = [None] * len(sds.shape)
        if sds.shape and sds.shape[0] % _axis_size(mesh, dspec) == 0:
            spec[0] = dspec
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(leaf, batch_sds)


def opt_state_shardings(mesh: Mesh, opt_sds, params_shardings) -> Any:
    """Optimizer-state shardings mirror the parameter layout.

    adamw m/v are param-shaped → reuse the param sharding.  adafactor vr/vc
    drop the last / second-to-last dim → slice the param spec accordingly.
    """
    def leaf(sds_dict, psh):
        out = {}
        for k, v in sds_dict.items():
            if k in ("m", "v"):
                out[k] = psh
            elif k in ("vr", "vc"):
                # factored vectors have param_rank − 1 dims
                spec = list(psh.spec) + [None] * (len(v.shape) + 1 - len(psh.spec))
                s = spec[:-1] if k == "vr" else spec[:-2] + spec[-1:]
                out[k] = NamedSharding(mesh, guard_divisibility(P(*s), v.shape, mesh))
            else:
                out[k] = NamedSharding(mesh, P())
        return out

    def is_state_leaf(x):
        return (isinstance(x, dict)
                and all(isinstance(v, jax.ShapeDtypeStruct) for v in x.values()))

    inner = jax.tree_util.tree_map(
        leaf, opt_sds.inner, params_shardings, is_leaf=is_state_leaf)
    from repro.optim.optimizers import OptState
    return OptState(NamedSharding(mesh, P()), inner)


def cache_shardings(mesh: Mesh, cfg: T.ModelConfig, state_sds) -> Any:
    """DecodeState shardings: stacked caches (L, B, T, H?, D?)."""
    data_axes = data_axis_names(mesh)
    dspec = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    msize = mesh.shape["model"]
    dsize = _axis_size(mesh, dspec)

    def leaf(sds):
        shape = sds.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        spec: list = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % dsize == 0:
            spec[1] = dspec                       # batch
        if len(shape) == 5:                       # (L, B, T, kvh, dh) KV cache
            if shape[3] % msize == 0:
                spec[3] = "model"                 # kv heads
            elif shape[2] % msize == 0:
                spec[2] = "model"                 # cache length (long ctx)
        elif len(shape) == 4:                     # (L, B, T, latent) MLA
            if shape[2] % msize == 0:
                spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(leaf, state_sds)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: T.ModelConfig, optimizer, aux_weight: float = 0.01,
                    grad_compression_axis: Optional[str] = None):
    """(params, opt_state, batch) → (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        extra = {k: batch[k] for k in ("frames", "patches") if k in batch}

        def loss_fn(p):
            logits, aux = T.forward(p, batch["tokens"], cfg, extra)
            loss = softmax_xent(logits, batch["labels"])
            return loss + aux_weight * aux, (loss, aux)

        grads, (loss, aux) = jax.grad(loss_fn, has_aux=True)(params)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(grads)))
        return new_params, new_opt, {"loss": loss, "aux": aux, "gnorm": gnorm}

    return train_step


def make_prefill_step(cfg: T.ModelConfig):
    def prefill(params, batch):
        extra = {k: batch[k] for k in ("frames", "patches") if k in batch}
        logits, _ = T.forward(params, batch["tokens"], cfg, extra)
        return logits[:, -1, :]
    return prefill


def make_serve_step(cfg: T.ModelConfig):
    """One decode token: (params, batch, state) → (next_tokens, state)."""
    def serve_step(params, batch, state: T.DecodeState):
        extra = {k: batch[k] for k in ("enc_out", "patches") if k in batch}
        logits, new_state = T.decode_step(params, batch["tokens"], state, cfg,
                                          extra)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return nxt, new_state
    return serve_step


# ---------------------------------------------------------------------------
# Abstract state builders (dry-run: no allocation anywhere)
# ---------------------------------------------------------------------------

def abstract_params(cfg: T.ModelConfig):
    """(params SDS tree, specs) — specs are static, captured during tracing."""
    holder = {}

    def grab(k):
        p, s = T.init_params(k, cfg)
        holder["specs"] = s          # side effect during trace: specs are
        return p                     # plain PartitionSpec objects, no arrays

    params_sds = jax.eval_shape(grab, jax.random.key(0))
    return params_sds, holder["specs"]


def abstract_state(cfg: T.ModelConfig, optimizer, shape_kind: str,
                   batch: int, seq: int):
    """ShapeDtypeStructs for params (+opt state / decode state)."""
    params_sds, specs = abstract_params(cfg)
    if shape_kind == "train":
        opt_sds = jax.eval_shape(optimizer.init, params_sds)
        return params_sds, specs, opt_sds
    if shape_kind == "decode":
        state_sds = jax.eval_shape(
            lambda: T.init_decode_state(cfg, batch, seq))
        return params_sds, specs, state_sds
    return params_sds, specs, None
