"""GBS sampling driver: the paper's workload end-to-end, fault-tolerant.

A thin shell over :class:`repro.api.SamplingSession`: argument parsing →
config construction → session calls.  The session composes every level —
DP×TP placement, micro batching, dynamic bond dimensions, segment
streaming, per-segment checkpoints — and the macro-batch
:class:`WorkQueue` (runtime/elastic.py) makes the run restart-exact: kill
it at any point and rerun, it resumes from the queue state (and, when
streaming, from the last mid-chain segment boundary) and produces
bit-identical samples (paper §4.1).

Usage:
  PYTHONPATH=src python -m repro.launch.sample --sites 64 --chi 64 \
      --samples 4096 --macro-batches 4 --scheme dp --out /tmp/gbs

Streaming mode (chains too big for device memory, paper §3.1/§3.3.2):
  PYTHONPATH=src python -m repro.launch.sample --sites 512 --chi 64 \
      --samples 4096 --stream --store /tmp/gbs_gamma --segment-len 64

Dynamic bond dimensions (§3.4.2) now compose with every mode:
  PYTHONPATH=src python -m repro.launch.sample --sites 512 --chi 64 \
      --samples 4096 --stream --dynamic-bond

Service mode (async job API, `repro.api.service`): the whole run is one
multi-batch job over elastic worker lanes — blocks persist and progress
prints as batches complete, with the same batch files (same seed schedule)
the synchronous path writes:
  PYTHONPATH=src python -m repro.launch.sample --sites 64 --chi 64 \
      --samples 4096 --macro-batches 8 --service --service-workers 2
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import dynamic_bond as DB
from repro.core import mps as M
from repro.data.gamma_store import MANIFEST_NAME, GammaStore
from repro.kernels import dispatch
from repro.runtime.elastic import WorkQueue
from repro.runtime.faults import DeadLetter, FaultError


def main() -> None:
    try:
        _main()
    except FaultError as e:
        # the structured failure path: a verified-I/O / transport fault
        # (quarantined Γ site, dead-lettered poison batch, …) exits with a
        # machine-readable fault record instead of a stack trace — the
        # operator sees WHAT rotted and WHERE, and exit code 2
        # distinguishes "your data is bad" from "the driver crashed"
        record = {"fault": e.fault.to_dict(), "error": str(e)}
        if isinstance(e, DeadLetter):
            record["report"] = e.report.to_dict()
        print(json.dumps(record, indent=1), file=sys.stderr)
        raise SystemExit(2)


def _main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sites", type=int, default=64)
    ap.add_argument("--chi", type=int, default=64)
    ap.add_argument("--d", type=int, default=3)
    ap.add_argument("--samples", type=int, default=4096)
    ap.add_argument("--macro-batches", type=int, default=4)
    ap.add_argument("--scheme", default="dp",
                    choices=["auto", "seq", "dp", "tp_single", "tp_double",
                             "baseline19"])
    ap.add_argument("--runtime", default="auto",
                    choices=["auto", "local", "multihost", "remote"],
                    help="cluster runtime: where processes live and how Γ "
                         "bytes move (auto = local on one process)")
    ap.add_argument("--kernels", default="auto",
                    choices=["auto", "pallas", "xla"],
                    help="site-step kernel dispatch: fused Pallas pipeline, "
                         "XLA reference, or auto (pallas on TPU)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--clamp", default=None,
                    help="conditional sampling: 'site=outcome,...' forces "
                         "those sites and reports the per-sample conditional "
                         "log-probability (repro.workloads)")
    ap.add_argument("--dynamic-bond", action="store_true")
    ap.add_argument("--micro-batch", type=int, default=0,
                    help="N₂ per data shard (0 = whole batch)")
    ap.add_argument("--precision", default="fp64",
                    choices=["fp64", "fp32", "mxu_bf16"])
    ap.add_argument("--out", default="/tmp/fastmps_out")
    ap.add_argument("--service", action="store_true",
                    help="run through the async SamplingService: the whole "
                         "run is ONE multi-batch job, blocks stream back "
                         "with progress as they complete")
    ap.add_argument("--service-workers", type=int, default=1,
                    help="service submit lanes (elastic worker threads)")
    ap.add_argument("--service-fleet", action="store_true",
                    help="back every service lane with a persistent worker "
                         "PROCESS (framed-pipe RPC, repro.runtime.transport)"
                         " instead of an in-process thread")
    ap.add_argument("--stream", action="store_true",
                    help="segment-streamed engine (Γ from --store, §3.1)")
    ap.add_argument("--store", default=None,
                    help="GammaStore dir; built from the synthetic MPS if empty")
    ap.add_argument("--segment-len", type=int, default=0,
                    help="sites per streamed segment (0 = perfmodel planner)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    # the runtime decides where devices live; the mesh is derived from it
    # (a remote runtime dispatches the whole request — no local mesh)
    runtime = api.resolve_runtime(args.runtime)
    mesh = (None if runtime.name == "remote" or args.service_fleet
            else runtime.mesh(args.model_parallel))
    print(f"runtime: {runtime.name} "
          f"(process {runtime.process_index}/{runtime.process_count})  "
          f"mesh: {dict(mesh.shape) if mesh else None}  "
          f"scheme: {args.scheme}")

    dtype = jnp.float64 if args.precision == "fp64" else jnp.float32
    compute = jnp.bfloat16 if args.precision == "mxu_bf16" else None

    def build_mps():
        return M.gbs_like_mps(jax.random.key(args.seed), args.sites,
                              args.chi, args.d,
                              dtype=jnp.float64).astype(dtype)

    # -- source: an in-memory MPS, or a Γ store the chain streams from ------
    # (streaming never materializes the full chain — that is its point)
    if args.stream:
        root = args.store or os.path.join(args.out, "gamma_store")
        store_dtype = jnp.float64 if args.precision == "fp64" else jnp.float32
        source = GammaStore(root, compute_dtype=store_dtype)
        if source.n_sites == 0:
            print(f"writing Γ store ({args.sites} sites) to {root}")
            source.write_mps(build_mps())
            source.write_digest_manifest()
        # verified Γ I/O (runtime/faults.py): with a digest manifest on
        # disk every site read is sha256-checked — a rotted file is
        # quarantined and the run exits 2 with a fault record instead of
        # emitting samples from bad bytes.  A zip-level CRC only covers
        # member payloads; the manifest covers the whole file.
        source.verify = os.path.exists(os.path.join(root, MANIFEST_NAME))
    else:
        source = build_mps()

    # -- config: every knob is a field; AUTO fields go to the planner -------
    chi_profile = None
    if args.dynamic_bond:
        prof = DB.area_law_profile(args.sites, args.chi, n_photon=1.0)
        buck = DB.bucketize(prof, sorted({max(args.model_parallel,
                                              args.chi // 4),
                                          args.chi // 2, args.chi}))
        chi_profile = tuple(int(c) for c in buck)
        print("table1:", DB.table1_metrics(prof, args.chi))

    clamp = None
    if args.clamp:
        from repro.workloads.clamp import parse_clamp_arg
        clamp = parse_clamp_arg(args.clamp)
        print(f"clamp: {clamp} (clamped walks skip chain checkpoints — "
              f"macro-batch idempotence is the restart story)")

    scheme = args.scheme
    if runtime.name == "remote" and scheme not in ("auto", "seq"):
        print(f"runtime=remote resolves placement on the worker — "
              f"overriding scheme {scheme!r} to auto")
        scheme = "auto"
    if args.service_fleet and scheme not in ("auto", "seq"):
        print(f"--service-fleet dispatches serialized job batches; workers "
              f"resolve their own placement — overriding scheme "
              f"{scheme!r} to auto")
        scheme = "auto"
    config = api.SamplerConfig(
        scheme=scheme,
        kernels=args.kernels,
        runtime=runtime,
        backend=("auto" if runtime.name == "remote"
                 else ("streamed" if args.stream else "inmem")),
        compute_dtype=compute,
        micro_batch=args.micro_batch or None,
        chi_profile=chi_profile,
        segment_len=args.segment_len or api.AUTO,
        checkpoint_every=1,
        clamp=clamp,
    )

    n1 = args.macro_batches
    assert args.samples % n1 == 0
    per_batch = args.samples // n1

    # resume: macro batches already on disk are done (idempotent by id)
    done = [b for b in range(n1)
            if os.path.exists(os.path.join(args.out, f"batch_{b:05d}.npy"))]
    queue = WorkQueue(n1, seed=args.seed)
    for b in done:
        queue.complete(b)
    print(f"pending macro batches: {queue.pending}")

    base = jax.random.key(args.seed + 1)
    t0 = time.perf_counter()
    with api.SamplingSession(source, config, mesh=mesh) as session:
        plan = session.plan(per_batch)
        print("plan:", plan)
        print("why:", session.explain(per_batch))
        print(f"kernel dispatch: requested={args.kernels!r} → resolved "
              f"{plan.kernels!r} (backend={jax.default_backend()}; "
              f"registered ops: {len(dispatch.registered_ops())})")

        lp_blocks: dict[int, np.ndarray] = {}

        def save_batch(b: int, out: np.ndarray) -> None:
            np.save(os.path.join(args.out, f"batch_{b:05d}.npy"),
                    np.asarray(out).astype(np.int8))
            if clamp is not None:
                lp = session.stats.get("log_prob")
                if lp is not None and len(lp) == out.shape[0]:
                    lp_blocks[b] = np.asarray(lp, dtype=np.float64)
            print(f"macro batch {b} done ({per_batch} samples)", flush=True)

        if args.service:
            # the async front door: ONE job, its macro batches fed through
            # the elastic WorkQueue across --service-workers lanes, blocks
            # streamed back (and persisted) as they complete.  The batch
            # files must be interchangeable with the synchronous mode's, so
            # the key schedule must match run_queue's fold_in(base, b) for
            # EVERY n1 — a 1-batch job passes its key through unfolded
            # (service.batch_key), so fold batch 0's key here.
            job_key = jax.random.fold_in(base, 0) if n1 == 1 else base
            # fleet lanes have no local chain walk — per-batch idempotence
            # (skip_batches from the files on disk) is the restart story
            ck_root = (None if args.service_fleet or clamp is not None
                       else os.path.join(args.out, "chain_ckpt"))
            with api.SamplingService(workers=args.service_workers,
                                     pool=args.service_fleet or None) as svc:
                handle = svc.submit(
                    session, n_samples=args.samples, key=job_key,
                    macro_batches=n1, skip_batches=done,
                    checkpoint_root=ck_root)
                for b, block in handle.stream():
                    save_batch(b, block)
                    p = handle.progress
                    st = svc.stats()
                    adm = st["admission"]
                    lanes = " ".join(
                        f"{n}:{c}"
                        for n, c in sorted(st["lane_batches"].items()))
                    print(f"[service] {p['done']}/{p['total']} batches "
                          f"(claims={p['claims']} requeues={p['requeues']} "
                          f"lanes={p['workers']}) queue_depth="
                          f"{st['queue_depth']} backpressure="
                          f"{'yes' if adm['backpressure'] else 'no'} "
                          f"(admitted={adm['admitted_jobs']} queued="
                          f"{adm['queued_jobs']}) per-lane: {lanes}",
                          flush=True)
                final = svc.stats()
                print("[service] final:", handle.status(), final)
                print(f"[service] per-lane batch counts: "
                      f"{final['lane_batches']}  stragglers: "
                      f"{final['stragglers']}" +
                      (f"  transport: {final['transport']}"
                       if args.service_fleet else ""), flush=True)
        else:
            session.run_queue(
                queue, per_batch, base, worker="driver",
                checkpoint_root=(None if clamp is not None else
                                 os.path.join(args.out, "chain_ckpt")),
                on_batch=save_batch)
        if session.stats:
            print("streaming stats:",
                  {k: (round(v, 4) if isinstance(v, float) else v)
                   for k, v in session.stats.items()
                   if k != "log_prob"})
        if clamp is not None and lp_blocks:
            # the conditional weights: ln P(clamped outcomes | earlier
            # sites) per sample — exp-mean estimates the clamp marginal
            lp = np.concatenate([lp_blocks[b] for b in sorted(lp_blocks)])
            w = np.exp(lp)
            print(f"clamp log_prob: n={lp.size} mean={lp.mean():.6f} "
                  f"min={lp.min():.6f} max={lp.max():.6f}  "
                  f"P(clamp) ≈ {w.mean():.6g}")
        # where the Γ bytes moved: disk I/O lives on the store counters,
        # interconnect/dispatch bytes on the runtime's
        print("runtime counters:", runtime.io_counters())
        # where the kernel block sizes came from (TPU: timed sweep entries;
        # elsewhere: heuristic table — either way cached per process)
        print("autotuner cache:", dispatch.autotune_cache_stats())
    if args.stream:
        source.close()

    # merge + stats
    allb = [np.load(os.path.join(args.out, f"batch_{b:05d}.npy"))
            for b in range(n1)]
    samples = np.concatenate(allb, axis=0)
    mean_photons = samples.mean(axis=0)
    stats = {"n_samples": int(samples.shape[0]), "sites": args.sites,
             "chi": args.chi, "walltime_s": time.perf_counter() - t0,
             "mean_photon_min": float(mean_photons.min()),
             "mean_photon_max": float(mean_photons.max())}
    with open(os.path.join(args.out, "stats.json"), "w") as f:
        json.dump(stats, f, indent=1)
    print(json.dumps(stats, indent=1))


if __name__ == "__main__":
    main()
