"""GBS sampling driver: the paper's workload end-to-end, fault-tolerant.

Walks the macro-batch work queue (runtime/elastic.py) over the multi-level
parallel sampler, checkpointing after every macro batch — kill it at any
point and rerun: it resumes from the queue state and produces bit-identical
samples (paper §4.1).

Usage:
  PYTHONPATH=src python -m repro.launch.sample --sites 64 --chi 64 \
      --samples 4096 --macro-batches 4 --scheme dp --out /tmp/gbs

Streaming mode (chains too big for device memory, paper §3.1/§3.3.2):
  PYTHONPATH=src python -m repro.launch.sample --sites 512 --chi 64 \
      --samples 4096 --stream --store /tmp/gbs_gamma --segment-len 64
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dynamic_bond as DB
from repro.core import mps as M
from repro.core import parallel as PP
from repro.core import sampler as S
from repro.core.perfmodel import TPU_V5E, Workload
from repro.data.gamma_store import GammaStore
from repro.engine import StreamPlan, StreamingEngine, explain_plan, plan_stream
from repro.launch.mesh import make_host_mesh
from repro.runtime.elastic import WorkQueue


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sites", type=int, default=64)
    ap.add_argument("--chi", type=int, default=64)
    ap.add_argument("--d", type=int, default=3)
    ap.add_argument("--samples", type=int, default=4096)
    ap.add_argument("--macro-batches", type=int, default=4)
    ap.add_argument("--scheme", default="dp",
                    choices=["dp", "tp_single", "tp_double", "baseline19"])
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dynamic-bond", action="store_true")
    ap.add_argument("--precision", default="fp64",
                    choices=["fp64", "fp32", "mxu_bf16"])
    ap.add_argument("--out", default="/tmp/fastmps_out")
    ap.add_argument("--stream", action="store_true",
                    help="segment-streamed engine (Γ from --store, §3.1)")
    ap.add_argument("--store", default=None,
                    help="GammaStore dir; built from the synthetic MPS if empty")
    ap.add_argument("--segment-len", type=int, default=0,
                    help="sites per streamed segment (0 = perfmodel planner)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    mesh = make_host_mesh(model=args.model_parallel)
    print(f"mesh: {dict(mesh.shape)}  scheme: {args.scheme}")

    dtype = jnp.float64 if args.precision == "fp64" else jnp.float32
    compute = jnp.bfloat16 if args.precision == "mxu_bf16" else None

    def build_mps():
        return M.gbs_like_mps(jax.random.key(args.seed), args.sites,
                              args.chi, args.d,
                              dtype=jnp.float64).astype(dtype)

    # streaming mode reads Γ from the store — only materialize the full
    # in-memory chain when a path actually consumes it (that is the point
    # of streaming: the chain may not fit in host memory at all)
    mps = None if args.stream else build_mps()
    scfg = S.SamplerConfig(compute_dtype=compute)
    pcfg = PP.ParallelConfig(scheme=args.scheme)

    n1 = args.macro_batches
    assert args.samples % n1 == 0
    per_batch = args.samples // n1

    # resume: macro batches already on disk are done (idempotent by id)
    queue = WorkQueue(n1, seed=args.seed)
    for b in range(n1):
        if os.path.exists(os.path.join(args.out, f"batch_{b:05d}.npy")):
            queue.complete(b)
    print(f"pending macro batches: {queue.pending}")

    if args.dynamic_bond:
        prof = DB.area_law_profile(args.sites, args.chi, n_photon=1.0)
        buck = DB.bucketize(prof, sorted({args.chi // 4, args.chi // 2,
                                          args.chi}))
        print("table1:", DB.table1_metrics(prof, args.chi))

    engine = None
    if args.stream:
        assert not args.dynamic_bond, "--stream composes with uniform χ only"
        assert args.scheme != "baseline19", "--stream has no [19] pipeline"
        root = args.store or os.path.join(args.out, "gamma_store")
        compute = {"fp64": jnp.float64, "fp32": jnp.float32,
                   "mxu_bf16": jnp.float32}[args.precision]
        store = GammaStore(root, compute_dtype=compute)
        if store.n_sites == 0:
            print(f"writing Γ store ({args.sites} sites) to {root}")
            store.write_mps(build_mps())
        if args.segment_len:
            plan = StreamPlan(segment_len=args.segment_len,
                              scheme=args.scheme, checkpoint_every=1)
        else:
            import dataclasses as _dc
            w = Workload(n_samples=args.samples, n_sites=args.sites,
                         chi=args.chi, d=args.d, macro_batch=per_batch,
                         micro_batch=per_batch)
            plan = plan_stream(w, TPU_V5E, p1=len(jax.devices())
                               // args.model_parallel, p2=args.model_parallel,
                               checkpoint_every=1)
            if plan.scheme != args.scheme:
                # the planner sizes segments; the requested schedule wins
                print(f"planner suggested scheme {plan.scheme!r}; "
                      f"honouring --scheme {args.scheme!r}")
                # argparse schemes are all parallel → N₂ is inmem-only
                plan = _dc.replace(plan, scheme=args.scheme, micro_batch=None)
            print("plan:", explain_plan(plan, w, TPU_V5E))
        engine = StreamingEngine(
            store, config=scfg, plan=plan,
            mesh=mesh if plan.scheme != "inmem" else None,
            pconfig=PP.ParallelConfig(plan.scheme)
            if plan.scheme != "inmem" else None)

    base = jax.random.key(args.seed + 1)
    t0 = time.perf_counter()
    while (b := queue.claim("driver")) is not None:
        kb = jax.random.fold_in(base, b)
        if engine is not None:
            # one checkpoint dir per macro batch: a mid-batch kill resumes
            # from the last segment boundary instead of restarting the chain
            ck = os.path.join(args.out, "chain_ckpt", f"batch_{b:05d}")
            engine.checkpoint_dir = ck
            os.makedirs(ck, exist_ok=True)
            partial = any(f.startswith("site_") for f in os.listdir(ck))
            out = engine.sample(per_batch, kb, resume=partial)
            shutil.rmtree(ck, ignore_errors=True)   # batch_*.npy is durable
        elif args.dynamic_bond:
            out = DB.sample_staged(mps, buck, per_batch, kb, scfg)
        else:
            out = PP.multilevel_sample(mesh, mps, per_batch, kb, pcfg, scfg)
        np.save(os.path.join(args.out, f"batch_{b:05d}.npy"),
                np.asarray(out).astype(np.int8))
        queue.complete(b)
        print(f"macro batch {b} done ({per_batch} samples)", flush=True)
    if engine is not None:
        print("streaming stats:", {k: (round(v, 4) if isinstance(v, float)
                                       else v) for k, v in engine.stats.items()})
        engine.close()

    # merge + stats
    allb = [np.load(os.path.join(args.out, f"batch_{b:05d}.npy"))
            for b in range(n1)]
    samples = np.concatenate(allb, axis=0)
    mean_photons = samples.mean(axis=0)
    stats = {"n_samples": int(samples.shape[0]), "sites": args.sites,
             "chi": args.chi, "walltime_s": time.perf_counter() - t0,
             "mean_photon_min": float(mean_photons.min()),
             "mean_photon_max": float(mean_photons.max())}
    with open(os.path.join(args.out, "stats.json"), "w") as f:
        json.dump(stats, f, indent=1)
    print(json.dumps(stats, indent=1))


if __name__ == "__main__":
    main()
