"""End-to-end training driver with checkpoint/auto-resume.

Runs on whatever devices exist (CPU here, a pod in production — the mesh is
the only difference).  Fault-tolerance contract:
  * checkpoints every --ckpt-every steps (atomic, keep-last-3);
  * on start, auto-resumes from the latest checkpoint if present;
  * batches are (seed, step)-deterministic, so a resumed run consumes the
    exact stream an uninterrupted run would have (restart-exact training).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
      --steps 50 --batch 4 --seq 32 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import store
from repro.data.tokens import synthetic_token_stream
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import optimizers, schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = make_host_mesh(model=args.model_parallel)
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name}  family: {cfg.family}")

    opt = optimizers.optimizer_for(cfg, schedule.cosine_schedule(
        args.lr, warmup=max(args.steps // 20, 1), total=args.steps))

    params, specs = T.init_params(jax.random.key(args.seed), cfg)
    opt_state = opt.init(params)
    start_step = 0

    if args.ckpt_dir and store.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start_step, _ = store.load_checkpoint(
            args.ckpt_dir, (params, opt_state))
        print(f"resumed from step {start_step}")

    with mesh:
        psh = steps_mod.param_shardings(mesh, params, specs, fsdp=False)
        params = jax.device_put(params, psh)
        step_fn = jax.jit(steps_mod.make_train_step(cfg, opt),
                          donate_argnums=(0, 1))
        batch_at = synthetic_token_stream(args.seed, cfg.vocab,
                                          args.batch, args.seq)
        t_last = time.perf_counter()
        for step in range(start_step, args.steps):
            batch = dict(batch_at(step))
            if cfg.family == "encdec":
                batch["frames"] = jax.random.normal(
                    jax.random.fold_in(jax.random.key(1), step),
                    (args.batch, cfg.enc_len, cfg.d_model), cfg.dtype)
            if cfg.family == "vlm":
                batch["patches"] = jax.random.normal(
                    jax.random.fold_in(jax.random.key(2), step),
                    (args.batch, cfg.n_patches, cfg.d_model), cfg.dtype)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if (step + 1) % 10 == 0 or step == start_step:
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t_last
                t_last = time.perf_counter()
                print(f"step {step + 1:5d}  loss {loss:8.4f}  "
                      f"gnorm {float(metrics['gnorm']):8.3f}  ({dt:.2f}s)",
                      flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                store.save_checkpoint(args.ckpt_dir, step + 1,
                                      (params, opt_state))
    print("done")


if __name__ == "__main__":
    main()
