"""Trip-count-aware HLO cost analysis (the dry-run "profiler").

``compiled.cost_analysis()`` visits a ``while`` body **once**, so for
scan-over-layers models (all of ours — O(1) compile in depth) it
under-counts FLOPs, bytes and collectives by a factor of L.  This module
re-derives the three roofline terms from ``compiled.as_text()`` with loop
multiplicities propagated through the call graph:

  * computations are parsed into instruction lists with result shapes;
  * ``while`` trip counts are recovered from the loop-condition's integer
    constant (jax scans lower to ``lt(i, L)``);
  * multiplicity flows ENTRY → fusion/call/conditional/while-body edges;
  * per instruction we account
      - dot FLOPs:      2 · |result| · Π contracting dims   (×4 if complex)
      - collective wire bytes (ring algorithms, per participating device):
          all-reduce       2·b·(g−1)/g        (b = result bytes, g = group)
          all-gather       b·(g−1)/g          (b = *result* = gathered size)
          reduce-scatter   b·(g−1)            (result is the scattered shard)
          all-to-all       b·(g−1)/g
          collective-permute  b
      - memory-traffic proxy: result bytes of every materializing op
        (fusion internals excluded — they live in registers/VMEM) plus dot
        operand reads.  This is a *proxy*: XLA's true ``bytes accessed`` is
        fusion-aware, but is not loop-aware; we prefer loop-correct.

All byte numbers are per-device (the compiled module is the per-device SPMD
program).  Validated against hand-counts in tests/test_hloanalysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose results are bookkeeping, not memory traffic
_NO_TRAFFIC = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota", "copy-start",
    "copy-done", "opt-barrier", "custom-call",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_NAME_EQ_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _parse_shapes(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    """'f32[128,256]{1,0}' or '(s32[], f32[10])' → [(dtype, dims), ...]."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, shape in _parse_shapes(type_str):
        n = 1
        for s in shape:
            n *= s
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    type_str: str
    line: str

    @property
    def result_bytes(self) -> int:
        return _bytes_of(self.type_str)

    @property
    def result_shape(self) -> Optional[tuple[str, tuple[int, ...]]]:
        shapes = _parse_shapes(self.type_str)
        return shapes[0] if shapes else None


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list[Instr]
    header: str

    def find(self, name: str) -> Optional[Instr]:
        for i in self.instrs:
            if i.name == name:
                return i
        return None


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr:
            cur = Computation(hdr.group(2), bool(hdr.group(1)), [], line)
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        line = _COMMENT_RE.sub("", line)     # strip /*index=N*/ comments
        m = _NAME_EQ_RE.match(line)
        if not m:
            continue
        rest = line[m.end():]
        op = _OPCODE_RE.search(rest)
        if not op:
            continue
        type_str = rest[: op.start()].strip()
        cur.instrs.append(Instr(m.group(1), op.group(1), type_str, line))
    return comps


def _trip_count(cond: Computation) -> int:
    """Loop bound = the largest integer constant in the condition comp (and
    its compare fusion).  jax scans compare the induction var to L."""
    best = 1
    for i in cond.instrs:
        for m in _CONST_RE.finditer(i.line):
            best = max(best, int(m.group(1)))
    return best


def _multiplicities(comps: dict[str, Computation]) -> dict[str, float]:
    """Propagate execution counts from ENTRY through the call graph."""
    mult: dict[str, float] = {c.name: 0.0 for c in comps.values()}
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:        # fall back: treat first computation as entry
        entry = next(iter(comps.values()))
    mult[entry.name] = 1.0

    # reverse-topological-ish fixed point (call graphs are acyclic in HLO)
    edges: dict[str, list[tuple[str, float]]] = {c: [] for c in comps}
    for c in comps.values():
        for i in c.instrs:
            if i.opcode == "while":
                names = dict(
                    (k, v) for k, v in
                    re.findall(r"(body|condition)=%?([\w\.\-]+)", i.line))
                body, cond = names.get("body"), names.get("condition")
                trips = _trip_count(comps[cond]) if cond in comps else 1
                if body in comps:
                    edges[c.name].append((body, float(trips)))
                if cond in comps:
                    edges[c.name].append((cond, float(trips + 1)))
            elif i.opcode == "conditional":
                b = _BRANCHES_RE.search(i.line)
                if b:
                    for name in re.findall(r"%?([\w\.\-]+)", b.group(1)):
                        if name in comps:
                            edges[c.name].append((name, 1.0))
            else:
                for name in _CALLS_RE.findall(i.line):
                    if name in comps:
                        edges[c.name].append((name, 1.0))

    # BFS propagation (acyclic)
    frontier = [entry.name]
    seen_order = []
    while frontier:
        nxt = []
        for cn in frontier:
            seen_order.append(cn)
            for callee, factor in edges[cn]:
                mult[callee] += mult[cn] * factor
                nxt.append(callee)
        frontier = nxt
        if len(seen_order) > 100_000:   # cycle guard
            break
    return mult


def _dot_flops(instr: Instr, comp: Computation,
               param_types: dict[str, str]) -> float:
    res = instr.result_shape
    if res is None:
        return 0.0
    dt, rshape = res
    n_res = 1
    for s in rshape:
        n_res *= s
    # contracting dims from the lhs operand.  Newer XLA prints operands with
    # their types inline — "dot(f32[64,128]{1,0} %Arg_0, ...)" — so prefer the
    # inline shape; fall back to a by-name lookup for the old untyped form.
    mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    tail = instr.line.split(f"{instr.opcode}(")[-1].lstrip()
    contract = 1
    lshape: Optional[tuple[int, ...]] = None
    tm = _SHAPE_RE.match(tail)
    if tm and tm.group(1) in DTYPE_BYTES:
        lshape = (tuple(int(x) for x in tm.group(2).split(","))
                  if tm.group(2) else ())
    else:
        ops = re.match(r"\s*%?([\w\.\-]+)", tail)
        if ops:
            lhs = comp.find(ops.group(1))
            lhs_type = lhs.type_str if lhs else param_types.get(ops.group(1), "")
            shapes = _parse_shapes(lhs_type)
            if shapes:
                lshape = shapes[0][1]
    if mdims and lshape is not None:
        for d in (int(x) for x in mdims.group(1).split(",") if x):
            if d < len(lshape):
                contract *= lshape[d]
    flops = 2.0 * n_res * contract
    if dt in ("c64", "c128"):
        flops *= 4.0
    return flops


def _group_size(line: str, default: int = 1) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_OLD_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_bytes(opcode: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if opcode == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if opcode == "all-gather":
        return result_bytes * (g - 1) / g
    if opcode == "reduce-scatter":
        return float(result_bytes) * (g - 1)
    if opcode == "all-to-all":
        return result_bytes * (g - 1) / g
    if opcode == "collective-permute":
        return float(result_bytes)
    return 0.0


@dataclasses.dataclass
class HLOCost:
    flops: float                       # per-device, loop-corrected
    memory_bytes: float                # per-device traffic proxy
    collective_wire_bytes: float       # per-device, ring model
    collective_raw_bytes: float        # Σ operand sizes (the naive metric)
    per_collective: dict               # opcode → wire bytes
    n_collectives: dict                # opcode → (loop-weighted) count
    upcast_bytes: float = 0.0          # pure dtype-convert traffic.  The CPU
    # backend has no bf16 compute units, so XLA hoists whole-array bf16→f32
    # converts in front of loops; the TPU MXU consumes bf16 natively and
    # this traffic does not exist there.  Kept separate so the roofline can
    # report the TPU-true memory term (memory_bytes − upcast_bytes).


def analyze(text: str) -> HLOCost:
    comps = parse_hlo(text)
    mult = _multiplicities(comps)

    # computations that are a single dtype convert (wrapped_convert fusions)
    pure_convert = set()
    for c in comps.values():
        body = [i for i in c.instrs if i.opcode != "parameter"]
        if len(body) == 1 and body[0].opcode == "convert":
            pure_convert.add(c.name)

    flops = 0.0
    mem = 0.0
    wire = 0.0
    raw = 0.0
    upcast = 0.0
    per: dict[str, float] = {}
    cnt: dict[str, float] = {}

    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if m <= 0:
            continue
        # entry-header parameter types (operands referenced directly)
        param_types: dict[str, str] = {}
        for pm in re.finditer(r"%?([\w\.\-]+):\s*([\w\[\]\{\},\d]+)", c.header):
            param_types[pm.group(1)] = pm.group(2)
        fusion_names = {i.name for i in c.instrs if i.opcode == "fusion"}
        is_fusion_comp = any(
            c.name.startswith(p) for p in ("fused_", "wrapped_"))
        for i in c.instrs:
            if i.opcode == "dot" or i.opcode == "convolution":
                f = _dot_flops(i, c, param_types)
                flops += m * f
                # dot reads lhs+rhs ≈ contract·(rows+cols): approximate via
                # result + 2×result (safe proxy for square-ish GEMMs)
                mem += m * 2 * i.result_bytes
            if i.opcode in COLLECTIVES:
                g = _group_size(i.line)
                w = _wire_bytes(i.opcode, i.result_bytes, g)
                wire += m * w
                raw += m * i.result_bytes
                per[i.opcode] = per.get(i.opcode, 0.0) + m * w
                cnt[i.opcode] = cnt.get(i.opcode, 0.0) + m
            if (i.opcode not in _NO_TRAFFIC and not is_fusion_comp):
                if i.opcode == "convert" or (
                        i.opcode == "fusion"
                        and any(n in pure_convert
                                for n in _CALLS_RE.findall(i.line))):
                    upcast += m * i.result_bytes
                    mem += m * i.result_bytes
                elif i.opcode == "dynamic-update-slice":
                    # writes only the update operand, not the whole buffer
                    tail = i.line.split("dynamic-update-slice(")[-1]
                    names = re.findall(r"%([\w\.\-]+)", tail)
                    upd = comp_find = None
                    if len(names) >= 2:
                        comp_find = c.find(names[1])
                    mem += m * (comp_find.result_bytes if comp_find
                                else i.result_bytes)
                else:
                    mem += m * i.result_bytes

    return HLOCost(flops, mem, wire, raw, per, cnt, upcast)


# ---------------------------------------------------------------------------
# Roofline terms (§Roofline): TPU v5e constants
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


@dataclasses.dataclass
class Roofline:
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    hlo_flops: float                # per-device × chips = total
    useful_ratio: float             # MODEL_FLOPS / HLO_FLOPs

    def table_row(self) -> dict:
        return {
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_ratio,
        }


def roofline(cost: HLOCost, n_chips: int, model_flops: float,
             peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW,
             ici_bw: float = ICI_BW) -> Roofline:
    """cost is the per-device program; totals scale by n_chips."""
    total_flops = cost.flops * n_chips
    t_comp = total_flops / (n_chips * peak_flops)
    t_mem = (cost.memory_bytes * n_chips) / (n_chips * hbm_bw)
    t_coll = (cost.collective_wire_bytes * n_chips) / (n_chips * ici_bw)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bott = max(terms, key=terms.get)
    useful = model_flops / total_flops if total_flops else 0.0
    return Roofline(t_comp, t_mem, t_coll, bott, model_flops, total_flops,
                    useful)
