"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the cached
dry-run JSONs (experiments/dryrun/*.json).

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b: float) -> str:
    if b >= 1e12:
        return f"{b / 1e12:.1f}TB"
    if b >= 1e9:
        return f"{b / 1e9:.1f}GB"
    if b >= 1e6:
        return f"{b / 1e6:.1f}MB"
    return f"{b / 1e3:.0f}KB"


def fmt_t(t: float) -> str:
    if t >= 1.0:
        return f"{t:.2f}s"
    if t >= 1e-3:
        return f"{t * 1e3:.1f}ms"
    return f"{t * 1e6:.0f}µs"


def load(dirname: str) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def dryrun_table(rows: list[dict], mesh: str | None = None) -> str:
    out = ["| arch | shape | mesh | chips | status | mem/dev | FLOPs/dev | "
           "wire B/dev | #coll | compile |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if mesh and r.get("mesh") != mesh:
            continue
        base = f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        if "skipped" in r:
            out.append(base + f"| — | SKIP ({r['skipped'][:40]}…) | | | | | |")
            continue
        if "error" in r:
            out.append(base + f"| — | FAIL {r['error'][:40]} | | | | | |")
            continue
        h = r["hlo"]
        ncoll = sum(r["hlo"]["n_collectives"].values())
        out.append(
            base + f"| {r['n_chips']} | ok "
            f"| {fmt_bytes(r['bytes_per_device']['peak_estimate'])} "
            f"| {h['flops_per_device']:.2e} "
            f"| {fmt_bytes(h['collective_wire_bytes_per_device'])} "
            f"| {ncoll:.0f} | {r['compile_s']:.0f}s |")
    return "\n".join(out)


def roofline_table(rows: list[dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | MODEL_FLOPS | HLO_FLOPs | useful |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != mesh or "roofline" not in r:
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {fmt_t(rf['t_compute_s'])} | {fmt_t(rf['t_memory_s'])} "
            f"| {fmt_t(rf['t_collective_s'])} | **{rf['bottleneck']}** "
            f"| {rf['model_flops']:.2e} | {rf['hlo_flops']:.2e} "
            f"| {rf['useful_ratio']:.2f} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--kind", default="roofline",
                    choices=["roofline", "dryrun"])
    args = ap.parse_args()
    rows = load(args.dir)
    if args.kind == "roofline":
        print(roofline_table(rows, args.mesh or "16x16"))
    else:
        print(dryrun_table(rows, args.mesh))


if __name__ == "__main__":
    main()
