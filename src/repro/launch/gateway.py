"""Gateway launcher: the multi-tenant HTTP front door as a process.

Wires the full serving stack — `SamplingService` lanes (threads or a
fleet `WorkerPool`), the tenant table, the content-addressed result
cache, and the `repro.obs` metrics registry — behind one
`repro.serve.Gateway`, prints the bound URL, and ticks a live stats line.

Usage:
  PYTHONPATH=src python -m repro.launch.gateway --port 8752 --workers 2 \
      --tenants tenants.json --store-root /data/stores \
      --cache-dir /tmp/fastmps_cache \
      --max-cache-bytes 1000000000 --max-active-bytes 8e9

With ``--store-root``, clients name stores *relative* to that directory
(``{"store": "demo_chain"}``) and can never reach outside it; without
it the gateway runs in trusted single-user mode where ``store`` is a
server path.  Always set a root when serving untrusted tenants.

Smoke/CI mode (bind an ephemeral port, build a demo store, exit after N
seconds):
  PYTHONPATH=src python -m repro.launch.gateway --port 0 --serve-s 20 \
      --demo-store /tmp/gw_demo --sites 8 --chi 4
"""
from __future__ import annotations

import argparse
import sys
import time


def _build_demo_store(root: str, sites: int, chi: int, d: int) -> None:
    import jax
    import jax.numpy as jnp

    from repro.core import mps as M
    from repro.data.gamma_store import GammaStore

    mps = M.random_linear_mps(jax.random.key(0), sites, chi, d)
    with GammaStore(root, storage_dtype=jnp.float64,
                    compute_dtype=jnp.float64) as store:
        store.write_mps(mps)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8752,
                    help="0 = ephemeral (the bound port is printed)")
    ap.add_argument("--workers", type=int, default=2,
                    help="service lanes")
    ap.add_argument("--fleet", action="store_true",
                    help="persistent worker processes instead of threads")
    ap.add_argument("--tenants", default=None,
                    help="tenants.json (see repro.serve.tenancy); "
                         "omitted = open single-tenant mode")
    ap.add_argument("--store-root", default=None,
                    help="confine client store names beneath this "
                         "directory; omitted = trusted mode (store is a "
                         "server path)")
    ap.add_argument("--cache-dir", default=None,
                    help="result-cache disk store (omitted = memory only)")
    ap.add_argument("--max-cache-bytes", type=float, default=None,
                    help="LRU budget for --cache-dir")
    ap.add_argument("--max-active-bytes", type=float, default=None,
                    help="service admission budget (perfmodel Eq. 3)")
    ap.add_argument("--stats-every", type=float, default=10.0,
                    help="seconds between live stats lines (0 = quiet)")
    ap.add_argument("--serve-s", type=float, default=None,
                    help="exit after N seconds (CI smoke); default: forever")
    ap.add_argument("--demo-store", default=None,
                    help="write a random demo GammaStore here at startup")
    ap.add_argument("--sites", type=int, default=8)
    ap.add_argument("--chi", type=int, default=4)
    ap.add_argument("--d", type=int, default=3)
    args = ap.parse_args(argv)

    from repro import api
    from repro.obs import (MetricsRegistry, instrument_dispatch,
                           instrument_service)
    from repro.serve import Gateway, ResultCache, TenantTable

    if args.demo_store:
        _build_demo_store(args.demo_store, args.sites, args.chi, args.d)
        print(f"demo store: {args.demo_store}", flush=True)

    tenants = (TenantTable.from_json(args.tenants) if args.tenants
               else TenantTable())
    if args.tenants and not args.store_root:
        print("warning: --tenants without --store-root lets every tenant "
              "name arbitrary server paths as stores", file=sys.stderr)
    cache = ResultCache(cache_dir=args.cache_dir,
                        max_bytes=(None if args.max_cache_bytes is None
                                   else int(args.max_cache_bytes)))
    registry = MetricsRegistry()
    instrument_dispatch(registry)
    with api.SamplingService(workers=args.workers,
                             pool=True if args.fleet else None,
                             max_active_bytes=args.max_active_bytes) as svc:
        instrument_service(svc, registry)
        with Gateway(svc, tenants=tenants, cache=cache, registry=registry,
                     host=args.host, port=args.port,
                     store_root=args.store_root) as gw:
            print(f"gateway listening on {gw.url}", flush=True)
            deadline = (None if args.serve_s is None
                        else time.monotonic() + args.serve_s)
            next_stats = time.monotonic() + (args.stats_every or 1e18)
            try:
                while deadline is None or time.monotonic() < deadline:
                    time.sleep(0.2)
                    if time.monotonic() >= next_stats:
                        next_stats = time.monotonic() + args.stats_every
                        st = gw.stats()
                        print(f"[stats] requests={st['gateway']['requests']} "
                              f"jobs={st['gateway']['by_state']} "
                              f"cache(hit={st['cache']['hits']} "
                              f"miss={st['cache']['misses']} "
                              f"attach={st['cache']['attaches']}) "
                              f"queue_depth={st['service']['queue_depth']} "
                              f"backpressure="
                              f"{st['service']['admission']['backpressure']}",
                              flush=True)
            except KeyboardInterrupt:
                pass
            st = gw.stats()
            print(f"gateway exit: {st['gateway']['requests']} requests, "
                  f"{st['cache']['hits']} cache hits", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
