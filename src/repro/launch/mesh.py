"""Production mesh definitions.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — "pod" is a
second data-parallel axis over the slow inter-pod links (gradient
all-reduce crosses it once per step; the sampler shards samples over it).

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host actually has — for tests and examples.

    Runtime-aware callers (``launch/sample.py``) ask the session's
    :class:`repro.api.runtime.ClusterRuntime` instead —
    ``runtime.mesh(model)`` — so the mesh covers the runtime's *global*
    device view rather than assuming the local host."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axis_names(mesh) -> tuple[str, ...]:
    return tuple(ax for ax in mesh.axis_names if ax != "model")


def data_parallel_size(mesh) -> int:
    out = 1
    for ax in data_axis_names(mesh):
        out *= mesh.shape[ax]
    return out
