"""Continuous-batching LM serving driver (the §5 analogy demo — the MPS
sampling gateway proper is ``repro.launch.gateway`` / ``repro.serve``).

The paper's §5 analogy made executable in the other direction: the
FastMPS macro-batch work queue becomes a *request* queue, the left
environment becomes the KV/latent/SSM cache, and slot management replaces
macro-batch scheduling.

Design (vLLM-lite, single jitted step):
  * a fixed pool of B cache slots; each active slot decodes one request;
  * when a request finishes (EOS token or max length), its slot is
    *immediately* refilled from the waiting queue — the batch never drains
    (continuous batching, not static batching);
  * refill resets that slot's cache rows and position via masked updates,
    so the decode step stays a single jit with static shapes;
  * per-slot positions (B,) replace the global scalar — each slot's causal
    mask is independent.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_lm --arch deepseek-7b --smoke \
      --requests 32 --batch 8 --max-new 24
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer as T

Array = jax.Array


class SlotState:
    """Host-side bookkeeping for one cache slot."""

    def __init__(self):
        self.request_id: Optional[int] = None
        self.generated: list[int] = []


def make_decode_fn(cfg):
    """(params, tokens (B,1), caches, positions (B,)) → (next, caches)."""

    def step(params, tokens, caches, positions):
        # per-slot positions: run decode_step with position = min over the
        # batch is wrong in general — instead we exploit that the KV cache
        # write index is per-slot: we pass each slot's own position through
        # a batched decode.  The stacked-layer decode path expects a scalar
        # write index, so we vmap it over the batch dimension.
        def one(p, tok, cache, pos):
            # re-insert a singleton batch dim for the stacked-cache layout
            cache1 = jax.tree_util.tree_map(lambda a: a[:, None], cache)
            st = T.DecodeState(cache1, pos)
            logits, new = T.decode_step(p, tok[None], st, cfg)
            return logits[0], jax.tree_util.tree_map(
                lambda a: a[:, 0], new.caches)

        logits, new_caches = jax.vmap(one, in_axes=(None, 0, 0, 0))(
            params, tokens, caches, positions)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], new_caches

    return step


def _unstack_batch(caches, batch):
    """(L, B, …) stacked caches → (B, L, …) for vmap-over-batch."""
    return jax.tree_util.tree_map(lambda a: jnp.moveaxis(a, 1, 0), caches)


def _reset_slot(caches, slot: int):
    return jax.tree_util.tree_map(
        lambda a: a.at[slot].set(jnp.zeros_like(a[slot])), caches)


def serve(cfg, params, prompts, batch: int, max_new: int,
          cache_len: int, eos: Optional[int] = None, verbose: bool = True):
    """Greedy-decode every prompt with continuous batching.

    prompts: per request either a first token (int) or (first_token,
    max_len) — variable-length requests are what make continuous batching
    beat static batching.  Returns {request_id: [generated tokens]}.
    """
    prompts = [p if isinstance(p, tuple) else (p, max_new) for p in prompts]
    step = jax.jit(make_decode_fn(cfg))
    init = T.init_decode_state(cfg, batch, cache_len)
    caches = _unstack_batch(init.caches, batch)       # (B, L, …)
    positions = jnp.zeros((batch,), jnp.int32)
    tokens = jnp.zeros((batch, 1), jnp.int32)

    waiting = list(enumerate(prompts))
    slots = [SlotState() for _ in range(batch)]
    done: dict[int, list[int]] = {}
    t0 = time.perf_counter()
    steps = 0

    limits = [max_new] * batch

    def refill(slot_idx, caches, positions, tokens):
        rid, (first_tok, limit) = waiting.pop(0)
        slots[slot_idx].request_id = rid
        slots[slot_idx].generated = []
        limits[slot_idx] = limit
        caches = _reset_slot(caches, slot_idx)
        positions = positions.at[slot_idx].set(0)
        tokens = tokens.at[slot_idx].set(first_tok)
        return caches, positions, tokens

    # initial fill
    for i in range(batch):
        if waiting:
            caches, positions, tokens = refill(i, caches, positions, tokens)

    while any(s.request_id is not None for s in slots):
        tokens, caches = step(params, tokens, caches, positions)
        positions = positions + 1
        steps += 1
        toks_host = np.asarray(tokens[:, 0])
        for i, s in enumerate(slots):
            if s.request_id is None:
                continue
            s.generated.append(int(toks_host[i]))
            finished = (len(s.generated) >= limits[i]
                        or (eos is not None and s.generated[-1] == eos)
                        or int(positions[i]) >= cache_len - 1)
            if finished:
                done[s.request_id] = s.generated
                s.request_id = None
                if waiting:
                    caches, positions, tokens = refill(i, caches, positions,
                                                       tokens)
    dt = time.perf_counter() - t0
    if verbose:
        total = sum(len(v) for v in done.values())
        print(f"served {len(done)} requests, {total} tokens in {dt:.2f}s "
              f"({total / dt:.0f} tok/s, {steps} batch steps; "
              f"static batching would need "
              f"{-(-len(done) // batch) * max_new} steps, ran {steps})")
    return done


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=configs.ARCHS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    params, _ = T.init_params(jax.random.key(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    toks = rng.integers(0, cfg.vocab, size=args.requests)
    lens = rng.integers(max(2, args.max_new // 4), args.max_new + 1,
                        size=args.requests)
    # variable-length requests exercise the continuous refill
    prompts = [(int(t), int(l)) for t, l in zip(toks, lens)]
    done = serve(cfg, params, prompts, args.batch, args.max_new,
                 args.cache_len, eos=0)
    lens = sorted(len(v) for v in done.values())
    print(f"request lengths: min {lens[0]} max {lens[-1]} "
          f"(EOS=0 ends a request early)")


if __name__ == "__main__":
    main()
