"""Architecture registry + assigned input shapes.

Every ``<arch>.py`` exports ``CONFIG`` (the exact published config) and
``SMOKE`` (a reduced same-family config for CPU tests).  GBS presets for the
paper's own experiments live in ``gbs.py``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig

ARCHS = [
    "zamba2-7b", "qwen1.5-4b", "deepseek-7b", "starcoder2-15b",
    "granite-3-2b", "llama-3.2-vision-11b", "whisper-small", "mamba2-1.3b",
    "kimi-k2-1t-a32b", "deepseek-v3-671b",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def _mod(arch: str):
    return importlib.import_module(f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).SMOKE


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch × shape) is a defined cell (long_500k needs sub-quadratic)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch — 500k decode skipped (DESIGN.md)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        spec = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if cfg.family == "encdec":
            spec["frames"] = sds((B, cfg.enc_len, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            spec["patches"] = sds((B, cfg.n_patches, cfg.d_model), cfg.dtype)
        return spec
    if shape.kind == "prefill":
        spec = {"tokens": sds((B, S), i32)}
        if cfg.family == "encdec":
            spec["frames"] = sds((B, cfg.enc_len, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            spec["patches"] = sds((B, cfg.n_patches, cfg.d_model), cfg.dtype)
        return spec
    if shape.kind == "decode":
        spec = {"tokens": sds((B, 1), i32)}
        if cfg.family == "encdec":
            spec["enc_out"] = sds((B, cfg.enc_len, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            spec["patches"] = sds((B, cfg.n_patches, cfg.d_model), cfg.dtype)
        return spec
    raise ValueError(shape.kind)
