"""llama-3.2-vision-11b — dense + cross-attn image layers (stub frontend)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, cross_attn_every=5, n_patches=1600,
    rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke", family="vlm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=128, cross_attn_every=2, n_patches=16,
    remat_policy="none",
)
