"""mamba2-1.3b — attention-free SSD [arXiv:2405.21060]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280, ssm_state=128,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=128, ssm_state=16, ssm_head=16, remat_policy="none",
)
