"""qwen1.5-4b — dense GQA with QKV bias [hf:Qwen/Qwen1.5-4B]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
    d_ff=6912, vocab=151936, qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=128, qkv_bias=True, remat_policy="none",
)
