"""GBS presets matching the paper's experiments (Tables 1-3, Fig. 9-12)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GBSPreset:
    name: str
    n_sites: int          # M
    chi: int              # bond dimension
    d: int                # physical (Fock cutoff)
    n_samples: int        # N
    asp: float            # actual squeezed photons (Table 1)


JIUZHANG2 = GBSPreset("jiuzhang2", 144, 10_000, 4, 10_000_000, 1.62)
JIUZHANG3_H = GBSPreset("jiuzhang3-h", 144, 10_000, 4, 10_000_000, 3.56)
B_M216_H = GBSPreset("b-m216-h", 216, 10_000, 4, 10_000_000, 6.54)
B_M288 = GBSPreset("b-m288", 288, 10_000, 4, 10_000_000, 10.69)
M8176 = GBSPreset("m8176", 8_176, 10_000, 3, 10_000_000, 8.82)

PRESETS = {p.name: p for p in
           [JIUZHANG2, JIUZHANG3_H, B_M216_H, B_M288, M8176]}
