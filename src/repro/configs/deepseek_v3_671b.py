"""deepseek-v3-671b — MLA, 1 shared + 256 routed top-8 [arXiv:2412.19437]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab=129280, n_experts=256, top_k=8, n_shared_experts=1,
    use_mla=True, head_dim=128,
)

SMOKE = ModelConfig(
    name="dsv3-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab=128, n_experts=8, top_k=2, capacity_factor=8.0, n_shared_experts=1,
    use_mla=True, head_dim=16, remat_policy="none",
)
