"""starcoder2-15b — dense GQA kv=4, RoPE [arXiv:2402.19173]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab=49152,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=128, vocab=128, remat_policy="none",
)
