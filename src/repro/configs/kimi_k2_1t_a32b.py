"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 [arXiv:2501]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, n_experts=384, top_k=8, n_shared_experts=1,
)

SMOKE = ModelConfig(
    name="kimi-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=128, n_experts=8, top_k=2, capacity_factor=8.0, n_shared_experts=1,
    remat_policy="none",
)
