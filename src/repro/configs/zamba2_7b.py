"""zamba2-7b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, ssm_state=64, attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=128, ssm_state=16, ssm_head=16, attn_every=2,
    remat_policy="none",
)
