"""whisper-small — enc-dec; conv frontend is a stub (frame embeddings in)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, mlp_style="gelu", enc_len=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=128, mlp_style="gelu", enc_len=32, remat_policy="none",
)
