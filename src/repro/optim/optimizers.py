"""Optimizers built from scratch (no optax dependency).

* ``adamw``      — the standard trainer for ≤100 B-param archs.
* ``adafactor``  — factored second moment; the only optimizer whose state
  fits the trillion-param MoEs on a 512-chip v5e pod (DESIGN.md §4): state is
  O(rows + cols) per matrix instead of O(rows·cols).

Both are implemented as ``(init, update)`` pairs over arbitrary pytrees and
are shard-agnostic: state mirrors the parameter PartitionSpecs (factored
vectors inherit the corresponding row/col axis spec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
Pytree = Any


class OptState(NamedTuple):
    step: Array
    inner: Pytree       # per-leaf optimizer state


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], OptState]
    update: Callable[[Pytree, OptState, Pytree], tuple[Pytree, OptState]]
    name: str = ""


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.01) -> Optimizer:
    def init(params):
        inner = jax.tree_util.tree_map(
            lambda p: {"m": jnp.zeros_like(p, jnp.float32),
                       "v": jnp.zeros_like(p, jnp.float32)}, params)
        return OptState(jnp.zeros((), jnp.int32), inner)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else lr

        def leaf(g, s, p):
            g = g.astype(jnp.float32)
            m = b1 * s["m"] + (1 - b1) * g
            v = b2 * s["v"] + (1 - b2) * g * g
            mhat = m / (1 - b1 ** step.astype(jnp.float32))
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            upd = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype), {"m": m, "v": v}

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state.inner)
        out = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_s = treedef.unflatten([o[1] for o in out])
        return new_p, OptState(step, new_s)

    return Optimizer(init, update, "adamw")


def adafactor(lr: float | Callable = 1e-2, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    """Adafactor (Shazeer & Stern 2018), factored for ndim ≥ 2 leaves."""
    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def leaf(p):
            if _factored(p):
                # factor the last two dims; leading dims (layer stacks,
                # expert axes) stay fully materialized in the vectors.
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree_util.tree_map(leaf, params))

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** -decay
        lr_t = lr(step) if callable(lr) else lr

        def leaf(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = (vr / jnp.mean(vr, axis=-1, keepdims=True))[..., None]
                u = g * jax.lax.rsqrt(rfac * vc[..., None, :] + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            newp = p.astype(jnp.float32) - lr_t * u
            if weight_decay:
                newp = newp - lr_t * weight_decay * p.astype(jnp.float32)
            return newp.astype(p.dtype), new_s

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state.inner)
        out = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (treedef.unflatten([o[0] for o in out]),
                OptState(step, treedef.unflatten([o[1] for o in out])))

    return Optimizer(init, update, "adafactor")


def optimizer_for(cfg, lr=None) -> Optimizer:
    """Policy: MoE giants → adafactor (state must fit HBM); else adamw."""
    total, _ = cfg.param_count()
    if total > 100e9:
        return adafactor(lr or 1e-2)
    return adamw(lr or 3e-4)
