from repro.optim.optimizers import (adamw, adafactor, OptState,
                                    optimizer_for)
from repro.optim.schedule import cosine_schedule
from repro.optim.compression import int8_compress, int8_decompress
