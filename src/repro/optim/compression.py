"""Gradient compression for cross-pod all-reduce (distributed-opt trick).

The paper's FP16-storage insight (§3.3.2: "data movements are insensitive to
errors and bandwidth-limited") applied to the *gradient* wire: int8
block-quantized all-reduce over the slow "pod" axis, full precision inside a
pod.  Per 256-element block we keep a fp32 scale → 4.125 bits/element wire
cost vs 16 for bf16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

BLOCK = 256


def int8_compress(x: Array) -> tuple[Array, Array]:
    """x (any shape) → (int8 values, per-block fp32 scales)."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.where(scale > 0, scale, 1.0)).astype(jnp.int8)
    return q, scale[:, 0]


def int8_decompress(q: Array, scale: Array, shape, dtype) -> Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(x: Array, axis_name: str) -> Array:
    """int8-compressed all-reduce: quantize → psum int32 → dequantize.

    Summing quantized values needs a shared scale: we pmax the scale first
    (one tiny collective), then sum int32 accumulators — exactly how
    bandwidth-optimal grad-compression collectives are built on ICI.
    """
    q, scale = int8_compress(x)
    gmax = jax.lax.pmax(scale, axis_name)
    requant = jnp.round(q.astype(jnp.float32) * scale[:, None]
                        / jnp.where(gmax[:, None] > 0, gmax[:, None], 1.0) * 127.0)
    acc = jax.lax.psum(requant.astype(jnp.int32), axis_name)
    out = acc.astype(jnp.float32) * gmax[:, None] / 127.0
    return out.reshape(-1)[: x.size].reshape(x.shape).astype(x.dtype)
