"""Prometheus-style metrics: one registry for every FastMPS counter.

The repo grew rich telemetry one subsystem at a time — autotuner cache
hits (``kernels/dispatch``), queue depths and admission backpressure
(``api/service``), straggler and transport fault counters
(``runtime/transport``/``stragglers``), broadcast and per-walk I/O bytes
(engine stats) — each surfaced through its own ad-hoc ``stats()`` dict.
This module is the consolidation layer: a dependency-free metrics
registry (:class:`Counter` / :class:`Gauge` / :class:`Histogram`) with
Prometheus text exposition (format 0.0.4 — what ``GET /metrics`` on the
serving gateway returns), and two bind points:

* **events** — producers expose an ``observer`` callback seam
  (``observer(event, **fields)``): :class:`~repro.api.service.SamplingService`
  emits job/batch/queue/straggler events, a
  :class:`~repro.runtime.transport.WorkerPool` emits spawn/reap/fault/
  dispatch events.  :func:`instrument_service` turns those into counter
  increments and histogram observations.  The producers never import this
  module — the seam is one optional callable, so the runtime layers stay
  dependency-free.
* **snapshots** — current-state numbers (queue depth, admission
  backpressure, live workers, autotuner cache entries) are *collected at
  scrape time* from the stable ``stats()`` schemas, via registry
  collectors — no polling thread, no stale gauges.

Minimal use::

    from repro.obs import MetricsRegistry, instrument_service

    reg = MetricsRegistry()
    instrument_service(svc, reg)        # events + scrape-time gauges
    print(reg.render())                 # Prometheus text exposition
"""
from __future__ import annotations

import math
import re
import threading
from typing import Callable, Iterable, Optional, Sequence

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# histogram default: batch/request latencies from ~1 ms to ~100 s
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0,
                   30.0, 60.0, 120.0)


def _escape_label(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace("\n",
                                                                   r"\n")


def _fmt(v: float) -> str:
    """Prometheus sample value: integers render bare, floats shortest."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


class _Metric:
    """Base: one named metric family with 0+ label dimensions.  Children
    (one per label-value tuple) hold the actual numbers; the unlabelled
    family is its own single child."""

    kind = "untyped"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._children: dict[tuple, "_Metric"] = {}

    def labels(self, *values, **kv) -> "_Metric":
        """The child for one label-value combination (created on first
        use).  Accepts positional values in ``labelnames`` order or
        keywords.  An unlabelled metric is its own child."""
        if kv:
            if values:
                raise ValueError("pass label values positionally OR by "
                                 "keyword, not both")
            values = tuple(kv[ln] for ln in self.labelnames)
        values = tuple(str(v) for v in values)
        if len(values) != len(self.labelnames):
            raise ValueError(f"{self.name}: expected labels "
                             f"{self.labelnames}, got {values}")
        if not self.labelnames:
            return self
        with self._lock:
            child = self._children.get(values)
            if child is None:
                child = self._children[values] = self._make_child()
            return child

    def _make_child(self) -> "_Metric":
        raise NotImplementedError

    def _ensure_unlabelled(self) -> "_Metric":
        if self.labelnames:
            raise ValueError(f"{self.name} has labels {self.labelnames} — "
                             f"use .labels(...)")
        return self

    # -- exposition ----------------------------------------------------------
    def _samples(self) -> Iterable[tuple[str, tuple, float]]:
        """Yield (name-suffix, ((label, value), ...), sample) triples."""
        raise NotImplementedError

    def expose(self) -> str:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for suffix, labels, value in self._samples():
            label_str = ",".join(
                f'{n}="{_escape_label(v)}"' for n, v in labels)
            body = f"{{{label_str}}}" if label_str else ""
            lines.append(f"{self.name}{suffix}{body} {_fmt(value)}")
        return "\n".join(lines)


class Counter(_Metric):
    """Monotonically increasing count (events, bytes, faults)."""

    kind = "counter"

    def __init__(self, name, help, labelnames=()):
        super().__init__(name, help, labelnames)
        self._value = 0.0

    def _make_child(self) -> "Counter":
        return Counter(self.name, self.help)

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        child = self._ensure_unlabelled()
        with child._lock:
            child._value += amount

    @property
    def value(self) -> float:
        child = self._ensure_unlabelled()
        with child._lock:
            return child._value

    # sample emission is driven from the family: an unlabelled family
    # reports its own value, a labelled one walks its children
    def _samples(self):
        if not self.labelnames:
            with self._lock:
                yield "", (), self._value
            return
        with self._lock:
            items = list(self._children.items())
        for values, child in items:
            with child._lock:
                v = child._value
            yield "", tuple(zip(self.labelnames, values)), v


class Gauge(_Metric):
    """Point-in-time value.  ``set_function`` makes it scrape-time lazy."""

    kind = "gauge"

    def __init__(self, name, help, labelnames=()):
        super().__init__(name, help, labelnames)
        self._value = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def _make_child(self) -> "Gauge":
        return Gauge(self.name, self.help)

    def set(self, value: float) -> None:
        child = self._ensure_unlabelled()
        with child._lock:
            child._value = float(value)
            child._fn = None

    def inc(self, amount: float = 1.0) -> None:
        child = self._ensure_unlabelled()
        with child._lock:
            child._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    def set_function(self, fn: Callable[[], float]) -> None:
        """Evaluate ``fn()`` at every scrape instead of storing a value —
        current-state gauges (queue depth, live workers) never go stale."""
        child = self._ensure_unlabelled()
        with child._lock:
            child._fn = fn

    @property
    def value(self) -> float:
        child = self._ensure_unlabelled()
        with child._lock:
            return float(child._fn()) if child._fn is not None \
                else child._value

    def _samples(self):
        if not self.labelnames:
            items = [((), self)]
        else:
            with self._lock:
                items = list(self._children.items())
        for values, child in items:
            with child._lock:
                fn = child._fn
                v = child._value
            if fn is not None:
                try:
                    v = float(fn())
                except Exception:          # noqa: BLE001 — a broken callback
                    continue               # must not take down the scrape
            yield "", tuple(zip(self.labelnames, values)), v


class Histogram(_Metric):
    """Cumulative-bucket histogram (the Prometheus layout: ``_bucket``
    per upper bound incl. +Inf, plus ``_sum`` and ``_count``)."""

    kind = "histogram"

    def __init__(self, name, help, labelnames=(),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self._counts = [0] * (len(self.buckets) + 1)   # +Inf last
        self._sum = 0.0
        self._count = 0

    def _make_child(self) -> "Histogram":
        return Histogram(self.name, self.help, buckets=self.buckets)

    def observe(self, value: float) -> None:
        child = self._ensure_unlabelled()
        with child._lock:
            child._sum += value
            child._count += 1
            for i, b in enumerate(child.buckets):
                if value <= b:
                    child._counts[i] += 1
                    break
            else:
                child._counts[-1] += 1

    @property
    def count(self) -> int:
        child = self._ensure_unlabelled()
        with child._lock:
            return child._count

    @property
    def sum(self) -> float:
        child = self._ensure_unlabelled()
        with child._lock:
            return child._sum

    def _samples(self):
        if not self.labelnames:
            items = [((), self)]
        else:
            with self._lock:
                items = list(self._children.items())
        for values, child in items:
            with child._lock:
                counts = list(child._counts)
                total, s = child._count, child._sum
            labels = tuple(zip(self.labelnames, values))
            cum = 0
            for b, c in zip(child.buckets, counts):
                cum += c
                yield "_bucket", labels + (("le", _fmt(b)),), cum
            yield "_bucket", labels + (("le", "+Inf"),), total
            yield "_sum", labels, s
            yield "_count", labels, total


class MetricsRegistry:
    """A named set of metrics + scrape-time collectors.

    ``counter``/``gauge``/``histogram`` are get-or-create (re-requesting a
    name returns the existing instrument; a *kind* mismatch raises), so
    independent subsystems can share one registry without coordination.
    ``add_collector(fn)`` registers a callable run at the top of every
    :meth:`render` — the hook snapshot-style sources (``service.stats()``,
    the autotuner cache) use to refresh their gauges lazily.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}
        self._collectors: list[Callable[[], None]] = []

    def _get_or_create(self, cls, name, help, labelnames, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if type(m) is not cls or m.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{type(m).__name__}{m.labelnames}")
                return m
            m = cls(name, help, labelnames, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    def add_collector(self, fn: Callable[[], None]) -> None:
        with self._lock:
            self._collectors.append(fn)

    def render(self) -> str:
        """The Prometheus text exposition (format 0.0.4) of every metric,
        collectors run first.  A failing collector is skipped — a scrape
        must never 500 because one subsystem's snapshot raced a close."""
        with self._lock:
            collectors = list(self._collectors)
            metrics = list(self._metrics.values())
        for fn in collectors:
            try:
                fn()
            except Exception:              # noqa: BLE001 — see docstring
                pass
        return "\n".join(m.expose() for m in metrics) + "\n"

    def snapshot(self) -> dict:
        """A plain-dict view (name → {labels-tuple: value}) for tests and
        JSON stats endpoints."""
        self.render()                      # run collectors
        with self._lock:
            metrics = list(self._metrics.values())
        out: dict[str, dict] = {}
        for m in metrics:
            fam: dict = {}
            for suffix, labels, value in m._samples():
                fam[(suffix, labels)] = value
            out[m.name] = fam
        return out


# ---------------------------------------------------------------------------
# instrumentation binders
# ---------------------------------------------------------------------------

class _ServiceObserver:
    """The event half of :func:`instrument_service`: translate
    ``observer(event, **fields)`` emissions from the service / queue /
    transport layers into registry updates."""

    def __init__(self, registry: MetricsRegistry, prefix: str):
        p = prefix
        self.jobs_submitted = registry.counter(
            f"{p}_jobs_submitted_total", "Jobs accepted by submit()")
        self.jobs_finished = registry.counter(
            f"{p}_jobs_finished_total", "Jobs by terminal state", ["state"])
        self.batches = registry.counter(
            f"{p}_batches_total", "Macro batches completed (counted once)")
        self.batch_seconds = registry.histogram(
            f"{p}_batch_seconds", "Wall time of one macro batch")
        self.queue_events = registry.counter(
            f"{p}_queue_events_total",
            "WorkQueue events (claim/requeue/complete/steal)", ["event"])
        self.straggler_steals = registry.counter(
            f"{p}_straggler_steals_total", "Straggler reclaims handed out")
        self.rejected_results = registry.counter(
            f"{p}_rejected_results_total",
            "Late completions discarded by the ownership check")
        self.transport_events = registry.counter(
            f"{p}_transport_events_total",
            "WorkerPool events (spawn/reap/fault/dispatch)", ["event"])
        self.transport_lane_faults = registry.counter(
            f"{p}_transport_lane_faults_total",
            "Transport faults absorbed as lane faults (batch requeued)")
        self.dispatch_bytes = registry.counter(
            f"{p}_transport_dispatch_bytes_total",
            "Serialized job-batch payload bytes dispatched to workers")
        self.walk_io = registry.counter(
            f"{p}_walk_io_bytes_total",
            "Per-walk engine byte counters", ["channel"])
        self.faults = registry.counter(
            f"{p}_faults_total",
            "Faults recorded by the service, by taxonomy kind "
            "(corruption/transport/poison/timeout/resource)", ["kind"])
        self.lane_quarantines = registry.counter(
            f"{p}_lane_quarantines_total",
            "Crash-looping lanes placed on cooldown quarantine")
        self.lane_readmits = registry.counter(
            f"{p}_lane_readmits_total",
            "Quarantined lanes readmitted after cooldown")

    def __call__(self, event: str, **fields) -> None:
        if event == "job_submit":
            self.jobs_submitted.inc()
        elif event == "job_finished":
            self.jobs_finished.labels(state=fields.get("state",
                                                       "unknown")).inc()
        elif event == "batch_done":
            self.batches.inc()
            if "duration_s" in fields:
                self.batch_seconds.observe(fields["duration_s"])
            stats = fields.get("stats") or {}
            for channel in ("io_bytes", "broadcast_send_bytes",
                            "broadcast_recv_bytes", "dispatch_bytes"):
                v = stats.get(channel)
                if v:
                    self.walk_io.labels(channel=channel).inc(float(v))
        elif event.startswith("queue_"):
            self.queue_events.labels(event=event[len("queue_"):]).inc()
        elif event == "steal":
            self.straggler_steals.inc()
        elif event == "rejected_result":
            self.rejected_results.inc()
        elif event == "lane_fault":
            self.transport_lane_faults.inc()
        elif event == "fault":
            self.faults.labels(kind=fields.get("kind", "unknown")).inc()
        elif event == "lane_quarantine":
            self.lane_quarantines.inc()
        elif event == "lane_readmit":
            self.lane_readmits.inc()
        elif event.startswith("transport_"):
            self.transport_events.labels(event=event[len("transport_"):]
                                         ).inc()
            if event == "transport_dispatch" and "nbytes" in fields:
                self.dispatch_bytes.inc(float(fields["nbytes"]))


def instrument_service(service, registry: MetricsRegistry,
                       prefix: str = "fastmps") -> _ServiceObserver:
    """Wire a :class:`~repro.api.service.SamplingService` into ``registry``.

    Two halves (see module docstring): the service's ``observer`` seam is
    bound for events (counters/histograms), and a scrape-time collector
    reads the stable :meth:`SamplingService.stats` schema into gauges —
    queue depth, lane count, job states, admission backpressure, straggler
    and transport totals.  Returns the observer (also installed as
    ``service.observer``) so callers can chain additional sinks.
    """
    obs = _ServiceObserver(registry, prefix)
    service.observer = obs
    pool = getattr(service, "pool", None)
    if pool is not None:
        pool.observer = obs

    p = prefix
    g_jobs = registry.gauge(f"{p}_jobs", "Jobs in the service table by "
                            "state", ["state"])
    g_queue = registry.gauge(f"{p}_queue_depth",
                             "Macro batches not yet completed across "
                             "pending/running jobs")
    g_workers = registry.gauge(f"{p}_workers", "Live service lanes")
    g_sessions = registry.gauge(f"{p}_sessions",
                                "Coalesced sessions owned by the service")
    g_active = registry.gauge(f"{p}_admission_active_model_bytes",
                              "Modeled resident bytes of admitted jobs "
                              "(perfmodel Eq. 3)")
    g_queued = registry.gauge(f"{p}_admission_queued_jobs",
                              "Jobs held PENDING by the admission budget")
    g_bp = registry.gauge(f"{p}_admission_backpressure",
                          "1 when admission control is holding jobs back")
    g_budget = registry.gauge(f"{p}_admission_budget_bytes",
                              "Admission byte budget (0 = unlimited)")
    g_dup = registry.gauge(f"{p}_straggler_duplicates",
                           "Duplicated batches from straggler reclaims")
    g_tworkers = registry.gauge(f"{p}_transport_workers",
                                "Live persistent worker processes")
    g_quar = registry.gauge(f"{p}_quarantined_lanes",
                            "Lanes currently on crash-loop cooldown")
    g_dl = registry.gauge(f"{p}_dead_letters",
                          "Jobs failed by the bounded-retry dead-letter "
                          "policy")

    def collect() -> None:
        st = service.stats()
        for state, n in st["jobs"].items():
            g_jobs.labels(state=state).set(n)
        g_queue.set(st["queue_depth"])
        g_workers.set(st["workers"])
        g_sessions.set(st["sessions"])
        adm = st["admission"]
        g_active.set(adm["active_model_bytes"])
        g_queued.set(adm["queued_jobs"])
        g_bp.set(1.0 if adm["backpressure"] else 0.0)
        g_budget.set(adm["budget_bytes"] or 0)
        g_dup.set(st["stragglers"]["duplicates"])
        g_tworkers.set(st["transport"]["workers"])
        g_quar.set(len(st["transport"].get("quarantined", ())))
        g_dl.set(st.get("dead_letters", 0))

    registry.add_collector(collect)
    return obs


def instrument_dispatch(registry: MetricsRegistry,
                        prefix: str = "fastmps") -> None:
    """Scrape-time gauges over the kernel autotuner cache
    (``kernels/dispatch.autotune_cache_stats``) — entries, hits, misses,
    timed sweeps — so kernel-dispatch behaviour shows up next to the
    serving counters."""
    g = registry.gauge(f"{prefix}_autotune_cache",
                       "Kernel block autotuner cache counters", ["key"])

    def collect() -> None:
        from repro.kernels.dispatch import autotune_cache_stats
        for k, v in autotune_cache_stats().items():
            g.labels(key=k).set(float(v))

    registry.add_collector(collect)
