"""Observability: the unified telemetry subsystem (``repro.obs.metrics``).

Counters, gauges, and histograms with Prometheus text exposition — the one
place the stats scattered across ``SamplingService.stats()``, the kernel
autotuner cache, the transport fault counters, and the per-walk engine I/O
consolidate (served at ``GET /metrics`` by ``repro.serve.gateway``).
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               instrument_dispatch, instrument_service)

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "instrument_dispatch", "instrument_service"]
