"""Streaming multi-level sampling engine (paper §3.1 + §3.3.2 composed).

Every layer of the paper's design runs together here: segment-streamed
chains (GammaStore double-buffered I/O), the jitted scan data plane (one
compilation per segment shape / χ bucket), DP×TP placement with micro
batching, dynamic bond dimensions, mid-chain checkpointing, and the
perfmodel-driven planner.

This is the *streamed backend's machinery* — applications reach it through
:class:`repro.api.SamplingSession`; the ``stream_sample`` convenience
wrapper is deprecated in favour of the facade.
"""
from repro.engine.planner import explain_plan, plan_stream
from repro.engine.streaming import StreamPlan, StreamingEngine, stream_sample

__all__ = ["StreamPlan", "StreamingEngine", "stream_sample",
           "plan_stream", "explain_plan"]
