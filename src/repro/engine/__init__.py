"""Streaming multi-level sampling engine (paper §3.1 + §3.3.2 composed).

Every layer of the paper's design runs together here: segment-streamed
chains (GammaStore double-buffered I/O, or the multihost runtime's
root-reads-then-broadcast), the jitted scan data plane (one compilation per
segment shape / χ bucket), DP×TP placement with micro batching, dynamic
bond dimensions, mid-chain checkpointing, and the perfmodel-driven planner.

This is the *streamed data plane's machinery* — applications reach it
through :class:`repro.api.SamplingSession` (``backend="streamed"``, any
``runtime=``).  The legacy ``stream_sample`` wrapper was removed one
release after the facade shipped, as scheduled.
"""
from repro.engine.planner import explain_plan, plan_stream
from repro.engine.streaming import StreamPlan, StreamingEngine

__all__ = ["StreamPlan", "StreamingEngine", "plan_stream", "explain_plan"]
