"""Streaming multi-level sampling engine (paper §3.1 + §3.3.2 composed).

The first subsystem where every layer of the paper's design runs together:
segment-streamed chains (GammaStore double-buffered I/O), the jitted scan
data plane (one compilation per segment shape), DP×TP placement, mid-chain
checkpointing, and the perfmodel-driven planner.
"""
from repro.engine.planner import explain_plan, plan_stream
from repro.engine.streaming import StreamPlan, StreamingEngine, stream_sample

__all__ = ["StreamPlan", "StreamingEngine", "stream_sample",
           "plan_stream", "explain_plan"]
