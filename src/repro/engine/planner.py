"""Perfmodel-driven streaming plans (paper Eqs. 2, 3, 7 composed).

Maps (:class:`Hardware`, :class:`Workload`, placement) onto a
:class:`StreamPlan`:

* **segment length** — the largest L whose *two* device buffers
  (double-buffering) fit beside the resident macro environment and the
  micro-batch intermediate of Eq. 3, inside the device memory budget;
* **micro batch** — the workload's N₂ (Eq. 3 keeps the unmeasured
  (N₂, χ, d) intermediate bounded) when it actually subdivides N₁;
* **scheme** — DP when only p₁ > 1; within a TP group the Eq. 7 overhead
  selector picks single- vs double-site, exactly as §4.3.

:func:`explain_plan` reports the §3.1 overlap condition (per-site compute
vs Γ read time, and the smallest macro batch that hides I/O) so benches and
drivers can print *why* a plan streams the way it does.
"""
from __future__ import annotations

from typing import Optional

from repro.core import perfmodel as PM
from repro.core.perfmodel import Hardware, Workload
from repro.engine.streaming import StreamPlan


def plan_stream(w: Workload, hw: Hardware, *, n_sites: Optional[int] = None,
                p1: int = 1, p2: int = 1, compute_bytes: int = 4,
                device_budget: Optional[float] = None,
                checkpoint_every: int = 0, safety: float = 0.9) -> StreamPlan:
    """Pick (segment length, N₂, scheme) for a streamed chain walk."""
    M = n_sites if n_sites is not None else w.n_sites
    budget = device_budget if device_budget is not None else hw.mem_capacity
    # all terms are PER-DEVICE: DP shards the batch p₁ ways, TP shards the
    # bond (and therefore Γ and the environment columns) p₂ ways
    n1_local = max(1, w.macro_batch // p1)
    site_bytes = w.chi * (w.chi // p2) * w.d * compute_bytes
    env_bytes = n1_local * (w.chi // p2) * compute_bytes       # Eq. 3 resident
    micro = w.micro_batch if 0 < w.micro_batch < w.macro_batch else None
    # the unmeasured (N₂, χ, d) intermediate spans the FULL bond under every
    # scheme — TP's split-K partial (and its psum result) is (N_local, χ, d),
    # not (N_local, χ/p₂, d)
    inter_bytes = ((micro or w.macro_batch) // p1 * w.chi
                   * w.d * compute_bytes)
    avail = safety * budget - env_bytes - inter_bytes
    if avail < 2 * site_bytes:
        raise ValueError(
            f"budget {budget:.2e} B cannot hold two Γ sites beside the "
            f"N₁={w.macro_batch} environment — shrink the macro batch")
    seg = int(avail // (2 * site_bytes))      # two live buffers at all times
    seg = max(2, min(seg, M))
    seg -= seg % 2                            # even → tp_double composes

    if p2 > 1:
        scheme = "tp_" + PM.choose_tp_scheme(w, hw, p2)
    elif p1 > 1:
        scheme = "dp"
    else:
        scheme = "inmem"
    # N₂ composes with every scheme: under DP/TP the segment runner walks
    # n_local/N₂ chunks per shard (sample_batched key schedule), so the
    # planner's per-shard micro batch must subdivide the local macro batch
    if micro is not None and scheme != "inmem":
        micro_local = micro // p1
        micro = (micro_local if micro % p1 == 0 and micro_local > 0
                 and n1_local % micro_local == 0 else None)
    return StreamPlan(segment_len=seg, scheme=scheme,
                      micro_batch=micro,
                      checkpoint_every=checkpoint_every)


def explain_plan(plan: StreamPlan, w: Workload, hw: Hardware, *,
                 storage_bytes: int = 2, compute_bytes: int = 4,
                 efficiency: float = 0.5) -> dict:
    """The §3.1 overlap accounting behind a plan, as printable numbers."""
    t_comp = PM.t_site_compute(w, hw, w.macro_batch, efficiency)
    t_io = PM.t_gamma_io(w, hw, storage_bytes)
    seg_bytes = plan.segment_len * w.chi * w.chi * w.d * compute_bytes
    return {
        "segment_len": plan.segment_len,
        "scheme": plan.scheme,
        "micro_batch": plan.micro_batch,
        "t_compute_per_site_s": t_comp,
        "t_io_per_site_s": t_io,
        "io_overlapped": t_comp >= t_io,
        "min_macro_batch_for_overlap": PM.min_macro_batch_for_overlap(
            w, hw, efficiency, storage_bytes),
        "segment_bytes": seg_bytes,
        "device_resident_bytes": 2 * seg_bytes + PM.eq3_memory(
            w, compute_bytes),
        # what SamplingService admission control charges this workload
        # (Eq. 3 resident bytes of one live batch + modeled walk seconds)
        "admission": PM.job_admission_cost(w, hw, efficiency=efficiency),
    }
