"""Segment-streamed MPS sampling with compute/I-O overlap (paper §3.1, §3.3.2).

The in-memory sampler requires the entire stacked Γ as a device operand —
at 8,176 sites and χ=10⁴ that is impossible.  This engine splits the chain
into fixed-size site *segments* and, while the jitted scan contracts
segment k, a background thread reads segment k+1 from :class:`GammaStore`
(bf16 on disk → fp32 upcast) and starts its host→device transfer
(``device_put`` is asynchronous), so Γ I/O is hidden behind compute exactly
as in the paper's data-parallel revival.  At most **two** segments are ever
device-resident (current + next); consumed buffers are explicitly deleted.
On a multi-process :class:`~repro.api.runtime.ClusterRuntime`, the same
prefetch slot carries the paper's §3.1 collective instead: only the ROOT
process reads the store and broadcasts each segment in storage format —
see ``_fetch_via_runtime``.

Every level of the framework composes behind :meth:`StreamingEngine.sample`:

* ``inmem`` scheme — the single-process ``core/sampler`` scan; bit-identical
  to ``sampler.sample`` for the same seed (``micro_batch=None``) or to
  ``sampler.sample_batched`` (``micro_batch=N₂``).
* ``dp`` / ``tp_single`` / ``tp_double`` — the ``core/parallel`` segment
  runner (micro batching N₂ included, and the per-sample ``log_scale``
  diagnostic carried); bit-identical to the corresponding whole-chain
  segment-runner schedule (``parallel._multilevel_sample``).
* dynamic bond dimensions (§3.4.2): a bucketed per-site ``chi_profile``
  splits the walk into χ-stages; segments never cross a stage boundary and
  every segment of a bucket pads to one shape, so a staged chain costs one
  jit compilation *per bucket* (not per chain position).  Bit-identical to
  ``dynamic_bond.sample_staged`` for the inmem scheme.
* per-segment checkpointing through ``checkpoint/sampler_state`` — a killed
  run resumes mid-chain and emits bit-identical samples (paper §4.1).
* macro batches (paper N₁) as idempotent :class:`WorkQueue` work items —
  :meth:`StreamingEngine.run_queue`.

All same-shape segments run through ONE jit compilation: ``start_site`` is a
traced operand, and segment tails are padded to the segment length with
*identity sites* (Γ = I on outcome 0, Λ = 1) whose draws are discarded — an
identity site leaves the environment, its rescale factors, and every real
site's PRNG stream untouched.

Applications should reach this engine through
:class:`repro.api.SamplingSession` (backend ``"streamed"``).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.runtime import ClusterRuntime, LocalRuntime
from repro.checkpoint.sampler_state import (load_sampler_state,
                                            newest_checkpoint_site,
                                            save_sampler_state)
from repro.core import parallel as PP
from repro.core import sampler as S
from repro.core.mps import MPS
from repro.core.precision import real_dtype_of
from repro.data import gamma_store as GS
from repro.runtime.faults import CorruptSegment, Fault


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """How to walk the chain.  Produced by ``engine.planner.plan_stream``."""
    segment_len: int                    # sites per device-resident segment
    scheme: str = "inmem"               # "inmem" | "dp" | "tp_single" | "tp_double"
    micro_batch: Optional[int] = None   # N₂; composes with EVERY scheme
    checkpoint_every: int = 0           # segments between checkpoints; 0 = off


def identity_sites(n: int, chi: int, d: int, dtype) -> tuple[np.ndarray,
                                                             np.ndarray]:
    """n pad sites that are exact no-ops for the chain walk: Γ[l,r,s] =
    δ_lr·δ_s0 keeps the environment fixed and puts all probability mass on
    outcome 0; Λ = 1 keeps born-semantics collapse factors at unity."""
    g = np.zeros((n, chi, chi, d), dtype=dtype)
    g[:, :, :, 0] = np.eye(chi)
    lam = np.ones((n, chi), dtype=np.zeros(1, dtype).real.dtype)
    return g, lam


@partial(jax.jit, static_argnames=("config", "n_micro"))
def _micro_segment(mps: MPS, env, log_scale, base_key, start_site,
                   config: S.SamplerConfig, n_micro: int):
    """One segment under §3.1 micro-batching: chunk c carries key
    split(base, n_micro)[c] for the whole chain, matching
    ``sampler.sample_batched`` draw-for-draw."""
    n, chi = env.shape
    n2 = n // n_micro
    keys = jax.random.split(base_key, n_micro)

    def one(xs):
        k, e, ls = xs
        res = S.sample_chain(mps, S.SamplerState(e, k, ls), config,
                             start_site=start_site)
        return res.samples, res.state.env, res.state.log_scale

    samples, env2, ls2 = jax.lax.map(
        one, (keys, env.reshape(n_micro, n2, chi),
              log_scale.reshape(n_micro, n2)))
    samples = jnp.transpose(samples, (1, 0, 2)).reshape(-1, n)  # (L, N)
    return samples, env2.reshape(n, chi), ls2.reshape(n)


class StreamingEngine:
    """Drives a chain stored in a :class:`GammaStore` through any DP×TP
    placement, never holding more than two Γ segments on device."""

    def __init__(self, store, *, semantics: str = "linear",
                 config: S.SamplerConfig = S.SamplerConfig(),
                 plan: StreamPlan = StreamPlan(segment_len=64),
                 mesh=None, pconfig: Optional[PP.ParallelConfig] = None,
                 checkpoint_dir: Optional[str] = None,
                 chi_profile=None,
                 runtime: Optional[ClusterRuntime] = None,
                 shard=None, clamp=None):
        from repro.workloads.clamp import clamp_map
        # conditional sampling (repro.workloads): a normalized clamp spec
        # forces outcomes at a subset of sites; per-segment (mask, vals)
        # operands are built on the fly in _run_segment_clamped and the
        # walk carries a per-sample log_prob alongside log_scale, surfaced
        # through stats["log_prob"].  None = unclamped (unchanged paths).
        self.clamp_map = clamp_map(clamp)
        self.store = store
        self._source_store = store
        self._wrapped_store = None
        # where this engine's process lives and how Γ bytes reach it: on a
        # LocalRuntime every segment is a store read; on a multi-process
        # runtime only the ROOT touches the store and everyone else receives
        # the broadcast (paper §3.1) — see _fetch.  A `shard` map
        # (repro.shard.ShardMap) switches the multi-process plane from
        # broadcast to block-cyclic ownership: every process reads ONLY its
        # owned slice and the walk pipelines the (N, χ) env host-to-host
        # (ROADMAP item 3) — see _sample_sharded
        self.runtime = runtime or LocalRuntime()
        self.shard = shard
        self.n_sites = store.n_sites
        if self.n_sites == 0:
            raise ValueError(f"empty GammaStore at {store.root}")
        if shard is not None:
            from repro.shard.store import ShardedGammaStore
            if shard.n_sites != self.n_sites:
                raise ValueError(f"shard map covers {shard.n_sites} sites, "
                                 f"store holds {self.n_sites}")
            if shard.n_hosts != self.runtime.process_count:
                raise ValueError(
                    f"shard map spans {shard.n_hosts} hosts but the runtime "
                    f"has {self.runtime.process_count} processes")
            if shard.n_hosts > 1 and not isinstance(store, ShardedGammaStore):
                # shared-root deployment: wrap the caller's plain store in
                # this host's ownership-enforcing view (engine-owned; the
                # caller's store object stays untouched and shared)
                self.store = ShardedGammaStore(
                    store.root, shard, self.runtime.process_index,
                    storage_dtype=store.storage_dtype,
                    compute_dtype=store.compute_dtype, verify=True)
                self._wrapped_store = self.store
        # verified Γ I/O is ON by default whenever bytes cross process
        # boundaries (broadcast or sharded): a flipped bit must surface as
        # a structured CorruptSegment before any sample is emitted.  A
        # single-process run keeps the caller's choice — structural
        # corruption (a torn npz) is caught on every read regardless.
        if self.runtime.process_count > 1:
            self.store.verify = True
        shape = self.store.meta(0)        # header-only: no Γ payload read
        self.chi, self.d = shape[0], shape[2]
        self.gamma_dtype = np.dtype(self.store.compute_dtype)
        self.semantics = semantics
        self.config = config
        self.plan = plan
        if plan.scheme != "inmem" and mesh is None:
            raise ValueError(f"scheme {plan.scheme!r} needs a mesh")
        self.mesh = mesh
        self.pconfig = pconfig or PP.ParallelConfig(scheme=plan.scheme)
        if plan.scheme != "inmem" and plan.micro_batch is not None:
            # §3.1 micro batching composes with the DP/TP schemes through the
            # segment runner (N₂ per data shard, sample_batched key schedule)
            self.pconfig = dataclasses.replace(self.pconfig,
                                               micro_batch=plan.micro_batch)
        self.chi_profile = (None if chi_profile is None
                            else np.asarray(chi_profile, dtype=np.int64))
        if self.chi_profile is not None:
            if len(self.chi_profile) != self.n_sites:
                raise ValueError(f"chi_profile covers "
                                 f"{len(self.chi_profile)} of "
                                 f"{self.n_sites} sites")
            if int(self.chi_profile.max()) > self.chi:
                raise ValueError("chi_profile exceeds the stored χ "
                                 f"({int(self.chi_profile.max())} > {self.chi})")
        self.checkpoint_dir = checkpoint_dir
        if checkpoint_dir:
            os.makedirs(checkpoint_dir, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._live_lock = threading.Lock()
        self._live = 0
        # one walk at a time: the engine is cached per plan by the session
        # and service lanes may hand it consecutive macro batches
        self._walk_lock = threading.Lock()
        # gang-scheduling slot: ((start, stop, χ), Future) for the NEXT
        # walk's first segment, fetched behind this walk's tail compute
        self._warm: Optional[tuple] = None
        # store I/O is counted relative to engine creation so a shared
        # (session-owned) store can serve many engines without the hidden-
        # I/O ratio mixing scopes (self.store: the sharded view when one
        # was wrapped — its counters see owned traffic only)
        self._store_io0 = (self.store.io_seconds, self.store.io_bytes)
        # runtime counters are scoped the same way: deltas since engine
        # creation, so shared runtimes serve many engines cleanly
        self._runtime_io0 = dict(self.runtime.io_counters())
        self._store_q0 = (self.store.quarantined_sites,
                          self.store.repaired_sites)
        self.stats = {"segments": 0, "io_wait_s": 0.0, "compute_s": 0.0,
                      "max_live_segments": 0, "store_io_s": 0.0,
                      "io_bytes": 0, "io_hidden_frac": 0.0,
                      "owned_segments": 0, "handoffs": 0,
                      "handoff_send_bytes": 0, "handoff_recv_bytes": 0,
                      "gather_bytes": 0, "quarantined_sites": 0,
                      "repaired_sites": 0}
        for k in self._runtime_io0:
            self.stats[k] = 0
        # the shard algebra must hold for the REAL schedule (χ-stages can
        # split blocks in ways plan-time uniform checks miss): every
        # scheduled segment needs exactly one owner, checked here once
        self._seg_owners = (None if self.shard is None else
                            tuple(self.shard.segment_owner(s, e)
                                  for s, e, _ in self._segment_schedule()))

    # -- chain schedule ------------------------------------------------------
    def _segment_schedule(self) -> list[tuple[int, int, int]]:
        """[(start, stop, χ_stage)] — ``plan.segment_len``-sized chunks that
        never cross a χ-stage boundary.  With no profile this is the uniform
        fixed-χ split; with one, each §3.4.2 bucket walks its own segments
        (every segment of a bucket is padded to the same length, so a
        dynamic-χ chain costs ONE jit compilation per bucket)."""
        from repro.core import dynamic_bond as DB
        from repro.shard.shardmap import chain_segments

        if self.chi_profile is None:
            stages = [(0, self.n_sites, self.chi)]
        else:
            stages = [(st.start, st.stop, st.chi)
                      for st in DB.stages_from_profile(self.chi_profile)]
        for s0, s1, _ in stages:
            if self.pconfig.scheme == "tp_double" and (s0 % 2 or s1 % 2):
                raise ValueError(
                    "tp_double pairs sites (2j, 2j+1): χ-stage boundaries "
                    f"must be even (got stage [{s0}, {s1}))")
        # the chunking itself is shared with the planner's shard validation
        # (shardmap.chain_segments) so "every segment has one owner" is
        # proved against the very schedule this engine walks
        return chain_segments(self.n_sites, self.plan.segment_len, stages)

    # -- segment fetch (runs on the pool thread) ----------------------------
    def _fetch_via_runtime(self, start: int,
                           stop: int) -> tuple[np.ndarray, np.ndarray]:
        """Paper §3.1: process 0 reads the segment once and broadcasts it.

        Only the root runtime instance ever touches the GammaStore payload;
        the wire carries the store's *storage format* (bf16-packed when the
        store is bf16 — half the interconnect bytes), and every process —
        root included — decodes through ``gamma_store.decode_segment``, so
        the walk stays bit-identical to a LocalRuntime one.  Running on the
        prefetch pool thread, the broadcast of segment k+1 overlaps the
        contraction of segment k exactly like the local read does."""
        payload = None
        if self.runtime.is_root:
            try:
                payload = self.store.get_segment_raw(start, stop - start)
            except CorruptSegment as e:
                # the fault must cross the wire too: a root that raised
                # while its peers block in the collective would hang the
                # cluster — instead EVERY process receives the error frame
                # and fails this round with the same structured fault
                payload = {"start": start, "error": str(e),
                           "fault": e.fault.to_dict()}
        payload = self.runtime.broadcast_segment(payload)
        if payload.get("error") is not None:
            fd = dict(payload.get("fault") or {})
            raise CorruptSegment(Fault(
                kind=fd.get("kind", "corruption"),
                message=fd.get("message", str(payload["error"])),
                site=fd.get("site"), store=fd.get("store")))
        if payload["start"] != start:
            # a real error, not an assert: schedule desync across processes
            # must never silently sample the wrong segment (python -O)
            raise RuntimeError(
                f"broadcast schedule desync: this process expected segment "
                f"start {start} but received {payload['start']} — are all "
                f"processes walking the same plan?")
        return GS.decode_segment(payload, compute_dtype=self.gamma_dtype)

    def _fetch(self, start: int, stop: int,
               chi_s: int) -> tuple[jax.Array, jax.Array, int]:
        L = self.plan.segment_len
        if self.shard is not None:
            # sharded plane: Γ NEVER crosses the interconnect — the owner
            # reads its own slice locally (multi-process included); the
            # walk loop schedules the next OWNED segment itself, so the
            # blanket next-segment prefetch stays off
            g, lam = self.store.get_segment(start, stop - start,
                                            prefetch_next_segment=False)
        elif self.runtime.process_count > 1:
            g, lam = self._fetch_via_runtime(start, stop)
        else:
            g, lam = self.store.get_segment(start, stop - start,
                                            prefetch_next_segment=True)
        if chi_s < self.chi:              # §3.4.2: only the bucketed bond
            g = g[:, :chi_s, :chi_s, :]
            lam = lam[:, :chi_s]
        real = g.shape[0]
        if real < L:                      # tail: pad with identity sites
            gp, lp = identity_sites(L - real, chi_s, self.d, g.dtype)
            g = np.concatenate([g, gp], axis=0)
            lam = np.concatenate([lam, lp.astype(lam.dtype)], axis=0)
        gd, ld = jax.device_put(g), jax.device_put(lam)    # async transfer
        with self._live_lock:
            self._live += 1
            self.stats["max_live_segments"] = max(
                self.stats["max_live_segments"], self._live)
        return gd, ld, real

    def _release(self, gd: jax.Array, ld: jax.Array) -> None:
        gd.delete()
        ld.delete()
        with self._live_lock:
            self._live -= 1

    # -- one segment of the data plane --------------------------------------
    def _run_segment(self, seg: MPS, env, log_scale, key, start: int):
        if self.plan.scheme == "inmem":
            if self.plan.micro_batch is not None:
                n_micro = env.shape[0] // self.plan.micro_batch
                return _micro_segment(seg, env, log_scale, key, start,
                                      self.config, n_micro)
            res = S.sample_chain(seg, S.SamplerState(env, key, log_scale),
                                 self.config, start_site=start)
            return res.samples, res.state.env, res.state.log_scale
        return PP.sample_segment(self.mesh, seg, env, key, start,
                                 self.pconfig, self.config,
                                 log_scale=log_scale)

    def _run_segment_clamped(self, seg: MPS, env, log_scale, log_prob, key,
                             start: int):
        """Clamped twin of ``_run_segment``: routes through the
        ``core.clamped`` walks with per-segment (mask, vals) built from the
        clamp spec.  Identity pad sites past the chain end are unclamped by
        construction, so they stay exact no-ops (outcome 0, zero weight).
        Returns ``(samples, env', log_scale', log_prob')``."""
        from repro.core import clamped as CL
        from repro.workloads.clamp import segment_clamp_arrays

        n = env.shape[0]
        mask, vals = segment_clamp_arrays(self.clamp_map, start,
                                          seg.n_sites, n)
        if self.plan.scheme == "inmem":
            return CL.clamped_segment(
                seg.gammas, seg.lambdas, env, key, start, mask, vals,
                self.config, log_scale=log_scale, log_prob=log_prob,
                micro_batch=self.plan.micro_batch)
        # tp schemes run the clamped dp walk over the non-model axes
        # (every schedule draws the same randoms per seed — §4.1)
        return CL.sample_segment_clamped(
            self.mesh, seg, env, key, start, mask, vals,
            CL.dp_equivalent_pconfig(self.pconfig), self.config,
            log_scale=log_scale, log_prob=log_prob)

    def _load_sample_blocks(self, up_to_site: int,
                            ckpt_dir: str) -> list[np.ndarray]:
        """Read back the per-segment sample blocks covering [0, up_to_site)."""
        blocks, cursor = [], 0
        names = sorted(f for f in os.listdir(ckpt_dir)
                       if f.startswith("samples_") and f.endswith(".npy"))
        for fn in names:
            offset = int(fn[len("samples_"):-len(".npy")])
            if offset >= up_to_site:
                break
            assert offset == cursor, (offset, cursor)   # contiguous prefix
            blk = np.load(os.path.join(ckpt_dir, fn))
            blocks.append(blk)
            cursor += blk.shape[0]
        assert cursor == up_to_site, (cursor, up_to_site)
        return blocks

    # -- per-walk bookkeeping ------------------------------------------------
    def _begin_walk(self) -> None:
        """Re-anchor the I/O deltas and zero the per-walk stats: a cached
        engine serves many macro batches, but ``stats`` always describes
        the most recent walk (the pre-cache contract)."""
        self._store_io0 = (self.store.io_seconds, self.store.io_bytes)
        self._store_q0 = (self.store.quarantined_sites,
                          self.store.repaired_sites)
        self._runtime_io0 = dict(self.runtime.io_counters())
        with self._live_lock:
            live = self._live           # a warm prefetched segment counts
        self.stats.update(segments=0, io_wait_s=0.0, compute_s=0.0,
                          max_live_segments=live, store_io_s=0.0,
                          io_bytes=0, io_hidden_frac=0.0,
                          owned_segments=0, handoffs=0,
                          handoff_send_bytes=0, handoff_recv_bytes=0,
                          gather_bytes=0, quarantined_sites=0,
                          repaired_sites=0)
        for k in self._runtime_io0:
            self.stats[k] = 0
        self.stats.pop("log_prob", None)   # set per walk, clamped only

    def _take_warm(self, seg_key) -> Optional[Future]:
        """Claim the gang-scheduled first-segment fetch if it matches this
        walk's opening segment; release a stale one."""
        if self._warm is None:
            return None
        key, fut = self._warm
        self._warm = None
        if key == seg_key:
            return fut
        try:
            gd, ld, _ = fut.result()    # schedule changed (e.g. resume):
            self._release(gd, ld)       # drop the stale buffers
        except Exception:
            # a failed SPECULATIVE fetch must not fail a walk that never
            # needed it (the matched case above surfaces its error when
            # the walk consumes the future — that data was required)
            pass
        return None

    # -- driver --------------------------------------------------------------
    _UNSET = object()

    def sample(self, n_samples: int, key: jax.Array, *, resume: bool = False,
               stop_after_segments: Optional[int] = None,
               checkpoint_dir=_UNSET, pipeline: bool = False) -> np.ndarray:
        """Walk the whole chain; returns (N, M) int32 outcomes.

        ``resume=True`` continues from the newest checkpoint (bit-identical
        to the uninterrupted run); ``checkpoint_dir`` overrides the
        engine's per walk (a cached engine serves many macro batches, each
        with its own checkpoint subdirectory); ``stop_after_segments``
        simulates a mid-run kill for tests — the engine checkpoints the
        boundary state and returns the partial (N, sites_done) block.
        ``pipeline=True`` gang-schedules across walks: once this walk's
        last segment is fetched, the prefetch pool immediately fetches (or,
        multi-process, broadcasts) the *first* segment again, so the next
        macro batch's Γ I/O hides behind this batch's tail compute.
        """
        return self.sample_with_stats(
            n_samples, key, resume=resume,
            stop_after_segments=stop_after_segments,
            checkpoint_dir=checkpoint_dir, pipeline=pipeline)[0]

    def sample_with_stats(self, n_samples: int, key: jax.Array, *,
                          resume: bool = False,
                          stop_after_segments: Optional[int] = None,
                          checkpoint_dir=_UNSET, pipeline: bool = False
                          ) -> tuple[np.ndarray, dict]:
        """:meth:`sample` plus a stats snapshot taken under the walk lock —
        on a shared (session-cached) engine, reading ``self.stats`` after
        the lock drops races the next walk's reset."""
        with self._walk_lock:
            out = self._sample_locked(n_samples, key, resume=resume,
                                      stop_after_segments=stop_after_segments,
                                      checkpoint_dir=checkpoint_dir,
                                      pipeline=pipeline)
            return out, dict(self.stats)

    def _sample_locked(self, n_samples: int, key: jax.Array, *,
                       resume: bool, stop_after_segments: Optional[int],
                       checkpoint_dir, pipeline: bool) -> np.ndarray:
        from repro.core.dynamic_bond import fit_env

        ckpt_dir = (self.checkpoint_dir if checkpoint_dir is self._UNSET
                    else checkpoint_dir)
        if self.clamp_map is not None and (resume or ckpt_dir):
            # the checkpoint unit is SamplerState(env, key, log_scale) —
            # it has no log_prob slot, so a resumed clamped walk would
            # silently drop the conditional weights accumulated before the
            # kill.  Refuse loudly; clamped macro batches are idempotent
            # work items (run_queue) — rerun the batch instead.
            raise ValueError(
                "clamped walks do not checkpoint or resume (the sampler "
                "state has no log_prob slot) — drop checkpoint_dir/resume "
                "and rely on idempotent macro batches")
        if ckpt_dir:
            os.makedirs(ckpt_dir, exist_ok=True)
        if self.shard is not None and self.runtime.process_count > 1:
            return self._sample_sharded(n_samples, key, resume=resume,
                                        stop_after_segments=stop_after_segments,
                                        ckpt_dir=ckpt_dir, pipeline=pipeline)
        self._begin_walk()

        M_sites = self.n_sites
        if self.plan.micro_batch is not None:
            assert n_samples % self.plan.micro_batch == 0, \
                (n_samples, self.plan.micro_batch)
        if self.runtime.process_count > 1 and stop_after_segments is not None:
            raise ValueError(
                "stop_after_segments injects a single-process kill — "
                "on a multi-process runtime the peers would block on "
                "the broadcast")

        schedule = self._segment_schedule()
        boundaries = {s for s, _, _ in schedule} | {M_sites}
        idx = 0
        done: list[np.ndarray] = []       # site-major (L_i, N) blocks
        persisted = 0                     # blocks already written to disk
        env = PP.segment_env_init(n_samples, schedule[0][2], self.gamma_dtype)
        log_scale = jnp.zeros((n_samples,),
                              dtype=real_dtype_of(env.dtype))
        log_prob = (jnp.zeros((n_samples,), dtype=real_dtype_of(env.dtype))
                    if self.clamp_map is not None else None)
        if resume:
            if not ckpt_dir:
                raise ValueError("resume=True needs a checkpoint_dir")
            if self.runtime.process_count > 1:
                # cluster-synchronized resume: after an unclean stop the
                # processes' newest durable boundaries can differ, and
                # resuming from unequal indices would desync the broadcast
                # schedule.  Agree on min(newest) — the newest boundary
                # EVERY process holds (keep=0 checkpoints, see
                # newest_checkpoint_site) — and walk from there in
                # lockstep; 0 means someone lost everything: start fresh.
                agreed = self.runtime.allreduce_min(
                    newest_checkpoint_site(ckpt_dir))
                loaded = (load_sampler_state(ckpt_dir, site=agreed)
                          if agreed > 0 else None)
            else:
                loaded = load_sampler_state(ckpt_dir)
            if loaded is not None:
                site, state, _ = loaded
                # the engine only checkpoints segment boundaries (or end)
                assert site in boundaries, (site, sorted(boundaries))
                # a mismatched key would silently produce a chimera batch
                # (prefix from the checkpoint's seed, suffix from the
                # caller's)
                assert jnp.array_equal(jax.random.key_data(key),
                                       jax.random.key_data(state.key)), \
                    "resume key does not match the checkpointed run"
                env, key, log_scale = state.env, state.key, state.log_scale
                idx = next((i for i, (s, _, _) in enumerate(schedule)
                            if s == site), len(schedule))
                done = self._load_sample_blocks(site, ckpt_dir)
                persisted = len(done)

        if idx >= len(schedule):          # resumed from a finished run
            self._finish_walk()
            return np.concatenate(done, axis=0).T.astype(np.int32)

        fut: Optional[Future] = self._take_warm(schedule[idx])
        if fut is None:
            fut = self._pool.submit(self._fetch, *schedule[idx])
        seg_idx = 0
        while idx < len(schedule):
            start, _, chi_s = schedule[idx]
            t0 = time.perf_counter()
            gd, ld, real = fut.result()
            self.stats["io_wait_s"] += time.perf_counter() - t0
            if idx + 1 < len(schedule):   # double buffer: fetch k+1 now
                fut = self._pool.submit(self._fetch, *schedule[idx + 1])
            elif pipeline and stop_after_segments is None:
                # gang-scheduling (paper §3.1 across macro batches): the
                # pool is idle for the rest of this walk, so fetch — or on a
                # multi-process runtime, broadcast — the next batch's FIRST
                # segment now, behind this batch's tail compute
                self._warm = (schedule[0],
                              self._pool.submit(self._fetch, *schedule[0]))

            t0 = time.perf_counter()
            # the lock is a no-op except on the emulated cluster, where the
            # member "processes" share one XLA backend and concurrent
            # collective programs would interleave their rendezvous and
            # deadlock (block_until_ready stays inside: dispatch is async)
            with self.runtime.compute_lock():
                seg = MPS(gd, ld, self.semantics)
                env = fit_env(env, chi_s)  # χ-stage transition (no-op within)
                if self.clamp_map is None:
                    samples, env, log_scale = self._run_segment(
                        seg, env, log_scale, key, start)
                else:
                    samples, env, log_scale, log_prob = \
                        self._run_segment_clamped(seg, env, log_scale,
                                                  log_prob, key, start)
                samples = np.asarray(samples[:real])  # drop identity pads
                jax.block_until_ready((env, log_scale))
            self.stats["compute_s"] += time.perf_counter() - t0
            self._release(gd, ld)
            done.append(samples)
            self.stats["segments"] += 1
            idx += 1
            seg_idx += 1
            site_done = start + real

            stopping = (stop_after_segments is not None
                        and seg_idx >= stop_after_segments
                        and idx < len(schedule))
            ckpt_due = (self.plan.checkpoint_every
                        and seg_idx % self.plan.checkpoint_every == 0)
            if ckpt_dir and (ckpt_due or stopping):
                # samples live in per-segment block files written exactly
                # once each — re-serializing the cumulative history every
                # segment would make total checkpoint I/O quadratic in M
                site_cursor = site_done - sum(b.shape[0]
                                              for b in done[persisted:])
                for blk in done[persisted:]:
                    np.save(os.path.join(ckpt_dir,
                                         f"samples_{site_cursor:06d}.npy"),
                            blk)
                    site_cursor += blk.shape[0]
                persisted = len(done)
                # multi-process walks keep the FULL boundary history
                # (keep=0): the cluster-min resume agreement must be able
                # to load any boundary a slower process is still at
                save_sampler_state(
                    ckpt_dir, site_done,
                    S.SamplerState(env, key, log_scale),
                    np.zeros((0, n_samples), dtype=np.int32),
                    keep=0 if self.runtime.process_count > 1 else 3)
            if stopping:
                if idx < len(schedule):   # drain the prefetch we no longer
                    gd, ld, _ = fut.result()   # need, or its buffers leak and
                    self._release(gd, ld)      # the ≤2-live bound breaks
                break

        if self.clamp_map is not None:
            self.stats["log_prob"] = np.asarray(log_prob)
        self._finish_walk()
        return np.concatenate(done, axis=0).T.astype(np.int32)

    def _verify_and_repair_sharded(self, me: int) -> None:
        """Pre-walk self-healing round (sharded plane): every host verifies
        its OWNED slice against the digest manifest, the union of corrupt
        sites is allgathered, and each corrupt site is re-materialized from
        the lowest-ranked peer holding a healthy copy over the existing
        tagged ``send``/``recv`` — block-cyclic replication (Adamski &
        Brown) means a peer often holds the very bytes a rotted slice
        needs.  With no healthy holder anywhere, EVERY process raises
        :class:`CorruptSegment` in the same round, so the collectives stay
        aligned and the job fails with a kind=corruption fault instead of
        hanging or sampling garbage."""
        if not getattr(self.store, "verify", False):
            return
        mine = self.store.verify_sites()
        rounds = self.runtime.allgather_payloads(
            {"corrupt": np.asarray(sorted(mine), dtype=np.int64)})
        bad = sorted({int(s) for pay in rounds
                      for s in np.asarray(pay["corrupt"]).ravel()})
        for site in bad:
            owner = self.shard.owner(site)
            healthy = int(me != owner and self.store.has_healthy_copy(site))
            votes = self.runtime.allgather_payloads(
                {"healthy": np.asarray([healthy], dtype=np.int64)})
            helpers = [r for r, pay in enumerate(votes)
                       if int(np.asarray(pay["healthy"]).ravel()[0])]
            if not helpers:
                raise CorruptSegment(Fault(
                    kind="corruption", site=site, store=self.store.root,
                    message=f"Γ site {site} (owner host {owner}) is corrupt "
                            f"and no peer holds a healthy copy — "
                            f"unrepairable; failing the job cleanly"))
            helper, tag = helpers[0], ("repair", site)
            if me == helper:
                data = self.store.read_repair_bytes(site)
                self.runtime.send(owner, {
                    "site": np.asarray(site, dtype=np.int64),
                    "data": np.frombuffer(data, dtype=np.uint8)}, tag=tag)
            elif me == owner:
                pay = self.runtime.recv(helper, tag=tag)
                if int(np.asarray(pay["site"])) != site:
                    raise RuntimeError(
                        f"repair desync: host {me} expected bytes for site "
                        f"{site} but received site "
                        f"{int(np.asarray(pay['site']))}")
                self.store.restore_site(
                    site, np.asarray(pay["data"], dtype=np.uint8).tobytes())
            else:
                self.runtime.observe_handoff(helper, tag=tag)

    def _sample_sharded(self, n_samples: int, key: jax.Array, *,
                        resume: bool, stop_after_segments: Optional[int],
                        ckpt_dir, pipeline: bool) -> np.ndarray:
        """Block-cyclic sharded walk (ROADMAP item 3, Adamski & Brown).

        Every process iterates the same segment schedule, but segment k's
        sites are contracted only by ``shard.segment_owner(k)``; at each
        ownership boundary the tiny (N, χ) environment — never Γ — crosses
        the wire (``runtime.send/recv``), and the next owner's Γ prefetch
        for its OWN slice runs behind the predecessor's compute, exactly as
        the broadcast plane overlaps its collective.  The walk ends with a
        barrier and one sample-block all-gather so every process returns
        the identical (N, M) batch: wire traffic is O(chain) env handoffs
        plus one outcome gather, not O(hosts × chain) Γ broadcast bytes.

        Crash consistency (the SIGKILL chaos test's contract): an owner
        persists a RECEIVED boundary before computing from it, and each
        computed block + post-compute boundary immediately after the
        compute — both with ``keep=0`` — so the cluster-min agreed site is
        always durable exactly where the resume needs it, with every owned
        block below it on disk.
        """
        from repro.core.dynamic_bond import fit_env
        from repro.shard import walk as SW

        if stop_after_segments is not None:
            raise ValueError(
                "stop_after_segments injects a single-process kill — on a "
                "sharded runtime the peers would block on the env handoff")
        self._begin_walk()
        if self.plan.micro_batch is not None:
            assert n_samples % self.plan.micro_batch == 0, \
                (n_samples, self.plan.micro_batch)

        schedule = self._segment_schedule()
        owners = list(self._seg_owners)
        me = self.runtime.process_index
        self._verify_and_repair_sharded(me)
        base_key_data = np.asarray(jax.random.key_data(key))

        idx0 = 0
        blocks: dict[int, np.ndarray] = {}     # start site → (L, N) block
        env = PP.segment_env_init(n_samples, schedule[0][2], self.gamma_dtype)
        log_scale = jnp.zeros((n_samples,), dtype=real_dtype_of(env.dtype))
        log_prob = (jnp.zeros((n_samples,), dtype=real_dtype_of(env.dtype))
                    if self.clamp_map is not None else None)

        if resume:
            if not ckpt_dir:
                raise ValueError("resume=True needs a checkpoint_dir")
            agreed = self.runtime.allreduce_min(
                newest_checkpoint_site(ckpt_dir))
            if agreed > 0:
                boundaries = {s for s, _, _ in schedule} | {self.n_sites}
                assert agreed in boundaries, (agreed, sorted(boundaries))
                idx0 = next((i for i, (s, _, _) in enumerate(schedule)
                             if s == agreed), len(schedule))
                for i in range(idx0):          # my durable blocks < agreed
                    if owners[i] == me:
                        s0 = schedule[i][0]
                        blocks[s0] = np.load(os.path.join(
                            ckpt_dir, f"samples_{s0:06d}.npy"))
                if idx0 < len(schedule) and owners[idx0] == me:
                    site, state, _ = load_sampler_state(ckpt_dir,
                                                        site=agreed)
                    assert jnp.array_equal(jax.random.key_data(key),
                                           jax.random.key_data(state.key)), \
                        "resume key does not match the checkpointed run"
                    env, key, log_scale = (state.env, state.key,
                                           state.log_scale)

        owned = [i for i in range(idx0, len(schedule)) if owners[i] == me]
        self.stats["owned_segments"] = len(owned)
        fut: Optional[Future] = None
        if owned:
            fut = self._take_warm(schedule[owned[0]])
            if fut is None:
                fut = self._pool.submit(self._fetch, *schedule[owned[0]])
        next_pos = 1                      # next entry of `owned` to prefetch

        for idx in range(idx0, len(schedule)):
            start, _, chi_s = schedule[idx]
            prev_owner = owners[idx - 1] if idx > idx0 else None
            incoming = prev_owner is not None and prev_owner != owners[idx]
            if owners[idx] != me:
                if incoming and prev_owner != me:
                    # neither endpoint: collective-backed transports still
                    # need this process in the transfer (no-op in-process)
                    self.runtime.observe_handoff(prev_owner, tag=start)
                continue

            if incoming:                  # I take over: receive the env
                t0 = time.perf_counter()
                payload = self.runtime.recv(prev_owner, tag=start)
                self.stats["io_wait_s"] += time.perf_counter() - t0
                env_h, ls_h, key_data, site = SW.decode_handoff(payload)
                if site != start:
                    raise RuntimeError(
                        f"handoff desync: host {me} expected the env at "
                        f"site {start} but received site {site} — are all "
                        f"processes walking the same plan?")
                if not np.array_equal(key_data, base_key_data):
                    raise RuntimeError(
                        "handoff key does not match this walk's base key — "
                        "the predecessor owner is sampling a different "
                        "(n_samples, key) job")
                env, log_scale = jnp.asarray(env_h), jnp.asarray(ls_h)
                if self.clamp_map is not None:
                    lp_h = SW.decode_handoff_log_prob(payload)
                    if lp_h is None:
                        raise RuntimeError(
                            "clamped walk received a handoff without the "
                            "log_prob carry — is the predecessor owner "
                            "running an unclamped plan?")
                    log_prob = jnp.asarray(lp_h)
                self.stats["handoffs"] += 1
                self.stats["handoff_recv_bytes"] += SW.payload_nbytes(payload)
                if ckpt_dir:              # durable BEFORE computing from it
                    save_sampler_state(
                        ckpt_dir, start, S.SamplerState(env, key, log_scale),
                        np.zeros((0, n_samples), dtype=np.int32), keep=0)

            t0 = time.perf_counter()
            gd, ld, real = fut.result()
            self.stats["io_wait_s"] += time.perf_counter() - t0
            if next_pos < len(owned):     # pipeline my NEXT owned segment
                fut = self._pool.submit(self._fetch,
                                        *schedule[owned[next_pos]])
                next_pos += 1
            else:
                fut = None
                if pipeline:              # gang-schedule the next walk
                    self._warm = (schedule[owned[0]], self._pool.submit(
                        self._fetch, *schedule[owned[0]]))

            t0 = time.perf_counter()
            with self.runtime.compute_lock():
                seg = MPS(gd, ld, self.semantics)
                env = fit_env(env, chi_s)
                if self.clamp_map is None:
                    samples, env, log_scale = self._run_segment(
                        seg, env, log_scale, key, start)
                else:
                    samples, env, log_scale, log_prob = \
                        self._run_segment_clamped(seg, env, log_scale,
                                                  log_prob, key, start)
                samples = np.asarray(samples[:real])
                jax.block_until_ready((env, log_scale))
            self.stats["compute_s"] += time.perf_counter() - t0
            self._release(gd, ld)
            blocks[start] = samples
            self.stats["segments"] += 1
            site_done = start + real
            if ckpt_dir:
                np.save(os.path.join(ckpt_dir, f"samples_{start:06d}.npy"),
                        samples)
                save_sampler_state(
                    ckpt_dir, site_done,
                    S.SamplerState(env, key, log_scale),
                    np.zeros((0, n_samples), dtype=np.int32), keep=0)
            if idx + 1 < len(schedule) and owners[idx + 1] != me:
                payload = SW.encode_handoff(env, log_scale, key, site_done,
                                            log_prob=log_prob)
                self.runtime.send(owners[idx + 1], payload, tag=site_done)
                self.stats["handoffs"] += 1
                self.stats["handoff_send_bytes"] += SW.payload_nbytes(payload)

        # every process finishes its slice before the outcome gather
        self.runtime.barrier()
        merged: dict[int, np.ndarray] = {}
        for pay in self.runtime.allgather_payloads(SW.encode_blocks(blocks)):
            self.stats["gather_bytes"] += SW.payload_nbytes(pay)
            merged.update(SW.decode_blocks(pay))
        out = SW.assemble_blocks(merged, self.n_sites, n_samples)
        if self.clamp_map is not None:
            # the completed carry lives with the LAST segment's owner; one
            # extra tiny gather makes stats["log_prob"] identical on every
            # process, matching the sample-block contract
            mine = (np.asarray(log_prob) if owners[-1] == me
                    else np.zeros((0,), dtype=np.float64))
            for pay in self.runtime.allgather_payloads({"log_prob": mine}):
                arr = np.asarray(pay["log_prob"])
                if arr.size:
                    self.stats["log_prob"] = arr
        self._finish_walk()
        return out

    def _finish_walk(self) -> None:
        """Fold the store's and the runtime's I/O counters (deltas since
        engine creation) into ``stats`` and line the processes up — every
        process finishes macro batch b before any starts b+1."""
        self.stats["store_io_s"] = self.store.io_seconds - self._store_io0[0]
        self.stats["io_bytes"] = self.store.io_bytes - self._store_io0[1]
        self.stats["quarantined_sites"] = (self.store.quarantined_sites
                                           - self._store_q0[0])
        self.stats["repaired_sites"] = (self.store.repaired_sites
                                        - self._store_q0[1])
        if self.stats["store_io_s"] > 0:
            hidden = max(0.0,
                         self.stats["store_io_s"] - self.stats["io_wait_s"])
            self.stats["io_hidden_frac"] = hidden / self.stats["store_io_s"]
        counters = self.runtime.io_counters()
        for k, v0 in self._runtime_io0.items():
            self.stats[k] = counters[k] - v0
        self.runtime.barrier()

    def run_queue(self, queue, per_batch: int, base_key: jax.Array,
                  worker: str = "engine") -> dict[int, np.ndarray]:
        """Macro batches (paper N₁) as engine work items: batch b is fully
        determined by fold_in(base_key, b), so the queue's elasticity /
        restart guarantees (runtime/elastic.py) hold verbatim — completed
        batches are never recomputed and results are owner-independent."""
        out: dict[int, np.ndarray] = {}
        while (b := queue.claim(worker)) is not None:
            # consecutive batches share the walk schedule — gang-schedule
            # the next batch's first segment behind this batch's tail
            # (pending includes b itself: the final batch must not pin a
            # speculative segment until close)
            out[b] = self.sample(per_batch, jax.random.fold_in(base_key, b),
                                 pipeline=len(queue.pending) > 1)
            queue.complete(b)
        return out

    def close(self, close_store: bool = True) -> None:
        """Join the prefetch thread (releasing any gang-scheduled segment
        still in its slot); ``close_store=False`` leaves the (possibly
        shared) GammaStore alive for further engines/sessions."""
        if self._warm is not None:
            _, fut = self._warm
            self._warm = None
            try:
                gd, ld, _ = fut.result()
                self._release(gd, ld)
            except Exception:           # fetch already failed — nothing live
                pass
        self._pool.shutdown(wait=True)
        if self._wrapped_store is not None:
            # the sharded view is ENGINE-owned (its prefetch thread must
            # not leak) even when the caller's underlying store is shared
            self._wrapped_store.close()
        if close_store:
            self._source_store.close()

    def __enter__(self) -> "StreamingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
