"""`repro.serve` — the multi-tenant HTTP front door over `SamplingService`.

Three orthogonal pieces, composable and individually testable:

* :mod:`repro.serve.cache` — content-addressed result cache.  The paper's
  restart-exactness (batch = f(seed, id)) makes sampling a *pure function*
  of (store bytes, resolved config, seed, n_samples, macro_batches) — so
  identical requests are served from cached bytes, and a request identical
  to one *currently running* attaches to its stream instead of recomputing.
* :mod:`repro.serve.tenancy` — API-key → tenant resolution, per-tenant
  job/byte quotas (429 + Retry-After on exhaustion), and fair-share
  priority (a tenant's effective priority decays with its active jobs).
* :mod:`repro.serve.gateway` — the stdlib ``ThreadingHTTPServer`` gateway:
  job submission/status/cancel as JSON, sample blocks streamed over
  chunked HTTP in the PR 6 frame codec, ``/v1/stats`` and Prometheus
  ``/metrics`` for scrapers.
"""
from repro.serve.cache import ResultCache, cache_key
from repro.serve.gateway import Gateway
from repro.serve.tenancy import (QuotaExceeded, Tenant, TenantTable,
                                 UnknownTenant)

__all__ = ["Gateway", "QuotaExceeded", "ResultCache", "Tenant",
           "TenantTable", "UnknownTenant", "cache_key"]
