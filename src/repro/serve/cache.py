"""Content-addressed result cache over idempotent sampling jobs.

The cache key is the *full causal input* of a job's bytes: the GammaStore
content digest, the resolved-config digest, the integer seed, and the
(n_samples, macro_batches) split — everything :func:`repro.api.service.
batch_key` and the engine consume.  Two requests with equal keys therefore
produce bit-identical blocks, which is what makes the three outcomes safe:

* **hit** — the blocks are already cached (memory or the on-disk store):
  serve the exact bytes, no compute;
* **attach** — an identical job is *running right now*: the second caller
  streams from the first job's entry as its blocks land (in-flight dedup —
  one execution, N streams);
* **miss** — the caller becomes the entry's owner: it runs the job,
  :meth:`Entry.publish`\\ es each block, and :meth:`Entry.finish`\\ es.

Blocks are stored as the npy frame bytes of the PR 6 transport codec
(``runtime/transport.array_to_frame``) — the same bytes the gateway puts
on the wire, so a cache hit is bit-identical to the original stream by
construction, not by re-serialization.

The optional disk store persists finished entries under
``cache_dir/<key>/batch_*.npy`` (+ ``meta.json``) with an LRU byte budget:
when ``max_bytes`` would be exceeded, least-recently-used entries are
evicted whole.  Memory holds only running entries plus at most
``max_memory_entries`` finished ones (its own LRU, enforced at seal/load
time): an evicted finished entry re-serves from disk when a store is
configured, or becomes a miss in memory-only mode — either way a
long-running cache cannot accumulate every unique job's bytes.
"""
from __future__ import annotations

import hashlib
import json
import logging
import os
import shutil
import threading
import time
from typing import Iterator, Optional

from repro.runtime.transport import array_from_frame

RUNNING, DONE_, FAILED_ = "running", "done", "failed"


def cache_key(store_digest: str, config_digest: str, seed: int,
              n_samples: int, macro_batches: int) -> str:
    """The content address of one job's result bytes (sha256 hex).

    ``macro_batches`` is part of the key even though the *concatenation*
    is seed-stable only per split — a k-batch job's blocks are framed
    per batch, and batch b draws with ``fold_in(key, b)`` (k > 1) vs the
    raw key (k == 1), so different splits are different byte streams."""
    return hashlib.sha256(json.dumps(
        {"store": store_digest, "config": config_digest, "seed": int(seed),
         "n_samples": int(n_samples), "macro_batches": int(macro_batches)},
        sort_keys=True).encode()).hexdigest()


class Entry:
    """One cached (or in-flight) job result: batch_id → npy frame bytes.

    The owner (the cache-miss caller) publishes blocks and finishes; any
    number of readers stream concurrently — :meth:`stream` blocks on a
    condition until the next expected batch lands, exactly the semantics
    of ``JobHandle.stream`` but over serialized bytes."""

    def __init__(self, key: str, n_batches: int):
        self.key = key
        self.n_batches = n_batches
        self.state = RUNNING
        self.error: Optional[str] = None
        self.blocks: dict[int, bytes] = {}
        self.created = time.time()
        self.last_used = time.monotonic()   # memory-LRU recency
        self._cond = threading.Condition()

    def publish(self, batch_id: int, frame: bytes) -> None:
        with self._cond:
            self.blocks[batch_id] = frame
            self._cond.notify_all()

    def finish(self, error: Optional[str] = None) -> None:
        with self._cond:
            self.state = FAILED_ if error else DONE_
            self.error = error
            self._cond.notify_all()

    @property
    def nbytes(self) -> int:
        with self._cond:
            return sum(len(b) for b in self.blocks.values())

    def stream(self, timeout: Optional[float] = None
               ) -> Iterator[tuple[int, bytes]]:
        """Yield ``(batch_id, npy_frame_bytes)`` in batch order as blocks
        land; raises RuntimeError if the owning job failed mid-stream."""
        for b in range(self.n_batches):
            deadline = None if timeout is None else time.monotonic() + timeout
            with self._cond:
                while b not in self.blocks:
                    if self.state == FAILED_:
                        raise RuntimeError(self.error or "job failed")
                    remaining = (None if deadline is None
                                 else deadline - time.monotonic())
                    if remaining is not None and remaining <= 0:
                        raise TimeoutError(
                            f"cache entry {self.key[:12]}: batch {b} not "
                            f"published within {timeout}s")
                    self._cond.wait(timeout=remaining)
                frame = self.blocks[b]
            yield b, frame

    def result_arrays(self, timeout: Optional[float] = None) -> list:
        return [array_from_frame(f) for _, f in self.stream(timeout=timeout)]


class ResultCache:
    """In-memory entry table + optional LRU-bounded disk store.

    ``get_or_begin`` is the single entry point; its status return drives
    the gateway's hit / attach / miss paths.  ``stats()`` has a stable
    schema (hits/misses/attaches/evictions/corrupt_entries/entries/
    disk_entries/disk_bytes, always present)."""

    def __init__(self, cache_dir: Optional[str] = None,
                 max_bytes: Optional[int] = None,
                 max_memory_entries: int = 64):
        self.cache_dir = cache_dir
        self.max_bytes = max_bytes
        self.max_memory_entries = max_memory_entries
        if cache_dir:
            os.makedirs(cache_dir, exist_ok=True)
        self._lock = threading.Lock()
        self._entries: dict[str, Entry] = {}
        self.hits = 0
        self.misses = 0
        self.attaches = 0
        self.evictions = 0
        self.corrupt_entries = 0   # disk entries dropped as unreadable
        # the telemetry seam (repro.obs): observer(event) for
        # "cache_hit" / "cache_miss" / "cache_attach" / "cache_evict" /
        # "cache_corrupt"
        self.observer = None

    def _emit(self, event: str, **fields) -> None:
        if self.observer is not None:
            try:
                self.observer(event, **fields)
            except Exception:              # noqa: BLE001 — telemetry seam
                pass

    # -- disk store ----------------------------------------------------------
    def _dir(self, key: str) -> str:
        return os.path.join(self.cache_dir, key)

    def _load_disk(self, key: str) -> Optional[Entry]:
        """Disk entry → a DONE memory entry (touches mtime for LRU)."""
        d = self._dir(key)
        meta_path = os.path.join(d, "meta.json")
        if not os.path.exists(meta_path):
            return None
        try:
            with open(meta_path) as f:
                meta = json.load(f)
            entry = Entry(key, int(meta["n_batches"]))
            for b in range(entry.n_batches):
                with open(os.path.join(d, f"batch_{b:05d}.npy"), "rb") as f:
                    entry.blocks[b] = f.read()
            entry.finish()
            os.utime(d)                    # LRU recency = dir mtime
            return entry
        except (OSError, ValueError, KeyError) as e:
            # corrupt entry: drop it — but LOUDLY, not silently.  A cache
            # entry that stopped deserializing means disk rot or a torn
            # write; operators need the count (metrics) and the key (log),
            # and the request falls through to a clean recompute.
            self.corrupt_entries += 1      # caller holds self._lock
            self._emit("cache_corrupt", key=key)
            logging.getLogger(__name__).warning(
                "result cache: dropping corrupt disk entry %s (%s: %s)",
                key, type(e).__name__, e)
            shutil.rmtree(d, ignore_errors=True)
            return None

    def _store_disk(self, entry: Entry) -> None:
        d = self._dir(entry.key)
        tmp = d + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        for b, frame in entry.blocks.items():
            with open(os.path.join(tmp, f"batch_{b:05d}.npy"), "wb") as f:
                f.write(frame)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"key": entry.key, "n_batches": entry.n_batches,
                       "created": entry.created}, f)
        shutil.rmtree(d, ignore_errors=True)
        os.replace(tmp, d)
        self._evict()

    def _disk_entries(self) -> list[tuple[str, float, int]]:
        """(key, mtime, bytes) per finished disk entry, oldest first."""
        if not self.cache_dir:
            return []
        out = []
        for key in os.listdir(self.cache_dir):
            d = self._dir(key)
            if not os.path.isdir(d) or key.endswith(".tmp"):
                continue
            try:
                size = sum(os.path.getsize(os.path.join(d, f))
                           for f in os.listdir(d))
                mtime = os.path.getmtime(d)
            except OSError:
                continue       # rmtree'd by a concurrent _evict mid-scan
            out.append((key, mtime, size))
        out.sort(key=lambda t: t[1])
        return out

    def _evict(self) -> None:
        if self.max_bytes is None:
            return
        entries = self._disk_entries()
        total = sum(size for _, _, size in entries)
        for key, _, size in entries:
            if total <= self.max_bytes:
                break
            shutil.rmtree(self._dir(key), ignore_errors=True)
            total -= size
            self.evictions += 1
            self._emit("cache_evict")

    def _evict_memory_locked(self) -> None:
        """Bound the in-memory table (caller holds ``_lock``): beyond
        ``max_memory_entries`` finished entries, drop the least recently
        used.  RUNNING entries are exempt — dropping one would break the
        in-flight dedup contract.  Streams already attached to a dropped
        entry keep their own reference; only the table forgets it."""
        finished = [(e.last_used, k) for k, e in self._entries.items()
                    if e.state != RUNNING]
        excess = len(finished) - self.max_memory_entries
        if excess <= 0:
            return
        finished.sort()
        for _, key in finished[:excess]:
            del self._entries[key]

    # -- the one entry point -------------------------------------------------
    def get_or_begin(self, key: str, n_batches: int
                     ) -> tuple[Entry, str]:
        """Resolve ``key`` → ``(entry, status)`` with status one of:

        * ``"hit"`` — a finished entry (memory or disk); stream it.
        * ``"attach"`` — a RUNNING entry; stream it (in-flight dedup).
        * ``"miss"`` — a fresh RUNNING entry registered under the caller's
          ownership: the caller MUST run the job, ``publish`` each block
          and ``finish`` (or ``finish(error=...)``) — and then call
          :meth:`seal` to persist and release the running slot.
        """
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                if entry.state == DONE_:
                    entry.last_used = time.monotonic()
                    self.hits += 1
                    self._emit("cache_hit")
                    return entry, "hit"
                if entry.state == RUNNING:
                    self.attaches += 1
                    self._emit("cache_attach")
                    return entry, "attach"
                # FAILED entries don't poison the key: fall through to miss
            if self.cache_dir:
                disk = self._load_disk(key)
                if disk is not None:
                    self._entries[key] = disk
                    self._evict_memory_locked()
                    self.hits += 1
                    self._emit("cache_hit")
                    return disk, "hit"
            entry = Entry(key, n_batches)
            self._entries[key] = entry
            self.misses += 1
            self._emit("cache_miss")
            return entry, "miss"

    def seal(self, entry: Entry) -> None:
        """Owner's epilogue after ``finish()``: persist a DONE entry to the
        disk store (under the LRU budget) and re-bound the in-memory
        table; drop a FAILED entry from the table so the next identical
        request recomputes."""
        if entry.state == DONE_:
            if self.cache_dir:
                self._store_disk(entry)
            with self._lock:
                entry.last_used = time.monotonic()
                self._evict_memory_locked()
        else:
            with self._lock:
                if self._entries.get(entry.key) is entry:
                    del self._entries[entry.key]

    def stats(self) -> dict:
        disk = self._disk_entries()
        with self._lock:
            running = sum(e.state == RUNNING for e in self._entries.values())
            return {"hits": self.hits, "misses": self.misses,
                    "attaches": self.attaches, "evictions": self.evictions,
                    "corrupt_entries": self.corrupt_entries,
                    "entries": len(self._entries), "running": running,
                    "disk_entries": len(disk),
                    "disk_bytes": sum(s for _, _, s in disk),
                    "max_bytes": self.max_bytes}


__all__ = ["Entry", "ResultCache", "cache_key"]
