"""Multi-tenant admission: API keys, quotas, fair-share priority.

A *tenant* is an API key with a base priority and two quotas — concurrent
executing jobs and concurrently-requested output bytes (``n_samples × M ×
4``, the f32 sample block the caller will receive).  Quotas bound what a
tenant can have *in flight*, not a rate: a 429 (``QuotaExceeded`` →
``Retry-After``) clears as soon as one of the tenant's jobs drains, which
composes with the service's own perfmodel admission control (that one
bounds the device, this one bounds the tenant).

**Fair share.**  The service schedules jobs by (-priority, id).  A tenant
submitting a burst would monopolize the queue at its base priority, so the
table maps base priority → *effective* priority at submit time:
``priority - active_jobs`` — a deficit scheme: each additional in-flight
job demotes the tenant's next one below other tenants at the same base,
interleaving pending work across tenants instead of FIFO-by-tenant.

Config file (``--tenants tenants.json``)::

    {"tenants": [
        {"name": "alice", "api_key": "alice-key", "priority": 10,
         "max_active_jobs": 4, "max_active_bytes": 100000000},
        {"name": "bob", "api_key": "bob-key"}
    ]}

An *open* table (no file) resolves every request — keyed or not — to a
quota-less ``anonymous`` tenant: single-user deployments need no config.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Optional


class UnknownTenant(KeyError):
    """API key not in the tenant table (gateway → 401)."""


class QuotaExceeded(RuntimeError):
    """Per-tenant quota exhausted (gateway → 429 + Retry-After)."""

    def __init__(self, msg: str, retry_after_s: float = 1.0):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


@dataclasses.dataclass
class Tenant:
    name: str
    api_key: Optional[str] = None
    priority: int = 0
    max_active_jobs: Optional[int] = None
    max_active_bytes: Optional[int] = None
    # live accounting (TenantTable.begin_job/end_job)
    active_jobs: int = 0
    active_bytes: int = 0
    submitted: int = 0
    rejected: int = 0

    def snapshot(self) -> dict:
        return {"name": self.name, "priority": self.priority,
                "active_jobs": self.active_jobs,
                "active_bytes": self.active_bytes,
                "max_active_jobs": self.max_active_jobs,
                "max_active_bytes": self.max_active_bytes,
                "submitted": self.submitted, "rejected": self.rejected}


class TenantTable:
    """Thread-safe tenant registry + quota ledger."""

    def __init__(self, tenants: Optional[list[Tenant]] = None):
        self._lock = threading.Lock()
        self.open = not tenants
        self._anonymous = Tenant(name="anonymous")
        self._by_key: dict[str, Tenant] = {}
        for t in tenants or []:
            if not t.api_key:
                raise ValueError(f"tenant {t.name!r} has no api_key")
            if t.api_key in self._by_key:
                raise ValueError(f"duplicate api_key for {t.name!r}")
            self._by_key[t.api_key] = t

    @classmethod
    def from_json(cls, path: str) -> "TenantTable":
        with open(path) as f:
            doc = json.load(f)
        fields = {f.name for f in dataclasses.fields(Tenant)}
        tenants = []
        for spec in doc.get("tenants", []):
            unknown = set(spec) - fields
            if unknown:
                raise ValueError(f"tenant spec {spec.get('name')!r}: unknown "
                                 f"fields {sorted(unknown)}")
            tenants.append(Tenant(**spec))
        return cls(tenants)

    def resolve(self, api_key: Optional[str]) -> Tenant:
        """API key → tenant; the open table accepts anything."""
        if self.open:
            return self._anonymous
        t = self._by_key.get(api_key or "")
        if t is None:
            raise UnknownTenant("unknown or missing API key")
        return t

    # -- quota ledger --------------------------------------------------------
    def begin_job(self, tenant: Tenant, nbytes: int) -> int:
        """Admit one job of ``nbytes`` requested output; returns the job's
        fair-share *effective priority*.  Raises :class:`QuotaExceeded`
        (without consuming quota) when either quota would be exceeded."""
        with self._lock:
            if (tenant.max_active_jobs is not None
                    and tenant.active_jobs >= tenant.max_active_jobs):
                tenant.rejected += 1
                raise QuotaExceeded(
                    f"tenant {tenant.name!r}: {tenant.active_jobs} active "
                    f"jobs ≥ quota {tenant.max_active_jobs}")
            if (tenant.max_active_bytes is not None
                    and tenant.active_bytes + nbytes
                    > tenant.max_active_bytes):
                tenant.rejected += 1
                raise QuotaExceeded(
                    f"tenant {tenant.name!r}: {tenant.active_bytes + nbytes}"
                    f" active bytes > quota {tenant.max_active_bytes}")
            eff = tenant.priority - tenant.active_jobs
            tenant.active_jobs += 1
            tenant.active_bytes += nbytes
            tenant.submitted += 1
            return eff

    def end_job(self, tenant: Tenant, nbytes: int) -> None:
        with self._lock:
            tenant.active_jobs = max(0, tenant.active_jobs - 1)
            tenant.active_bytes = max(0, tenant.active_bytes - nbytes)

    def stats(self) -> dict:
        with self._lock:
            tenants = ([self._anonymous.snapshot()] if self.open else
                       [t.snapshot() for t in self._by_key.values()])
            return {"open": self.open, "tenants": tenants,
                    "active_jobs": sum(t["active_jobs"] for t in tenants),
                    "rejected": sum(t["rejected"] for t in tenants)}


__all__ = ["QuotaExceeded", "Tenant", "TenantTable", "UnknownTenant"]
