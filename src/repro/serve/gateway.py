"""The HTTP gateway: `SamplingService` behind a stdlib front door.

``ThreadingHTTPServer`` + ``BaseHTTPRequestHandler`` — no third-party web
stack; one OS thread per in-flight request, which is the right shape here
because a streaming response spends its life blocked on a condition
variable, not computing.

Routes (JSON in/out unless noted)::

    POST   /v1/jobs              submit → {"id", "cache", "state", ...}
    GET    /v1/jobs/<id>         status/progress snapshot
    GET    /v1/jobs/<id>/stream  chunked stream of sample blocks (frames)
    DELETE /v1/jobs/<id>         cancel the underlying execution
    GET    /v1/stats             service + cache + tenant snapshot
    GET    /metrics              Prometheus text exposition (repro.obs)

**Authorization**: every ``/v1/jobs/<id>`` route resolves ``x-api-key``
exactly like submit does (401 on an unknown key) and answers 404 unless
the job belongs to the caller's tenant — a job id is never a capability,
and ids are unguessable tokens (``secrets.token_hex``) as defense in
depth.  The open (no tenants file) table maps every caller to the same
``anonymous`` tenant, so single-user deployments see no auth at all.

**Submission body** — a whitelist, unknown fields are a 400 (a typo'd
tuning knob must fail loudly, not silently sample with defaults)::

    {"store": "demo_chain",             # required (see store_root below)
     "n_samples": 4096,                 # required
     "seed": 7,                         # required (job key = key(seed))
     "macro_batches": 4,                # optional, default 1
     "config": {"segment_len": 4, ...}} # optional SamplerConfig overrides

With ``store_root`` configured (``--store-root``), ``store`` is a
relative name resolved strictly beneath that directory — absolute paths
and ``..`` escapes are a 400, so clients can never point the server at
arbitrary host filesystem.  Without a root (trusted single-user mode)
``store`` is a server-side path, as before.

``config`` keys are validated against the full ``SamplerConfig`` schema
via the v2 wire codec (``remote.config_to_dict`` round-trip), minus the
server-side fields (``runtime``, ``hardware``, checkpoint paths).

**The stream wire format** reuses the PR 6 frame codec verbatim inside a
chunked HTTP body: per block a JSON frame ``{"kind": "block",
"batch_id": b, "nbytes": n}`` then an npy frame of the (per_batch, M)
samples; terminated by ``{"kind": "end", ...}`` or ``{"kind": "error",
"error": msg}``.  Frames come from the result cache's entries, so a cache
hit re-serves byte-identical frames and an attached request streams the
owner's frames as they land (one execution, N streams).

**Cancel semantics**: an execution is shared by every request attached to
its cache entry, so only the *owning* request's DELETE cancels it (every
attached stream then sees the error frame — their results were the
owner's bytes).  An attacher's DELETE merely detaches its own record; a
hit-served request has nothing to cancel.
"""
from __future__ import annotations

import dataclasses
import json
import os
import secrets
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.runtime.transport import array_to_frame, write_frame, write_json
from repro.serve.cache import ResultCache, cache_key
from repro.serve.tenancy import QuotaExceeded, TenantTable, UnknownTenant

# request fields a client may set; everything else is the server's
_TOP_FIELDS = {"store", "n_samples", "seed", "macro_batches", "config"}
_REQUIRED = {"store", "n_samples", "seed"}
_SERVER_CONFIG_FIELDS = {"runtime", "hardware", "store_root",
                         "checkpoint_dir", "checkpoint_every"}
_SAMPLE_ITEMSIZE = 4               # samples return as (N, M) i32/f32 blocks


class _HTTPError(Exception):
    def __init__(self, code: int, msg: str, **extra):
        super().__init__(msg)
        self.code = code
        self.body = dict({"error": msg}, **extra)
        self.headers: dict[str, str] = {}


@dataclasses.dataclass
class _Record:
    """One submitted request's view of its (possibly shared) execution."""
    gid: str
    tenant_name: str
    cache_status: str              # hit | attach | miss
    entry: object                  # serve.cache.Entry
    handle: object                 # api.service.JobHandle (miss only)
    n_samples: int
    n_batches: int
    created: float
    cancelled: bool = False

    def state(self) -> str:
        if self.handle is not None:
            return self.handle.status()
        if self.cancelled:
            return "cancelled"
        return self.entry.state       # running | done | failed

    def snapshot(self) -> dict:
        out = {"id": self.gid, "tenant": self.tenant_name,
               "cache": self.cache_status, "state": self.state(),
               "n_samples": self.n_samples, "n_batches": self.n_batches,
               "blocks_done": len(self.entry.blocks),
               "created": self.created}
        if self.entry.error:
            out["error"] = self.entry.error
        if self.handle is not None:
            out["progress"] = {
                k: v for k, v in self.handle.progress.items()
                if isinstance(v, (int, float, bool, str))}
            report = self.handle.fault_report()
            if report is not None:
                # the structured failure surface: fault taxonomy records +
                # the dead-letter when bounded retries gave the job up
                out["fault_report"] = report
        return out


class Gateway:
    """The server object: owns the HTTP listener, the request records, and
    the (tenants, cache, registry) collaborators; drives — but does not
    own — the :class:`~repro.api.service.SamplingService`."""

    def __init__(self, service, *, tenants: Optional[TenantTable] = None,
                 cache: Optional[ResultCache] = None, registry=None,
                 host: str = "127.0.0.1", port: int = 0,
                 store_root: Optional[str] = None, max_records: int = 4096):
        self.service = service
        self.tenants = tenants or TenantTable()
        self.cache = cache or ResultCache()
        self.registry = registry
        self.store_root = store_root
        self.max_records = max_records
        self._host, self._port = host, port
        self._lock = threading.Lock()
        self._records: dict[str, _Record] = {}
        self._digest_cache: dict[str, tuple[tuple, str, int]] = {}
        self._server: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self.requests = 0
        if registry is not None:
            self._wire_metrics(registry)
        else:
            self._http_requests = None

    # -- telemetry -----------------------------------------------------------
    def _wire_metrics(self, registry, prefix: str = "fastmps") -> None:
        self._http_requests = registry.counter(
            f"{prefix}_http_requests_total", "HTTP requests by route/code",
            labelnames=("route", "code"))
        cache_events = registry.counter(
            f"{prefix}_cache_events_total",
            "Result-cache events (hit/miss/attach/evict)",
            labelnames=("event",))
        self.cache.observer = lambda event, **f: cache_events.labels(
            event=event.removeprefix("cache_")).inc()
        self._tenant_rejections = registry.counter(
            f"{prefix}_tenant_rejections_total",
            "Requests rejected by tenant quota (HTTP 429)")
        g_disk = registry.gauge(f"{prefix}_cache_disk_bytes",
                                "Result-cache on-disk footprint")
        g_entries = registry.gauge(f"{prefix}_cache_entries",
                                   "Result-cache in-memory entries")
        g_active = registry.gauge(f"{prefix}_tenant_active_jobs",
                                  "Executing jobs across tenants")
        g_corrupt = registry.gauge(f"{prefix}_cache_corrupt_entries",
                                   "Corrupt result-cache disk entries "
                                   "dropped (disk rot / torn writes)")

        def collect() -> None:
            cs = self.cache.stats()
            g_disk.set(cs["disk_bytes"])
            g_entries.set(cs["entries"])
            g_active.set(self.tenants.stats()["active_jobs"])
            g_corrupt.set(cs.get("corrupt_entries", 0))

        registry.add_collector(collect)

    def _observe_request(self, route: str, code: int) -> None:
        self.requests += 1
        if self._http_requests is not None:
            self._http_requests.labels(route=route, code=str(code)).inc()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "Gateway":
        gw = self

        class Handler(_Handler):
            gateway = gw

        self._server = ThreadingHTTPServer((self._host, self._port), Handler)
        self._server.daemon_threads = True
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        name="fastmps-gateway", daemon=True)
        self._thread.start()
        return self

    @property
    def url(self) -> str:
        host, port = self._server.server_address[:2]
        return f"http://{host}:{port}"

    def close(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._thread.join(timeout=30)
            self._server = None

    def __enter__(self) -> "Gateway":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- store identity ------------------------------------------------------
    def _resolve_store(self, name: str) -> str:
        """Client ``store`` field → server path.  With a configured
        ``store_root`` the name must resolve strictly beneath it (realpath
        containment, so ``..`` and symlink escapes both fail); without one
        (trusted single-user mode) the name is used as a path verbatim."""
        if self.store_root is None:
            return name
        if os.path.isabs(name):
            raise _HTTPError(
                400, f"store {name!r} must be a name relative to the "
                     f"configured store root, not an absolute path")
        if ".." in name.replace("\\", "/").split("/"):
            raise _HTTPError(400, f"store {name!r} escapes the store root")
        root = os.path.realpath(self.store_root)
        real = os.path.realpath(os.path.join(root, name))
        if real != root and not real.startswith(root + os.sep):
            raise _HTTPError(400, f"store {name!r} escapes the store root")
        return real

    def _store_identity(self, path: str) -> tuple[str, int]:
        """(content digest, n_sites) of the store at ``path``, cached per
        realpath and invalidated when any site file's (name, mtime_ns,
        size, inode) changes — submissions against an unchanged store
        don't re-hash.  ``st_mtime_ns + st_ino`` (not coarse mtime) so an
        atomic rewrite with identical size can't serve a stale digest."""
        real = os.path.realpath(path)
        if not os.path.isdir(real):
            raise _HTTPError(400, f"store {path!r} is not a directory")
        sites = sorted(f for f in os.listdir(real)
                       if f.startswith("site_") and f.endswith(".npz"))
        if not sites:
            raise _HTTPError(400, f"store {path!r} holds no site_*.npz")
        stats = [os.stat(os.path.join(real, f)) for f in sites]
        sig = tuple((f, st.st_mtime_ns, st.st_size, st.st_ino)
                    for f, st in zip(sites, stats))
        with self._lock:
            hit = self._digest_cache.get(real)
            if hit is not None and hit[0] == sig:
                return hit[1], hit[2]
        from repro.data.gamma_store import GammaStore
        with GammaStore(real) as store:
            digest = store.digest()
        with self._lock:
            self._digest_cache[real] = (sig, digest, len(sites))
        return digest, len(sites)

    # -- submission ----------------------------------------------------------
    def _parse_body(self, body: dict):
        from repro.api.config import SamplerConfig
        from repro.api.remote import config_from_dict, config_to_dict

        if not isinstance(body, dict):
            raise _HTTPError(400, "request body must be a JSON object")
        unknown = set(body) - _TOP_FIELDS
        if unknown:
            raise _HTTPError(400, f"unknown fields {sorted(unknown)} "
                                  f"(accepted: {sorted(_TOP_FIELDS)})")
        missing = _REQUIRED - set(body)
        if missing:
            raise _HTTPError(400, f"missing required fields "
                                  f"{sorted(missing)}")
        try:
            n_samples = int(body["n_samples"])
            seed = int(body["seed"])
            macro_batches = int(body.get("macro_batches", 1))
        except (TypeError, ValueError):
            raise _HTTPError(400, "n_samples/seed/macro_batches must be "
                                  "integers")
        if n_samples < 1 or macro_batches < 1:
            raise _HTTPError(400, "n_samples and macro_batches must be ≥ 1")
        if n_samples % macro_batches:
            raise _HTTPError(400, f"n_samples={n_samples} must divide over "
                                  f"{macro_batches} macro batches")
        # the override schema is the SamplerConfig dataclass itself, so new
        # client-side fields (e.g. the workloads `clamp` spec, a {site:
        # outcome} object — conditional jobs) are accepted here without a
        # gateway change; a malformed value (clamp included) fails
        # SamplerConfig construction below → clean 400, and the resolved
        # digest folds it into the ResultCache key, so a clamped job can
        # never serve an unclamped job's cached frames (or vice versa)
        overrides = body.get("config") or {}
        if not isinstance(overrides, dict):
            raise _HTTPError(400, "config must be a JSON object")
        base = config_to_dict(SamplerConfig())
        for k in overrides:
            if k in _SERVER_CONFIG_FIELDS:
                raise _HTTPError(400, f"config field {k!r} is server-side")
            if k not in base:
                raise _HTTPError(400, f"unknown config field {k!r}")
        merged = dict(base, **overrides)
        try:
            cfg = config_from_dict(merged)
        except Exception as e:       # noqa: BLE001 — client error, not ours
            raise _HTTPError(400, f"invalid config: {e}")
        # the resolved-config digest: the cache key must see the config the
        # engine will actually consume, not the request's sparse overrides
        cfg_digest = json.dumps(config_to_dict(cfg), sort_keys=True,
                                default=str)
        return str(body["store"]), cfg, cfg_digest, n_samples, seed, \
            macro_batches

    def submit(self, body: dict, api_key: Optional[str]) -> dict:
        import jax

        try:
            tenant = self.tenants.resolve(api_key)
        except UnknownTenant as e:
            raise _HTTPError(401, str(e))
        store, cfg, cfg_digest, n_samples, seed, macro_batches = \
            self._parse_body(body)
        store = self._resolve_store(store)
        store_digest, n_sites = self._store_identity(store)
        nbytes = n_samples * n_sites * _SAMPLE_ITEMSIZE
        try:
            priority = self.tenants.begin_job(tenant, nbytes)
        except QuotaExceeded as e:
            if self.registry is not None:
                self._tenant_rejections.inc()
            err = _HTTPError(429, str(e),
                            admission=self.service.stats()["admission"])
            err.headers["Retry-After"] = str(max(1, int(e.retry_after_s)))
            raise err
        key = cache_key(store_digest, cfg_digest, seed, n_samples,
                        macro_batches)
        entry, status = self.cache.get_or_begin(key, macro_batches)
        gid = f"j{secrets.token_hex(12)}"     # unguessable: ids leak nothing
        handle = None
        if status == "miss":
            try:
                handle = self.service.submit(
                    store, cfg, n_samples=n_samples,
                    key=jax.random.key(seed), macro_batches=macro_batches,
                    priority=priority)
            except Exception as e:    # noqa: BLE001 — refuse, roll back
                entry.finish(error=str(e))
                self.cache.seal(entry)
                self.tenants.end_job(tenant, nbytes)
                raise _HTTPError(400, f"submit rejected: {e}")
            threading.Thread(target=self._pump,
                             args=(handle, entry, tenant, nbytes),
                             name=f"gateway-pump-{gid}", daemon=True).start()
        else:
            # hit/attach: this request triggers no execution — its quota
            # charge releases immediately (the owner's charge stands)
            self.tenants.end_job(tenant, nbytes)
        rec = _Record(gid=gid, tenant_name=tenant.name, cache_status=status,
                      entry=entry, handle=handle, n_samples=n_samples,
                      n_batches=macro_batches, created=time.time())
        with self._lock:
            self._records[gid] = rec
            self._purge_records_locked()
        return rec.snapshot()

    def _purge_records_locked(self) -> None:
        """Bound ``_records``: beyond ``max_records``, drop the oldest
        *terminal* (done/failed/cancelled) records — insertion order is
        creation order.  Live records are never dropped, so the table can
        exceed the bound only while that many jobs are actually in
        flight."""
        excess = len(self._records) - self.max_records
        if excess <= 0:
            return
        drop = []
        for gid, rec in self._records.items():
            if len(drop) >= excess:
                break
            if rec.state() in ("done", "failed", "cancelled"):
                drop.append(gid)
        for gid in drop:
            del self._records[gid]

    def _pump(self, handle, entry, tenant, nbytes: int) -> None:
        """Owner loop of a cache-miss execution: service blocks → cache
        frames.  Every attached stream reads the entry, never the handle."""
        try:
            for b, block in handle.stream():
                entry.publish(b, array_to_frame(block))
            entry.finish()
        except BaseException as e:    # noqa: BLE001 — surfaced as a frame
            entry.finish(error=f"{type(e).__name__}: {e}")
        finally:
            self.cache.seal(entry)
            self.tenants.end_job(tenant, nbytes)

    # -- the other routes ----------------------------------------------------
    def record(self, gid: str, api_key: Optional[str]) -> _Record:
        """gid → record, tenant-scoped: the caller's key must resolve
        (401) and the record must belong to that tenant — a foreign
        tenant's job id answers 404, indistinguishable from absent, so
        ids leak neither results nor existence."""
        try:
            tenant = self.tenants.resolve(api_key)
        except UnknownTenant as e:
            raise _HTTPError(401, str(e))
        with self._lock:
            rec = self._records.get(gid)
        if rec is None or rec.tenant_name != tenant.name:
            raise _HTTPError(404, f"no such job {gid!r}")
        return rec

    def cancel(self, gid: str, api_key: Optional[str]) -> dict:
        rec = self.record(gid, api_key)
        if rec.handle is not None:
            ok = rec.handle.cancel()
        else:
            ok = rec.entry.state == "running" and rec.cache_status == "attach"
            rec.cancelled = rec.cancelled or ok
        return {"id": gid, "cancelled": bool(ok), "state": rec.state()}

    def stats(self) -> dict:
        with self._lock:
            recs = list(self._records.values())
        by_state: dict[str, int] = {}
        for r in recs:
            s = r.state()
            by_state[s] = by_state.get(s, 0) + 1
        return {"service": self.service.stats(),
                "cache": self.cache.stats(),
                "tenants": self.tenants.stats(),
                "gateway": {"requests": self.requests,
                            "jobs": len(recs), "by_state": by_state}}


class _ChunkedWriter:
    """File-like adapter that chunk-encodes writes onto the raw socket —
    lets the PR 6 frame codec write straight into an HTTP/1.1 chunked
    body."""

    def __init__(self, wfile):
        self._w = wfile

    def write(self, data: bytes) -> int:
        if data:
            self._w.write(b"%X\r\n" % len(data))
            self._w.write(data)
            self._w.write(b"\r\n")
        return len(data)

    def flush(self) -> None:
        self._w.flush()

    def close(self) -> None:
        self._w.write(b"0\r\n\r\n")
        self._w.flush()


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    # small JSON exchanges + length-prefixed frames are exactly the write
    # pattern Nagle+delayed-ACK stalls (~40ms per exchange on loopback)
    disable_nagle_algorithm = True
    gateway: Gateway = None        # bound by Gateway.start()

    # -- plumbing ------------------------------------------------------------
    def log_message(self, *args) -> None:     # noqa: D102 — silence stderr
        pass

    def _json(self, code: int, obj: dict,
              headers: Optional[dict] = None) -> None:
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _route(self, method: str) -> tuple[str, tuple]:
        parts = [p for p in self.path.split("?")[0].split("/") if p]
        if parts == ["metrics"] and method == "GET":
            return "metrics", ()
        if parts[:1] == ["v1"]:
            rest = parts[1:]
            if rest == ["stats"] and method == "GET":
                return "stats", ()
            if rest == ["jobs"] and method == "POST":
                return "submit", ()
            if len(rest) == 2 and rest[0] == "jobs":
                if method == "GET":
                    return "status", (rest[1],)
                if method == "DELETE":
                    return "cancel", (rest[1],)
            if (len(rest) == 3 and rest[0] == "jobs"
                    and rest[2] == "stream" and method == "GET"):
                return "stream", (rest[1],)
        raise _HTTPError(404, f"no route {method} {self.path}")

    def _dispatch(self, method: str) -> None:
        gw = self.gateway
        route = "?"
        try:
            route, args = self._route(method)
            code = getattr(self, "_do_" + route)(*args)
        except _HTTPError as e:
            code = e.code
            self._json(e.code, e.body, headers=e.headers)
        except (BrokenPipeError, ConnectionResetError):
            code = 499                      # client went away mid-stream
        except Exception as e:              # noqa: BLE001 — a 500, not a crash
            code = 500
            try:
                self._json(500, {"error": f"{type(e).__name__}: {e}"})
            except OSError:
                pass
        gw._observe_request(route, code)

    def do_GET(self) -> None:
        self._dispatch("GET")

    def do_POST(self) -> None:
        self._dispatch("POST")

    def do_DELETE(self) -> None:
        self._dispatch("DELETE")

    # -- routes --------------------------------------------------------------
    def _do_submit(self) -> int:
        try:
            n = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(n) or b"{}")
        except (ValueError, TypeError):
            raise _HTTPError(400, "body is not valid JSON")
        out = self.gateway.submit(body, self._api_key())
        self._json(201, out)
        return 201

    def _api_key(self) -> Optional[str]:
        return self.headers.get("x-api-key")

    def _do_status(self, gid: str) -> int:
        self._json(200, self.gateway.record(gid, self._api_key()).snapshot())
        return 200

    def _do_cancel(self, gid: str) -> int:
        self._json(200, self.gateway.cancel(gid, self._api_key()))
        return 200

    def _do_stream(self, gid: str) -> int:
        rec = self.gateway.record(gid, self._api_key())
        self.send_response(200)
        self.send_header("Content-Type", "application/x-fastmps-frames")
        self.send_header("Transfer-Encoding", "chunked")
        self.end_headers()
        w = _ChunkedWriter(self.wfile)
        try:
            for b, frame in rec.entry.stream():
                write_json(w, {"kind": "block", "batch_id": b,
                               "nbytes": len(frame)})
                write_frame(w, frame)
            write_json(w, {"kind": "end", "n_batches": rec.n_batches})
        except (TimeoutError, RuntimeError) as e:
            write_json(w, {"kind": "error", "error": str(e)})
        w.close()          # chunked terminator — the connection stays usable
        return 200

    def _do_stats(self) -> int:
        self._json(200, self.gateway.stats())
        return 200

    def _do_metrics(self) -> int:
        if self.gateway.registry is None:
            raise _HTTPError(404, "no metrics registry configured")
        body = self.gateway.registry.render().encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)
        return 200


__all__ = ["Gateway"]
