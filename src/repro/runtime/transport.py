"""Persistent worker-process RPC transport for fleet-scale dispatch.

PR 5's ``RemoteRuntime.submit`` shelled out one fresh interpreter per
macro batch, serially — every batch paid a full jax import and a cold jit
cache.  This module is the replacement: **worker processes stay alive**
and stream job-batch results back over a framed pipe protocol, so one
worker amortizes its startup and compilation across every batch it runs
(the FastMPS premise: a batch is an independent, restart-exact unit, so a
fleet of long-lived workers can claim batches in any order).

Layers, bottom up:

* **frames** — length-prefixed messages on a byte stream: an 8-byte
  big-endian length plus a 4-byte CRC32 of the body, then the body (a
  corrupt frame is rejected at decode as a lane fault, never parsed into
  garbage).  A request is one JSON frame; a
  response is a JSON header frame (``{"kind": "result" | "error", ...}``)
  followed, for results, by one raw ``.npy`` frame.  Deliberately dumb:
  any queue/RPC system (gRPC, ZMQ, a Redis list) can carry the same
  payloads — the schema is ``repro.api.remote``'s v2 job batch, unchanged.
* :class:`WorkerProcess` — one spawned ``python -m repro.runtime.transport``
  child, driven synchronously: ``call(payload)`` writes the request and
  blocks (with a deadline) for the streamed-back result.  The worker loop
  on the far side caches :class:`~repro.api.session.SamplingSession`
  objects per (store, config) cell, so repeated batches of one job hit a
  warm engine and jit cache — the whole point of staying alive.
* :class:`WorkerPool` — named workers spawned/reaped on demand (the
  elastic-lane membership operations), with **chaos injectors**: test
  hooks observing/perturbing every dispatch and result (delay a batch,
  drop a result, deliver a payload twice, kill a worker mid-call) so the
  fault-tolerance claims are *exercised*, not assumed
  (``tests/chaos.py``).

Failure model: any transport fault — worker death, dropped result,
deadline overrun — raises :class:`TransportError`.  Callers (the service's
fleet lanes) treat it as a lane fault, NOT a job fault: the batch requeues
on the :class:`~repro.runtime.elastic.WorkQueue` and the worker respawns;
because batch = f(seed, id), the recomputation is bit-identical.
"""
from __future__ import annotations

import io
import json
import os
import random
import select
import struct
import subprocess
import sys
import tempfile
import time
from typing import Optional

import numpy as np

import zlib

#: frame header: 8-byte big-endian body length + 4-byte CRC32 of the body.
#: The checksum means a corrupt frame is rejected at decode (a
#: :class:`TransportError` — lane fault, batch requeues) instead of parsed
#: into garbage a worker would faithfully compute on.
_HDR = struct.Struct(">QI")
_LEN = struct.Struct(">Q")     # legacy alias: header length parsing in tests
SHUTDOWN = {"kind": "shutdown"}


class TransportError(RuntimeError):
    """A transport-level fault (worker death, drop, deadline, corrupt
    frame).  The batch is NOT lost — callers requeue it and recompute
    bit-identically."""


class WorkerDied(TransportError):
    """The worker process exited (or was killed) mid-conversation."""


# ---------------------------------------------------------------------------
# frames
# ---------------------------------------------------------------------------

def write_frame(stream, body: bytes) -> None:
    stream.write(_HDR.pack(len(body), zlib.crc32(body)))
    stream.write(body)
    stream.flush()


def read_frame(stream) -> bytes:
    """Blocking read of one frame; raises :class:`WorkerDied` on EOF and
    :class:`TransportError` on a checksum mismatch."""
    head = stream.read(_HDR.size)
    if len(head) != _HDR.size:
        raise WorkerDied("stream closed mid-frame")
    n, crc = _HDR.unpack(head)
    body = b""
    while len(body) < n:
        chunk = stream.read(n - len(body))
        if not chunk:
            raise WorkerDied("stream closed mid-frame")
        body += chunk
    if zlib.crc32(body) != crc:
        raise TransportError(
            f"frame checksum mismatch ({zlib.crc32(body):#010x} != "
            f"{crc:#010x}) — corrupt frame rejected at decode")
    return body


def write_json(stream, obj: dict) -> None:
    write_frame(stream, json.dumps(obj).encode())


def read_json(stream) -> dict:
    return json.loads(read_frame(stream).decode())


def array_to_frame(arr: np.ndarray) -> bytes:
    bio = io.BytesIO()
    np.save(bio, np.asarray(arr), allow_pickle=False)
    return bio.getvalue()


def array_from_frame(body: bytes) -> np.ndarray:
    return np.load(io.BytesIO(body), allow_pickle=False)


# ---------------------------------------------------------------------------
# the client side: one persistent worker
# ---------------------------------------------------------------------------

def _src_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


class WorkerProcess:
    """One long-lived ``python -m repro.runtime.transport`` child.

    Synchronous request/response: one in-flight call at a time (a service
    lane drives exactly one worker, so this is the natural shape; a real
    RPC stack would multiplex).  ``call`` enforces ``timeout`` with a
    select() deadline on the response pipe and kills the worker on
    overrun — a hung worker must not wedge its lane.
    """

    def __init__(self, name: str, python: Optional[str] = None,
                 env: Optional[dict] = None, timeout: float = 600.0):
        self.name = name
        self.timeout = timeout
        self.batches = 0                  # results streamed back
        self.dispatch_bytes = 0
        env = dict(os.environ if env is None else env)
        env["PYTHONPATH"] = _src_root() + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        # stderr goes to a file, never a pipe: a chatty worker (jax
        # warnings, tracebacks) must not fill a 64K pipe buffer and wedge
        # itself mid-batch; the tail is read back on fault for diagnostics
        fd, self._stderr_path = tempfile.mkstemp(
            prefix=f"fastmps_worker_{name}_", suffix=".log")
        self._proc = subprocess.Popen(
            [python or sys.executable, "-m", "repro.runtime.transport"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            stderr=fd, env=env)
        os.close(fd)

    @property
    def pid(self) -> int:
        return self._proc.pid

    @property
    def alive(self) -> bool:
        return self._proc.poll() is None

    def _drain_stderr(self) -> str:
        try:
            with open(self._stderr_path, "rb") as f:
                return f.read()[-2000:].decode(errors="replace")
        except OSError:
            return ""

    def _read_frame_deadline(self, deadline: float) -> bytes:
        """``read_frame`` with a wall deadline enforced via select()."""
        fd = self._proc.stdout.fileno()
        buf = b""
        need = _HDR.size
        body_len = None
        body_crc = None
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                # the response stream is now desynced (a late frame would be
                # misread as the NEXT call's response) — the worker dies here
                pid = self.pid
                self.kill()
                raise TransportError(
                    f"worker {self.name!r} (pid {pid}) exceeded the "
                    f"{self.timeout}s deadline")
            ready, _, _ = select.select([fd], [], [], min(remaining, 1.0))
            if not ready:
                if not self.alive:
                    raise WorkerDied(
                        f"worker {self.name!r} died (rc="
                        f"{self._proc.returncode}):\n{self._drain_stderr()}")
                continue
            chunk = os.read(fd, need - len(buf))
            if not chunk:
                raise WorkerDied(
                    f"worker {self.name!r} closed its pipe (rc="
                    f"{self._proc.poll()}):\n{self._drain_stderr()}")
            buf += chunk
            if len(buf) == need:
                if body_len is None:
                    body_len, body_crc = _HDR.unpack(buf)
                    buf, need = b"", body_len
                    if body_len == 0:
                        body = b""
                    else:
                        continue
                else:
                    body = buf
                if zlib.crc32(body) != body_crc:
                    raise TransportError(
                        f"worker {self.name!r} sent a corrupt frame "
                        f"(crc {zlib.crc32(body):#010x} != "
                        f"{body_crc:#010x}) — rejected at decode")
                return body

    def call(self, payload: dict) -> np.ndarray:
        """Dispatch one job-batch payload; block for its streamed result."""
        if not self.alive:
            raise WorkerDied(f"worker {self.name!r} is not running (rc="
                             f"{self._proc.returncode})")
        blob = json.dumps({"kind": "batch", "payload": payload}).encode()
        self.dispatch_bytes += len(blob)
        try:
            write_frame(self._proc.stdin, blob)
        except (BrokenPipeError, OSError) as e:
            raise WorkerDied(f"worker {self.name!r} pipe broke on dispatch: "
                             f"{e}\n{self._drain_stderr()}") from None
        deadline = time.monotonic() + self.timeout
        head = json.loads(self._read_frame_deadline(deadline).decode())
        if head.get("kind") == "error":
            # the *payload* failed on a healthy worker: a job error, not a
            # transport fault — re-raise as the job-visible exception type
            raise RuntimeError(
                f"worker {self.name!r} batch failed: {head.get('error')}")
        if head.get("kind") != "result":
            raise TransportError(f"worker {self.name!r} sent unknown frame "
                                 f"{head.get('kind')!r}")
        out = array_from_frame(self._read_frame_deadline(deadline))
        self.batches += 1
        return out

    def kill(self) -> None:
        """Hard-kill (chaos / deadline path) — no shutdown handshake."""
        if self.alive:
            self._proc.kill()
        self._close_pipes()
        self._proc.wait(timeout=30)

    def close(self, timeout: float = 30.0) -> None:
        """Graceful shutdown: handshake, then wait; kill on overrun."""
        if self.alive:
            try:
                write_json(self._proc.stdin, SHUTDOWN)
                self._proc.stdin.close()
                self._proc.wait(timeout=timeout)
            except (BrokenPipeError, OSError, subprocess.TimeoutExpired):
                self._proc.kill()
                self._proc.wait(timeout=30)
        self._close_pipes()

    def _close_pipes(self) -> None:
        for pipe in (self._proc.stdin, self._proc.stdout):
            try:
                if pipe is not None:
                    pipe.close()
            except OSError:
                pass
        try:
            os.unlink(self._stderr_path)
        except OSError:
            pass


# ---------------------------------------------------------------------------
# the pool: elastic membership + chaos injection points
# ---------------------------------------------------------------------------

class LaneHealth:
    """Per-lane fault accounting: exponential respawn backoff with jitter,
    and a sliding fault window that turns a crash-looping lane into a
    :class:`~repro.runtime.faults.CrashLoopLane` instead of a hot respawn.

    Shared by :class:`WorkerPool` and any in-process pool stand-in (the
    fault-injection tests), so the quarantine policy is one implementation
    everywhere."""

    def __init__(self, backoff_base: float = 0.05, backoff_max: float = 2.0,
                 fault_window_s: float = 30.0,
                 max_faults_per_window: int = 5):
        self.backoff_base = backoff_base
        self.backoff_max = backoff_max
        self.fault_window_s = fault_window_s
        self.max_faults_per_window = max_faults_per_window
        self._faults: dict[str, list[float]] = {}
        self._streak: dict[str, int] = {}     # consecutive respawns per lane
        self.backoff_seconds = 0.0            # total backoff slept (telemetry)

    def record_fault(self, name: str, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        self._faults.setdefault(name, []).append(now)

    def record_success(self, name: str) -> None:
        self._streak.pop(name, None)

    def forgive(self, name: str) -> None:
        """Clear a lane's fault window and streak — called when the lane is
        quarantined (the cooldown IS the penalty; readmit starts clean)."""
        self._faults.pop(name, None)
        self._streak.pop(name, None)

    def window_faults(self, name: str, now: Optional[float] = None) -> int:
        now = time.monotonic() if now is None else now
        kept = [t for t in self._faults.get(name, ())
                if now - t <= self.fault_window_s]
        if kept:
            self._faults[name] = kept
        else:
            self._faults.pop(name, None)
        return len(kept)

    def check_respawn(self, name: str, now: Optional[float] = None) -> float:
        """Gate one respawn of ``name``: raises
        :class:`~repro.runtime.faults.CrashLoopLane` when the lane's fault
        window is exhausted, else returns the backoff delay (exponential
        in the consecutive-respawn streak, ±50% jitter) the caller should
        sleep before spawning."""
        from repro.runtime.faults import CrashLoopLane, Fault
        n_window = self.window_faults(name, now)
        if n_window >= self.max_faults_per_window:
            raise CrashLoopLane(Fault(
                kind="transport", lane=name,
                message=f"lane {name!r} crash-looping: {n_window} faults "
                        f"inside {self.fault_window_s}s — quarantine it "
                        f"(cooldown readmit) instead of respawning hot"))
        streak = self._streak.get(name, 0)
        self._streak[name] = streak + 1
        if streak == 0:
            return 0.0
        delay = min(self.backoff_base * (2 ** (streak - 1)), self.backoff_max)
        delay *= 0.5 + random.random()        # jitter: ±50%, decorrelates
        self.backoff_seconds += delay
        return delay

    def stats(self) -> dict:
        now = time.monotonic()
        return {"lane_window_faults": {n: self.window_faults(n, now)
                                       for n in sorted(self._faults)},
                "backoff_seconds": self.backoff_seconds}


class WorkerPool:
    """Named persistent workers, spawned/reaped on demand.

    The service's fleet lanes map 1:1 onto pool workers: ``add_worker`` →
    :meth:`spawn`, ``remove_worker`` → :meth:`reap`, one ``call`` per
    claimed batch.  ``injectors`` is the chaos seam: every entry may
    implement ``before(worker, payload) -> None | "drop" | "duplicate"``
    and/or ``after(worker, payload, result) -> None | "drop"`` — sleeps
    inside model delay, ``"drop"`` raises :class:`TransportError` (before:
    without executing; after: discarding a computed result), and
    ``"duplicate"`` delivers the payload twice (the worker executes both;
    results must agree bit-for-bit — idempotence, checked here).
    """

    def __init__(self, python: Optional[str] = None,
                 env: Optional[dict] = None, timeout: float = 600.0,
                 observer=None, health: Optional[LaneHealth] = None):
        self.python = python
        self.env = env
        self.timeout = timeout
        self.workers: dict[str, WorkerProcess] = {}
        self.injectors: list = []
        self.spawned = 0
        self.reaped = 0
        self.faults = 0               # TransportErrors surfaced to callers
        self.health = LaneHealth() if health is None else health
        # telemetry seam (repro.obs.metrics): optional callable invoked as
        # observer(event, ...) for transport_{spawn,reap,fault,dispatch,
        # result}; errors swallowed — telemetry never perturbs dispatch
        self.observer = observer

    def _emit(self, event: str, **fields) -> None:
        if self.observer is not None:
            try:
                self.observer(event, **fields)
            except Exception:          # noqa: BLE001 — see __init__
                pass

    def spawn(self, name: str) -> WorkerProcess:
        if name in self.workers and self.workers[name].alive:
            raise ValueError(f"worker {name!r} already running")
        w = WorkerProcess(name, python=self.python, env=self.env,
                          timeout=self.timeout)
        self.workers[name] = w
        self.spawned += 1
        self._emit("transport_spawn", worker=name)
        return w

    def reap(self, name: str, kill: bool = False) -> None:
        w = self.workers.pop(name, None)
        if w is None:
            return
        (w.kill if kill else w.close)()
        self.reaped += 1
        self._emit("transport_reap", worker=name)

    def respawn(self, name: str) -> WorkerProcess:
        """Replace a dead/hung worker under its stable lane name.

        Gated by :class:`LaneHealth`: consecutive respawns back off
        exponentially (with jitter) so a flapping lane doesn't hot-loop
        fork(), and a lane whose fault window is exhausted raises
        :class:`~repro.runtime.faults.CrashLoopLane` — the caller
        quarantines it (cooldown readmit) instead of respawning."""
        delay = self.health.check_respawn(name)   # may raise CrashLoopLane
        if delay > 0:
            time.sleep(delay)
        self.reap(name, kill=True)
        return self.spawn(name)

    def call(self, name: str, payload: dict) -> np.ndarray:
        w = self.workers.get(name)
        if w is None:
            raise WorkerDied(f"no worker {name!r} in the pool")
        try:
            actions = [inj.before(name, payload) for inj in self.injectors
                       if hasattr(inj, "before")]
            if "drop" in actions:
                raise TransportError(
                    f"payload to {name!r} dropped by injector")
            self._emit("transport_dispatch", worker=name,
                       nbytes=len(json.dumps(payload)))
            out = w.call(payload)
            if "duplicate" in actions:          # delivered twice: idempotent?
                again = w.call(payload)
                if not np.array_equal(out, again):
                    raise TransportError(
                        f"worker {name!r} is not idempotent: duplicate "
                        f"delivery produced different bits")
            for inj in self.injectors:
                if hasattr(inj, "after"):
                    if inj.after(name, payload, out) == "drop":
                        raise TransportError(
                            f"result from {name!r} dropped by injector")
            self._emit("transport_result", worker=name, nbytes=out.nbytes)
            self.health.record_success(name)
            return out
        except TransportError:
            self.faults += 1
            self.health.record_fault(name)
            self._emit("transport_fault", worker=name)
            raise

    def stats(self) -> dict:
        out = {"workers": len(self.workers),
               "spawned": self.spawned, "reaped": self.reaped,
               "faults": self.faults,
               "batches": {n: w.batches for n, w in self.workers.items()},
               "dispatch_bytes": sum(w.dispatch_bytes
                                     for w in self.workers.values())}
        out.update(self.health.stats())
        return out

    def close(self) -> None:
        for name in list(self.workers):
            self.reap(name)

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# the worker side (``python -m repro.runtime.transport``)
# ---------------------------------------------------------------------------

def serve(stdin, stdout) -> int:
    """The worker loop: frames in, results out, until shutdown/EOF.

    Sessions are cached per (store, config) cell across batches — the
    second batch of a job reuses the first's engine, prefetch pool, and
    jit cache, which is exactly what subprocess-per-batch could never do.
    """
    from repro.api.remote import execute_payload

    cache: dict = {}
    try:
        while True:
            try:
                msg = read_json(stdin)
            except WorkerDied:            # parent went away: clean exit
                return 0
            kind = msg.get("kind")
            if kind == "shutdown":
                return 0
            if kind != "batch":
                write_json(stdout, {"kind": "error",
                                    "error": f"unknown frame {kind!r}"})
                continue
            try:
                out = execute_payload(msg["payload"], cache=cache)
            except BaseException as e:    # noqa: BLE001 — shipped to caller
                write_json(stdout, {"kind": "error",
                                    "error": f"{type(e).__name__}: {e}"})
                continue
            write_json(stdout, {"kind": "result"})
            write_frame(stdout, array_to_frame(out))
    finally:
        for sess in cache.values():
            try:
                sess.close()
            except Exception:             # noqa: BLE001 — shutdown path
                pass


def _main() -> int:
    # claim the protocol stream BEFORE anything else can print: the real
    # stdout becomes ours exclusively, and fd 1 (plus sys.stdout writes
    # from imported libraries) is re-pointed at stderr so stray prints can
    # never corrupt a frame
    protocol_out = os.fdopen(os.dup(sys.stdout.fileno()), "wb")
    os.dup2(sys.stderr.fileno(), sys.stdout.fileno())
    return serve(sys.stdin.buffer, protocol_out)


if __name__ == "__main__":
    sys.exit(_main())
