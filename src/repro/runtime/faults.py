"""Structured fault taxonomy: one failure model for the whole stack.

Every failure the system can survive — a rotted Γ site file, a corrupted
wire frame, a worker death, a straggler reclaim, a payload that
deterministically kills its worker — folds into one structured
:class:`Fault` with a *kind* from a small closed set and enough context
(site / batch / lane / store) to act on it.  Faults ride job state
through ``SamplingService.stats()``, the gateway's job status, and
``MetricsRegistry`` labels, so an operator sees *what kind* of trouble a
fleet is in, not just "error".

Kinds (:data:`KINDS`):

* ``corruption`` — bytes failed verification: a Γ site file whose Merkle
  leaf digest mismatches the manifest, a torn npz, a wire payload whose
  checksum does not match.  The offending file is quarantined
  (``*.quarantine``) and, in sharded mode, repair from a healthy peer is
  attempted before the job is failed.
* ``transport`` — the fleet RPC plane faulted: worker death, dropped
  result, broken pipe.  The batch requeues and recomputes bit-identically
  (batch = f(seed, id)), bounded by ``max_batch_attempts``.
* ``poison`` — one payload repeatedly killed its worker: after
  ``max_batch_attempts`` the batch dead-letters its *job* instead of
  crash-looping the lane forever.
* ``timeout`` — a deadline fired: the RPC response deadline, or a
  straggler's claim reclaimed by the EWMA deadline.
* ``resource`` — the host ran out of something (memory, disk, fds).

Exception types: :class:`FaultError` is the common base — an exception
*carrying* a :class:`Fault`.  :class:`CorruptSegment` (data plane),
:class:`DeadLetter` (a job failed by bounded retries, carrying the full
:class:`FaultReport`), and :class:`CrashLoopLane` (a lane exceeding its
fault window) specialize it.  :func:`classify` folds foreign exception
types (``TransportError``, ``MemoryError``, ``TimeoutError``, ...) into
a :class:`Fault` so callers never branch on exception classes twice.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

#: the closed set of fault kinds — metrics label values, report keys
KINDS = ("corruption", "transport", "poison", "timeout", "resource")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One classified failure event with its blast-radius context."""
    kind: str
    message: str
    site: Optional[int] = None       # Γ chain site (data-plane faults)
    batch: Optional[int] = None      # macro batch id (fleet faults)
    lane: Optional[str] = None       # service lane / pool worker name
    store: Optional[str] = None      # GammaStore root (data-plane faults)
    at: float = dataclasses.field(default_factory=time.time)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")

    def to_dict(self) -> dict:
        """JSON-safe dict; context keys with no value are omitted."""
        out = {"kind": self.kind, "message": self.message, "at": self.at}
        for k in ("site", "batch", "lane", "store"):
            v = getattr(self, k)
            if v is not None:
                out[k] = v
        return out

    def with_context(self, **ctx) -> "Fault":
        """A copy with missing context fields filled in (never overwrites
        context the original fault already carries)."""
        updates = {k: v for k, v in ctx.items()
                   if v is not None and getattr(self, k, None) is None}
        return dataclasses.replace(self, **updates) if updates else self


@dataclasses.dataclass
class FaultReport:
    """The fault history of one job — what the gateway serves on job
    status and what a dead-lettered job fails with."""
    faults: list = dataclasses.field(default_factory=list)
    dead_letter: Optional[dict] = None   # {batch, attempts, kind} when poisoned

    def add(self, fault: Fault) -> None:
        self.faults.append(fault)

    def counts(self) -> dict:
        out = {k: 0 for k in KINDS}
        for f in self.faults:
            out[f.kind] += 1
        return out

    def to_dict(self) -> dict:
        return {"faults": [f.to_dict() for f in self.faults],
                "counts": self.counts(),
                "dead_letter": self.dead_letter}


class FaultError(RuntimeError):
    """An exception carrying a structured :class:`Fault`."""

    def __init__(self, fault: Fault):
        super().__init__(fault.message)
        self.fault = fault


class CorruptSegment(FaultError):
    """Bytes failed verification: digest mismatch, torn npz, or a wire
    payload whose checksum does not match.  kind=corruption."""


class DeadLetter(FaultError):
    """A batch exhausted ``max_batch_attempts`` and failed its job; the
    attached :attr:`report` is the job's full :class:`FaultReport`."""

    def __init__(self, fault: Fault, report: FaultReport):
        super().__init__(fault)
        self.report = report


class CrashLoopLane(FaultError):
    """A lane exceeded its fault window — quarantine it (with a cooldown
    readmit) instead of respawning it hot."""


def classify(exc: BaseException, **context) -> Optional[Fault]:
    """Fold an exception into a :class:`Fault`, or None for exceptions
    that are not infrastructure faults (a plain job error — bad config,
    a numerical assert — stays a job error).

    ``context`` (site= / batch= / lane= / store=) fills in whatever the
    exception itself did not record."""
    if isinstance(exc, FaultError):
        return exc.fault.with_context(**context)
    # lazy import: transport pulls in subprocess machinery; faults stays
    # importable from anywhere (checkpoint, data plane) without it
    from repro.runtime.transport import TransportError, WorkerDied
    if isinstance(exc, WorkerDied):
        return Fault(kind="transport", message=str(exc), **context)
    if isinstance(exc, TransportError):
        kind = "timeout" if "deadline" in str(exc) else "transport"
        return Fault(kind=kind, message=str(exc), **context)
    if isinstance(exc, (TimeoutError,)):
        return Fault(kind="timeout", message=str(exc), **context)
    if isinstance(exc, (MemoryError, OSError)):
        return Fault(kind="resource", message=f"{type(exc).__name__}: {exc}",
                     **context)
    return None


def dead_letter_kind(batch_faults: list) -> str:
    """The kind a dead-lettered batch fails with: ``poison`` when the
    payload repeatedly took its worker down (≥2 transport faults on one
    batch — the crash-loop signature), else the batch's dominant kind."""
    crashes = sum(1 for f in batch_faults if f.kind == "transport")
    if crashes >= 2:
        return "poison"
    if not batch_faults:
        return "transport"
    tally: dict[str, int] = {}
    for f in batch_faults:
        tally[f.kind] = tally.get(f.kind, 0) + 1
    return max(tally, key=lambda k: (tally[k], k))


__all__ = ["KINDS", "Fault", "FaultReport", "FaultError", "CorruptSegment",
           "DeadLetter", "CrashLoopLane", "classify", "dead_letter_kind"]
