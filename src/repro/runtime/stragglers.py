"""Straggler mitigation: deadline-based work stealing over the WorkQueue.

The [19] pipeline's Eq. 1 pays ``N·(max−mean)`` for stragglers — FastMPS's
data parallelism removes the structural coupling, and this module removes
the *statistical* tail: a batch that exceeds ``deadline = k × EWMA(batch
time)`` is reissued to an idle worker; first completion wins (idempotent
batches make duplicates harmless).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.runtime.elastic import WorkQueue


@dataclasses.dataclass
class StragglerMitigator:
    queue: WorkQueue
    k: float = 3.0                 # deadline multiplier
    ewma_alpha: float = 0.2
    _ewma: Optional[float] = None
    duplicates: int = 0            # instrumentation

    def observe_completion(self, duration: float) -> None:
        self._ewma = (duration if self._ewma is None
                      else self.ewma_alpha * duration + (1 - self.ewma_alpha) * self._ewma)

    @property
    def deadline(self) -> Optional[float]:
        return None if self._ewma is None else self.k * self._ewma

    def maybe_steal(self, idle_worker: str, now: Optional[float] = None) -> Optional[int]:
        """Give an idle worker a stale batch to duplicate, if any is late.

        ``reclaim_stale`` requeues every batch past the deadline (its old
        owner loses the claim — a late completion is rejected by the
        queue's ownership check); the first reclaimed batch is handed to
        the idle worker via :meth:`WorkQueue.steal`, the rest re-offer
        through normal claims."""
        if self.deadline is None:
            return None
        for b in self.queue.reclaim_stale(self.deadline, now):
            if self.queue.steal(b, idle_worker, now):
                self.duplicates += 1
                return b
        return None

    def stats(self) -> dict:
        """Instrumentation snapshot (merged into job ``progress``)."""
        return {"ewma_s": self._ewma, "deadline_s": self.deadline,
                "duplicates": self.duplicates}
