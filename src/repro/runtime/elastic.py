"""Elastic scaling: macro batches as an idempotent work queue.

The paper's data-parallel scheme makes every macro batch independent —
batch b is fully determined by (seed, b).  That property makes elasticity
trivial and *exact*: when the worker set changes (node loss, scale-up), the
pending batch ids are simply re-partitioned; completed work is never
recomputed, and results are independent of which worker ran what.

This is pure-Python control plane; the data plane (the jitted chain scan)
is untouched — the same split production serving systems use.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, Optional


def partition_batches(batch_ids: Iterable[int], workers: list[str]) -> dict[str, list[int]]:
    """Deterministic round-robin partition of pending batches over workers."""
    out: dict[str, list[int]] = {w: [] for w in workers}
    for i, b in enumerate(sorted(batch_ids)):
        out[workers[i % len(workers)]].append(b)
    return out


@dataclasses.dataclass
class BatchRecord:
    batch_id: int
    owner: Optional[str] = None
    started_at: Optional[float] = None
    done: bool = False


class WorkQueue:
    """Idempotent macro-batch queue with failure/elasticity semantics.

    * ``claim(worker)`` hands out the lowest unclaimed batch.
    * ``fail(worker)`` / ``remove_worker`` requeue everything the worker
      held (restart-exact: batch = f(seed, id)).
    * ``add_worker`` just makes the new worker eligible to claim.
    * ``reclaim_stale(timeout)`` is the straggler hook (see stragglers.py).
    """

    def __init__(self, n_batches: int, seed: int = 0):
        self.seed = seed
        self.records = {b: BatchRecord(b) for b in range(n_batches)}
        self.workers: set[str] = set()

    # -- membership ----------------------------------------------------------
    def add_worker(self, w: str) -> None:
        self.workers.add(w)

    def remove_worker(self, w: str) -> None:
        self.workers.discard(w)
        for r in self.records.values():
            if r.owner == w and not r.done:
                r.owner, r.started_at = None, None

    # -- work ----------------------------------------------------------------
    def claim(self, w: str, now: Optional[float] = None) -> Optional[int]:
        if w not in self.workers:
            self.add_worker(w)
        for b in sorted(self.records):
            r = self.records[b]
            if r.owner is None and not r.done:
                r.owner, r.started_at = w, (now if now is not None else time.monotonic())
                return b
        return None

    def complete(self, b: int) -> None:
        self.records[b].done = True

    def fail(self, w: str) -> None:
        self.remove_worker(w)

    def reclaim_stale(self, timeout: float, now: Optional[float] = None) -> list[int]:
        now = now if now is not None else time.monotonic()
        out = []
        for r in self.records.values():
            if r.owner is not None and not r.done and now - r.started_at > timeout:
                r.owner, r.started_at = None, None
                out.append(r.batch_id)
        return out

    @property
    def pending(self) -> list[int]:
        return [b for b, r in self.records.items() if not r.done]

    @property
    def finished(self) -> bool:
        return all(r.done for r in self.records.values())
