"""Elastic scaling: macro batches as an idempotent work queue.

The paper's data-parallel scheme makes every macro batch independent —
batch b is fully determined by (seed, b).  That property makes elasticity
trivial and *exact*: when the worker set changes (node loss, scale-up), the
pending batch ids are simply re-partitioned; completed work is never
recomputed, and results are independent of which worker ran what.

This is pure-Python control plane; the data plane (the jitted chain scan)
is untouched — the same split production serving systems use.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, Optional


def partition_batches(batch_ids: Iterable[int], workers: list[str]) -> dict[str, list[int]]:
    """Deterministic round-robin partition of pending batches over workers."""
    out: dict[str, list[int]] = {w: [] for w in workers}
    for i, b in enumerate(sorted(batch_ids)):
        out[workers[i % len(workers)]].append(b)
    return out


@dataclasses.dataclass
class BatchRecord:
    batch_id: int
    owner: Optional[str] = None
    started_at: Optional[float] = None
    done: bool = False
    attempts: int = 0     # hand-outs (claims + steals): the retry bound


class WorkQueue:
    """Idempotent macro-batch queue with failure/elasticity semantics.

    * ``claim(worker)`` re-offers requeued batches (FIFO) before handing out
      the lowest fresh unclaimed batch — work orphaned by a worker loss is
      never starved behind a long tail of fresh batches.
    * ``fail(worker)`` / ``remove_worker`` requeue everything the worker
      held (restart-exact: batch = f(seed, id)).
    * ``complete(b, worker=...)`` with a worker is ownership-checked: a
      removed worker's late completion of a batch that was requeued (and may
      be recomputed elsewhere) is rejected instead of double-counted —
      results are identical either way, but the queue's accounting must
      attribute the batch to its current owner.
    * ``add_worker`` just makes the new worker eligible to claim.
    * ``reclaim_stale(timeout)`` is the straggler hook (see stragglers.py).
    * ``stats()`` is the progress snapshot service layers surface — a flat
      dict with a STABLE schema: ``total``/``done``/``claimed``/
      ``requeued``/``pending``/``claims``/``requeues``/``workers``, every
      key always present (zero on an idle queue).
    * ``observer`` is the telemetry seam (``repro.obs.metrics``): an
      optional callable invoked as ``observer(event, batch=b, worker=w)``
      for ``claim`` / ``requeue`` / ``complete`` / ``steal``.  Observer
      errors are swallowed — telemetry must never perturb scheduling.
    """

    def __init__(self, n_batches: int, seed: int = 0, observer=None):
        self.seed = seed
        self.observer = observer
        self.records = {b: BatchRecord(b) for b in range(n_batches)}
        self.workers: set[str] = set()
        self._requeued: list[int] = []     # FIFO of re-offer-first batch ids
        self._claims = 0
        self._requeues = 0

    def _emit(self, event: str, **fields) -> None:
        if self.observer is not None:
            try:
                self.observer(event, **fields)
            except Exception:              # noqa: BLE001 — see class docstring
                pass

    # -- membership ----------------------------------------------------------
    def add_worker(self, w: str) -> None:
        self.workers.add(w)

    def _requeue(self, r: BatchRecord) -> None:
        r.owner, r.started_at = None, None
        if r.batch_id not in self._requeued:
            self._requeued.append(r.batch_id)
            self._requeues += 1
            self._emit("requeue", batch=r.batch_id)

    def remove_worker(self, w: str) -> None:
        self.workers.discard(w)
        for r in self.records.values():
            if r.owner == w and not r.done:
                self._requeue(r)

    # -- work ----------------------------------------------------------------
    def _hand_out(self, r: BatchRecord, w: str, now: Optional[float]) -> int:
        r.owner = w
        r.started_at = now if now is not None else time.monotonic()
        r.attempts += 1
        self._claims += 1
        self._emit("claim", batch=r.batch_id, worker=w)
        return r.batch_id

    def attempts(self, b: int) -> int:
        """Hand-out count of batch ``b`` — what the service's bounded-retry
        / dead-letter policy (``max_batch_attempts``) is measured against."""
        return self.records[b].attempts

    def claim(self, w: str, now: Optional[float] = None) -> Optional[int]:
        if w not in self.workers:
            self.add_worker(w)
        while self._requeued:              # orphaned work first, FIFO
            r = self.records[self._requeued[0]]
            if r.owner is not None or r.done:   # raced/stale entry
                self._requeued.pop(0)
                continue
            self._requeued.pop(0)
            return self._hand_out(r, w, now)
        for b in sorted(self.records):
            r = self.records[b]
            if r.owner is None and not r.done:
                return self._hand_out(r, w, now)
        return None

    def complete(self, b: int, worker: Optional[str] = None) -> bool:
        """Mark batch ``b`` done; returns whether the completion counted.

        With ``worker`` given, a completion from a worker that no longer
        owns the batch (it was removed and the batch requeued) is rejected
        — the caller should discard its result and let the current owner's
        identical recomputation land instead.  A batch completes at most
        once: the second delivery of a duplicated batch (straggler
        reissue, transport replay) reports False so it is never
        double-counted."""
        r = self.records[b]
        if r.done:
            return False
        if worker is not None and r.owner != worker:
            return False
        r.done = True
        r.owner = None
        self._emit("complete", batch=b, worker=worker)
        return True

    def fail(self, w: str) -> None:
        self.remove_worker(w)

    def steal(self, b: int, w: str, now: Optional[float] = None) -> bool:
        """Reassign a reclaimed (unowned, not-done) batch to ``w`` — the
        straggler-duplicate path.  Counts as a claim and drops the batch
        from the re-offer FIFO, so ordinary ``claim`` calls won't hand the
        same batch out a second time."""
        r = self.records[b]
        if r.done or r.owner is not None:
            return False
        if b in self._requeued:
            self._requeued.remove(b)
        if w not in self.workers:
            self.add_worker(w)
        self._hand_out(r, w, now)
        self._emit("steal", batch=b, worker=w)
        return True

    def reclaim_stale(self, timeout: float, now: Optional[float] = None) -> list[int]:
        now = now if now is not None else time.monotonic()
        out = []
        for r in self.records.values():
            if r.owner is not None and not r.done and now - r.started_at > timeout:
                self._requeue(r)
                out.append(r.batch_id)
        return out

    def stats(self) -> dict:
        """Progress snapshot: the counts a service's ``progress`` reports."""
        done = sum(r.done for r in self.records.values())
        claimed = sum(r.owner is not None and not r.done
                      for r in self.records.values())
        return {"total": len(self.records), "done": done, "claimed": claimed,
                "requeued": len([b for b in self._requeued
                                 if not self.records[b].done]),
                "pending": len(self.records) - done,
                "claims": self._claims, "requeues": self._requeues,
                "workers": len(self.workers)}

    @property
    def pending(self) -> list[int]:
        return [b for b, r in self.records.items() if not r.done]

    @property
    def finished(self) -> bool:
        return all(r.done for r in self.records.values())
