from repro.runtime.elastic import WorkQueue, partition_batches
from repro.runtime.stragglers import StragglerMitigator
