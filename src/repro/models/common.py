"""Shared building blocks for the LM zoo.

Conventions
-----------
* Parameters are plain pytrees (nested dicts of arrays).  Every init
  function returns ``(params, specs)`` where ``specs`` mirrors ``params``
  with a ``PartitionSpec`` per leaf — the MaxText "logical axis" idea
  without the indirection.  Mesh axes: ``("pod", "data", "model")`` or
  ``("data", "model")``; DATA below expands to the data-like axes.
* All models expose ``init(cfg, key|abstract)``, ``train_step`` /
  ``serve_step`` builders in ``transformer.py``.
* Repeated identical layers are **stacked on a leading axis and scanned**
  (compile time O(1) in depth; remat policy applied per layer).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array
Pytree = Any

# Logical sharding vocabulary.  launch/mesh.py resolves these to mesh axes:
#   "embed"  -> "model"      (d_model is *not* sharded by default; see below)
#   "heads", "ffn", "expert", "vocab" -> "model"
#   "batch"  -> ("pod", "data") / ("data",)
# We keep raw PartitionSpecs here with the *mesh* axis names and a DATA
# placeholder tuple that mesh.py rewrites for 2- vs 3-axis meshes.
DATA = "__data__"          # placeholder for ("pod","data") or ("data",)
MODEL = "model"


def spec(*axes) -> P:
    return P(*axes)


# ---------------------------------------------------------------------------
# Activation-sharding context (MaxText's logical-axis rules, minimal form).
#
# FSDP-sharded weights tempt GSPMD into split-K contractions over the *data*
# axes, which replicates the batch and all-reduces giant attention
# intermediates (measured: 74 TB/step on deepseek-v3 train_4k — §Perf
# iteration moe-2).  Pinning the batch axis of the per-layer activations
# forces the all-gather-weights FSDP schedule instead.
# ---------------------------------------------------------------------------

_MESH_CTX: dict = {"data": None, "model": None}


def set_mesh_axes(data_axes, model_axis: str = "model") -> None:
    """Declare the mesh axes activations should be constrained to.

    Call before tracing (launch/dryrun.py, launch/train.py); tests and
    single-device runs leave it unset -> constraints are no-ops.
    """
    _MESH_CTX["data"] = tuple(data_axes) if data_axes else None
    _MESH_CTX["model"] = model_axis


def clear_mesh_axes() -> None:
    _MESH_CTX["data"] = None
    _MESH_CTX["model"] = None


def batch_sharded(x: Array) -> Array:
    """Constrain dim 0 (batch) to the data axes; no-op without context."""
    d = _MESH_CTX["data"]
    if d is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(*([d] + [None] * (x.ndim - 1))))


def shard_hint(x: Array, *logical) -> Array:
    """Constrain dims to logical axes: 'data' | 'model' | None per dim."""
    d = _MESH_CTX["data"]
    if d is None:
        return x
    m = _MESH_CTX["model"]
    spec_ = [d if ax == "data" else (m if ax == "model" else None)
             for ax in logical]
    spec_ += [None] * (x.ndim - len(spec_))
    return jax.lax.with_sharding_constraint(x, P(*spec_))


def resolve_specs(tree: Pytree, data_axes: tuple[str, ...]) -> Pytree:
    """Rewrite DATA placeholders for the concrete mesh."""
    def fix(s):
        if not isinstance(s, P):
            return s
        out = []
        for ax in s:
            if ax == DATA:
                out.append(data_axes if len(data_axes) > 1 else data_axes[0])
            else:
                out.append(ax)
        return P(*out)
    return jax.tree_util.tree_map(
        fix, tree, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Initializers (used both concretely and under jax.eval_shape for dry-runs)
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> Array:
    scale = 1.0 / math.sqrt(in_dim)
    return jax.random.uniform(key, (in_dim, out_dim), dtype, -scale, scale)


def embed_init(key, vocab: int, dim: int, dtype) -> Array:
    return jax.random.normal(key, (vocab, dim), dtype) * 0.02


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------

def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x: Array, w_up: Array, b_up: Array, w_down: Array, b_down: Array) -> Array:
    return jax.nn.gelu(x @ w_up + b_up, approximate=True) @ w_down + b_down


def mlp_init(key, d_model: int, d_ff: int, dtype, style: str = "swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    if style == "swiglu":
        params = {
            "gate": dense_init(k1, d_model, d_ff, dtype),
            "up": dense_init(k2, d_model, d_ff, dtype),
            "down": dense_init(k3, d_ff, d_model, dtype),
        }
        specs = {"gate": P(None, MODEL), "up": P(None, MODEL),
                 "down": P(MODEL, None)}
    else:  # gelu (whisper-style, with biases)
        params = {
            "up": dense_init(k1, d_model, d_ff, dtype),
            "b_up": jnp.zeros((d_ff,), dtype),
            "down": dense_init(k2, d_ff, d_model, dtype),
            "b_down": jnp.zeros((d_model,), dtype),
        }
        specs = {"up": P(None, MODEL), "b_up": P(MODEL),
                 "down": P(MODEL, None), "b_down": P(None)}
    return params, specs


def mlp_apply(params, x, style: str = "swiglu"):
    if style == "swiglu":
        return swiglu(x, params["gate"], params["up"], params["down"])
    return gelu_mlp(x, params["up"], params["b_up"], params["down"], params["b_down"])


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, max_pos: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_pos, dtype=jnp.float32)
    ang = jnp.outer(t, inv)                        # (max_pos, head_dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x (..., S, H, Dh); positions (..., S) int32.  Rotates pairwise halves."""
    dh = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    ang = positions[..., None].astype(jnp.float32) * inv      # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------

def softmax_xent(logits: Array, labels: Array, mask: Optional[Array] = None):
    """Mean next-token cross entropy.  logits (B,S,V) fp32, labels (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Remat policy
# ---------------------------------------------------------------------------

def remat(fn: Callable, policy: str = "nothing") -> Callable:
    if policy == "nothing":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if policy == "none":
        return fn
    raise ValueError(policy)
