"""Config-driven model zoo assembly: one code path, ten architectures.

Families
--------
dense   : [attn + swiglu] × L                      (qwen, deepseek-7b,
          starcoder2, granite)
moe     : [attn|MLA + MoE] × L                     (kimi-k2, deepseek-v3)
ssm     : [mamba2] × L                             (mamba2-1.3b)
hybrid  : [mamba2] × L with a *shared* attention   (zamba2-7b)
          block applied every ``attn_every`` layers
encdec  : whisper — encoder [attn+mlp] × Lₑ, decoder [attn+cross+mlp] × L
vlm     : llama-3.2-vision — dense stack with cross-attention to patch
          embeddings every ``cross_attn_every`` layers

All repeated stacks are **scanned over stacked params** (O(1) compile in
depth, remat per layer).  The decode path carries a cache pytree — KV
(attention), latent (MLA) or SSM state — which is the LM analogue of the
FastMPS left environment (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as A
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models.common import (DATA, MODEL, batch_sharded, embed_init,
                                 mlp_apply, mlp_init, remat, rms_norm,
                                 softmax_xent)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 → d_model // n_heads
    qkv_bias: bool = False
    mlp_style: str = "swiglu"
    # moe
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    use_mla: bool = False
    # ssm / hybrid
    ssm_state: int = 0
    ssm_head: int = 64
    attn_every: int = 6          # hybrid: shared attn cadence
    # encdec / vlm
    n_enc_layers: int = 0
    enc_len: int = 1500          # whisper frame count (stub frontend)
    cross_attn_every: int = 0    # vlm cadence
    n_patches: int = 1600        # vlm patch count (stub frontend)
    # numerics
    dtype: Any = jnp.bfloat16
    remat_policy: str = "dots"
    remat_block: int = 0         # >0: sqrt-L block remat — scan over L/k
                                 # blocks of k layers, checkpoint block
                                 # inputs only (saved acts ~ (L/k + k)·x
                                 # instead of L·x; §Perf iteration mem-1)
    rope_theta: float = 10000.0

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def attn_cfg(self, causal: bool = True, rope: bool = True) -> A.AttnConfig:
        return A.AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                            self.hd, self.qkv_bias, rope, self.rope_theta,
                            causal)

    def mla_cfg(self) -> A.MLAConfig:
        return A.MLAConfig(self.d_model, self.n_heads, head_dim=self.hd,
                           rope_head_dim=64, q_lora_rank=1536,
                           kv_lora_rank=512)

    def moe_cfg(self) -> MOE.MoEConfig:
        return MOE.MoEConfig(self.d_model, self.d_ff, self.n_experts,
                             self.top_k, self.n_shared_experts,
                             self.capacity_factor)

    def ssm_cfg(self) -> M2.Mamba2Config:
        return M2.Mamba2Config(self.d_model, self.ssm_state, self.ssm_head)

    @property
    def has_attn(self) -> bool:
        return self.family != "ssm"

    @property
    def supports_long_context(self) -> bool:
        return self.family in ("ssm", "hybrid")

    # -- parameter counts for roofline MODEL_FLOPS --------------------------
    def param_count(self) -> tuple[int, int]:
        """(total, active) parameter counts (embedding included once)."""
        dm, dff, hd = self.d_model, self.d_ff, self.hd
        attn = dm * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.use_mla:
            attn = dm * 1536 + 1536 * self.n_heads * (hd + 64) \
                + dm * (512 + 64) + 512 * self.n_heads * hd * 2 \
                + self.n_heads * hd * dm
        mlp = 3 * dm * dff if self.mlp_style == "swiglu" else 2 * dm * dff
        per_layer_dense = attn + mlp
        emb = self.vocab * dm * 2
        if self.family == "dense":
            total = self.n_layers * per_layer_dense + emb
            return total, total
        if self.family == "moe":
            experts = self.n_experts * 3 * dm * dff
            shared = self.n_shared_experts * 3 * dm * dff
            router = dm * self.n_experts
            per = attn + experts + shared + router
            total = self.n_layers * per + emb
            act = self.n_layers * (attn + (self.top_k + self.n_shared_experts)
                                   * 3 * dm * dff + router) + emb
            return total, act
        if self.family in ("ssm", "hybrid"):
            c = self.ssm_cfg()
            per = dm * (2 * c.d_inner + 2 * c.n_groups * c.d_state + c.heads) \
                + c.d_inner * dm
            total = self.n_layers * per + emb
            if self.family == "hybrid":
                total += attn + mlp    # one shared block
            return total, total
        if self.family == "encdec":
            total = (self.n_layers * (2 * attn + mlp)
                     + self.n_enc_layers * (attn + mlp) + emb)
            return total, total
        if self.family == "vlm":
            n_cross = self.n_layers // self.cross_attn_every
            total = self.n_layers * per_layer_dense + n_cross * attn + emb
            return total, total
        raise ValueError(self.family)


# ===========================================================================
# Parameter init (runs under jax.eval_shape for the dry-run)
# ===========================================================================

def _stacked(fn, key, n, *args):
    """Init n stacked copies of a layer; returns (params, specs_with_leading_None)."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: fn(k, *args)[0])(keys)
    _, specs = fn(key, *args)
    specs = jax.tree_util.tree_map(
        lambda s: P(*((None,) + tuple(s))), specs,
        is_leaf=lambda x: isinstance(x, P))
    return params, specs


def _layer_init_dense(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    ap, as_ = A.attn_init(k1, cfg.attn_cfg(), dtype)
    mp, ms = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_style)
    params = {"attn": ap, "mlp": mp,
              "ln1": jnp.ones((cfg.d_model,), dtype),
              "ln2": jnp.ones((cfg.d_model,), dtype)}
    specs = {"attn": as_, "mlp": ms, "ln1": P(None), "ln2": P(None)}
    return params, specs


def _layer_init_moe(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    if cfg.use_mla:
        ap, as_ = A.mla_init(k1, cfg.mla_cfg(), dtype)
    else:
        ap, as_ = A.attn_init(k1, cfg.attn_cfg(), dtype)
    mp, ms = MOE.moe_init(k2, cfg.moe_cfg(), dtype)
    params = {"attn": ap, "moe": mp,
              "ln1": jnp.ones((cfg.d_model,), dtype),
              "ln2": jnp.ones((cfg.d_model,), dtype)}
    specs = {"attn": as_, "moe": ms, "ln1": P(None), "ln2": P(None)}
    return params, specs


def _layer_init_ssm(key, cfg: ModelConfig, dtype):
    mp, ms = M2.mamba2_init(key, cfg.ssm_cfg(), dtype)
    params = {"mamba": mp, "ln": jnp.ones((cfg.d_model,), dtype)}
    specs = {"mamba": ms, "ln": P(None)}
    return params, specs


def _layer_init_encdec_dec(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    sp, ss = A.attn_init(k1, cfg.attn_cfg(causal=True, rope=False), dtype)
    cp, cs = A.attn_init(k2, cfg.attn_cfg(causal=False, rope=False), dtype)
    mp, ms = mlp_init(k3, cfg.d_model, cfg.d_ff, dtype, "gelu")
    params = {"self": sp, "cross": cp, "mlp": mp,
              "ln1": jnp.ones((cfg.d_model,), dtype),
              "ln2": jnp.ones((cfg.d_model,), dtype),
              "ln3": jnp.ones((cfg.d_model,), dtype)}
    specs = {"self": ss, "cross": cs, "mlp": ms,
             "ln1": P(None), "ln2": P(None), "ln3": P(None)}
    return params, specs


def _layer_init_enc(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    ap, as_ = A.attn_init(k1, cfg.attn_cfg(causal=False, rope=False), dtype)
    mp, ms = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, "gelu")
    params = {"attn": ap, "mlp": mp,
              "ln1": jnp.ones((cfg.d_model,), dtype),
              "ln2": jnp.ones((cfg.d_model,), dtype)}
    specs = {"attn": as_, "mlp": ms, "ln1": P(None), "ln2": P(None)}
    return params, specs


def init_params(key, cfg: ModelConfig):
    """Returns (params, specs).  Call under jax.eval_shape for dry-runs."""
    dtype = cfg.dtype
    ke, kl, ko, kx = jax.random.split(key, 4)
    params: dict = {"embed": embed_init(ke, cfg.vocab, cfg.d_model, dtype),
                    "ln_f": jnp.ones((cfg.d_model,), dtype),
                    "unembed": embed_init(ko, cfg.vocab, cfg.d_model, dtype).T}
    specs: dict = {"embed": P(MODEL, None), "ln_f": P(None),
                   "unembed": P(None, MODEL)}

    if cfg.family == "dense":
        params["layers"], specs["layers"] = _stacked(
            _layer_init_dense, kl, cfg.n_layers, cfg, dtype)
    elif cfg.family == "moe":
        params["layers"], specs["layers"] = _stacked(
            _layer_init_moe, kl, cfg.n_layers, cfg, dtype)
    elif cfg.family == "ssm":
        params["layers"], specs["layers"] = _stacked(
            _layer_init_ssm, kl, cfg.n_layers, cfg, dtype)
    elif cfg.family == "hybrid":
        params["layers"], specs["layers"] = _stacked(
            _layer_init_ssm, kl, cfg.n_layers, cfg, dtype)
        sp, ss = A.attn_init(kx, cfg.attn_cfg(), dtype)
        mp, ms = mlp_init(jax.random.fold_in(kx, 1), cfg.d_model, cfg.d_ff,
                          dtype, cfg.mlp_style)
        params["shared_attn"] = {"attn": sp, "mlp": mp,
                                 "ln1": jnp.ones((cfg.d_model,), dtype),
                                 "ln2": jnp.ones((cfg.d_model,), dtype)}
        specs["shared_attn"] = {"attn": ss, "mlp": ms,
                                "ln1": P(None), "ln2": P(None)}
    elif cfg.family == "encdec":
        params["enc_layers"], specs["enc_layers"] = _stacked(
            _layer_init_enc, kx, cfg.n_enc_layers, cfg, dtype)
        params["layers"], specs["layers"] = _stacked(
            _layer_init_encdec_dec, kl, cfg.n_layers, cfg, dtype)
        params["enc_ln_f"] = jnp.ones((cfg.d_model,), dtype)
        specs["enc_ln_f"] = P(None)
        params["enc_pos"] = embed_init(
            jax.random.fold_in(ke, 2), cfg.enc_len, cfg.d_model, dtype)
        specs["enc_pos"] = P(None, None)
        params["dec_pos"] = embed_init(
            jax.random.fold_in(ke, 3), 32768, cfg.d_model, dtype)
        specs["dec_pos"] = P(None, None)
    elif cfg.family == "vlm":
        params["layers"], specs["layers"] = _stacked(
            _layer_init_dense, kl, cfg.n_layers, cfg, dtype)
        n_cross = cfg.n_layers // cfg.cross_attn_every
        params["cross_layers"], specs["cross_layers"] = _stacked(
            lambda k, c, d: A.attn_init(k, c.attn_cfg(causal=False, rope=False), d),
            kx, n_cross, cfg, dtype)
        params["ln_cross"] = jnp.ones((n_cross, cfg.d_model), dtype)
        specs["ln_cross"] = P(None, None)
    else:
        raise ValueError(cfg.family)
    return params, specs


# ===========================================================================
# Forward passes
# ===========================================================================

def _scan_blocks(body, x, layers, cfg: ModelConfig):
    """Scan over layers with optional sqrt-L block remat (remat_block = k).

    k = 0 → the plain per-layer remat policy.  k > 0 → the stacked layer
    params are reshaped to (L/k, k, …); the outer scan checkpoints only the
    L/k block inputs and the inner k-layer scan recomputes inside each
    block during the backward pass: peak saved activations drop from L·x
    to (L/k + k)·x at the cost of one extra forward.
    """
    k = cfg.remat_block
    if not k:
        x, _ = jax.lax.scan(remat(body, cfg.remat_policy), x, layers)
        return x
    L = cfg.n_layers
    assert L % k == 0, (L, k)
    blocked = jax.tree_util.tree_map(
        lambda a: a.reshape(L // k, k, *a.shape[1:]), layers)

    def block_body(xc, blk):
        # per-layer remat *inside* the block too: otherwise the in-block
        # backward keeps every layer's attention S² intermediates live at
        # once (measured: 177 GB/device on starcoder2 — §Perf mem-1)
        xc, _ = jax.lax.scan(remat(body, cfg.remat_policy), xc, blk)
        return xc, None

    x, _ = jax.lax.scan(remat(block_body, "nothing"), x, blocked)
    return x

def _dense_block(lp, x, cfg: ModelConfig, positions, cache=None):
    acfg = cfg.attn_cfg()
    h, new_cache = A.attn_apply(lp["attn"], rms_norm(x, lp["ln1"]), acfg,
                                positions, cache)
    x = x + h
    x = x + mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"]), cfg.mlp_style)
    return x, new_cache


def _moe_block(lp, x, cfg: ModelConfig, positions, cache=None):
    if cfg.use_mla:
        h, new_cache = A.mla_apply(lp["attn"], rms_norm(x, lp["ln1"]),
                                   cfg.mla_cfg(), positions, cache)
    else:
        h, new_cache = A.attn_apply(lp["attn"], rms_norm(x, lp["ln1"]),
                                    cfg.attn_cfg(), positions, cache)
    x = x + h
    y, aux = MOE.moe_apply(lp["moe"], rms_norm(x, lp["ln2"]), cfg.moe_cfg())
    return x + y, new_cache, aux


def forward(params, tokens: Array, cfg: ModelConfig,
            extra: Optional[dict] = None) -> Array:
    """Full-sequence forward (train / prefill).  Returns logits (B,S,V)."""
    extra = extra or {}
    B, S = tokens.shape
    x = params["embed"][tokens]           # gather; embed sharded over vocab
    positions = jnp.arange(S)[None, :]
    aux_acc = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense",):
        def body(x, lp):
            x = batch_sharded(x)
            x, _ = _dense_block(lp, x, cfg, positions)
            return x, None
        x = _scan_blocks(body, x, params["layers"], cfg)

    elif cfg.family == "moe":
        def body(carry, lp):
            x, aux = carry
            x = batch_sharded(x)
            x, _, a = _moe_block(lp, x, cfg, positions)
            return (x, aux + a["lb_loss"]), None
        (x, aux_acc), _ = jax.lax.scan(
            remat(body, cfg.remat_policy), (x, aux_acc), params["layers"])

    elif cfg.family == "ssm":
        scfg = cfg.ssm_cfg()
        def body(x, lp):
            x = batch_sharded(x)
            h, _ = M2.mamba2_apply(lp["mamba"], rms_norm(x, lp["ln"]), scfg)
            return x + h, None
        x, _ = jax.lax.scan(remat(body, cfg.remat_policy), x, params["layers"])

    elif cfg.family == "hybrid":
        scfg = cfg.ssm_cfg()
        shared = params["shared_attn"]
        is_attn = (jnp.arange(cfg.n_layers) % cfg.attn_every) == (cfg.attn_every - 1)
        def body(x, xs):
            lp, use_attn = xs
            x = batch_sharded(x)
            h, _ = M2.mamba2_apply(lp["mamba"], rms_norm(x, lp["ln"]), scfg)
            x = x + h
            def with_attn(x):
                y, _ = _dense_block(shared, x, cfg, positions)
                return y
            x = jax.lax.cond(use_attn, with_attn, lambda x: x, x)
            return x, None
        x, _ = jax.lax.scan(remat(body, cfg.remat_policy), x,
                            (params["layers"], is_attn))

    elif cfg.family == "encdec":
        frames = extra["frames"]          # (B, T_enc, D) stub frontend output
        e = frames + params["enc_pos"][None, :frames.shape[1]]
        def ebody(e, lp):
            e = batch_sharded(e)
            acfg = cfg.attn_cfg(causal=False, rope=False)
            h, _ = A.attn_apply(lp["attn"], rms_norm(e, lp["ln1"]), acfg)
            e = e + h
            e = e + mlp_apply(lp["mlp"], rms_norm(e, lp["ln2"]), "gelu")
            return e, None
        e, _ = jax.lax.scan(remat(ebody, cfg.remat_policy), e, params["enc_layers"])
        enc_out = rms_norm(e, params["enc_ln_f"])

        x = x + params["dec_pos"][None, :S]
        def dbody(x, lp):
            x = batch_sharded(x)
            sa = cfg.attn_cfg(causal=True, rope=False)
            ca = cfg.attn_cfg(causal=False, rope=False)
            h, _ = A.attn_apply(lp["self"], rms_norm(x, lp["ln1"]), sa, positions)
            x = x + h
            h, _ = A.attn_apply(lp["cross"], rms_norm(x, lp["ln2"]), ca,
                                positions, kv_input=enc_out)
            x = x + h
            x = x + mlp_apply(lp["mlp"], rms_norm(x, lp["ln3"]), "gelu")
            return x, None
        x, _ = jax.lax.scan(remat(dbody, cfg.remat_policy), x, params["layers"])

    elif cfg.family == "vlm":
        patches = extra["patches"]        # (B, n_patches, D) stub frontend
        every = cfg.cross_attn_every
        n_cross = cfg.n_layers // every
        idx_of_layer = jnp.arange(cfg.n_layers) // every
        is_cross = (jnp.arange(cfg.n_layers) % every) == (every - 1)
        # cross params are stacked (n_cross, ...); select per layer via gather
        def body(x, xs):
            lp, use_cross, ci = xs
            x = batch_sharded(x)
            x, _ = _dense_block(lp, x, cfg, positions)
            cp = jax.tree_util.tree_map(lambda a: a[jnp.minimum(ci, n_cross - 1)],
                                        params["cross_layers"])
            lnc = params["ln_cross"][jnp.minimum(ci, n_cross - 1)]
            def with_cross(x):
                acfg = cfg.attn_cfg(causal=False, rope=False)
                h, _ = A.attn_apply(cp, rms_norm(x, lnc), acfg,
                                    kv_input=patches)
                return x + h
            x = jax.lax.cond(use_cross, with_cross, lambda x: x, x)
            return x, None
        x, _ = jax.lax.scan(remat(body, cfg.remat_policy), x,
                            (params["layers"], is_cross, idx_of_layer))
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["ln_f"])
    logits = x @ params["unembed"]
    return logits, aux_acc


# ===========================================================================
# Decode (serve) path.  Caches are plain dicts of stacked arrays so the
# pytree structure is identical before/after every step (stable jit cache).
# ===========================================================================

class DecodeState(NamedTuple):
    caches: Any          # dict of stacked arrays (see init_decode_state)
    position: Array      # () int32


def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int) -> DecodeState:
    dt = cfg.dtype
    L = cfg.n_layers
    kvh, hd = cfg.n_kv_heads, cfg.hd

    def kv(nl):
        return {"k": jnp.zeros((nl, batch, cache_len, kvh, hd), dt),
                "v": jnp.zeros((nl, batch, cache_len, kvh, hd), dt)}

    if cfg.family in ("dense", "vlm", "encdec"):
        caches = kv(L)
    elif cfg.family == "moe":
        if cfg.use_mla:
            m = cfg.mla_cfg()
            caches = {"latent": jnp.zeros(
                (L, batch, cache_len, m.kv_lora_rank + m.rope_head_dim), dt)}
        else:
            caches = kv(L)
    elif cfg.family == "ssm":
        c = cfg.ssm_cfg()
        caches = {"state": jnp.zeros(
            (L, batch, c.heads, c.d_head, c.d_state), jnp.float32)}
    elif cfg.family == "hybrid":
        c = cfg.ssm_cfg()
        n_attn = max(1, L // cfg.attn_every)
        caches = {"state": jnp.zeros(
                      (L, batch, c.heads, c.d_head, c.d_state), jnp.float32),
                  **{k: v for k, v in kv(n_attn).items()}}
    else:
        raise ValueError(cfg.family)
    return DecodeState(caches, jnp.zeros((), jnp.int32))


def decode_step(params, tokens: Array, state: DecodeState, cfg: ModelConfig,
                extra: Optional[dict] = None):
    """One decode step: tokens (B, 1) → logits (B, 1, V), new state."""
    extra = extra or {}
    B = tokens.shape[0]
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(state.position[None, None], (B, 1))
    pos = state.position

    if cfg.family == "encdec":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], pos, 1, axis=0)[None]

    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        def body(x, xs):
            lp, cache_l = xs
            if cfg.family == "moe":
                if cfg.use_mla:
                    cache = A.MLACache(cache_l["latent"], pos)
                else:
                    cache = A.KVCache(cache_l["k"], cache_l["v"], pos)
                x2, new_c, _ = _moe_block(lp, x, cfg, positions, cache)
                new_l = ({"latent": new_c.latent} if cfg.use_mla
                         else {"k": new_c.k, "v": new_c.v})
            elif cfg.family == "encdec":
                cache = A.KVCache(cache_l["k"], cache_l["v"], pos)
                sa = cfg.attn_cfg(causal=True, rope=False)
                h, new_c = A.attn_apply(lp["self"], rms_norm(x, lp["ln1"]), sa,
                                        positions, cache)
                x2 = x + h
                ca = cfg.attn_cfg(causal=False, rope=False)
                h, _ = A.attn_apply(lp["cross"], rms_norm(x2, lp["ln2"]), ca,
                                    positions, kv_input=extra["enc_out"])
                x2 = x2 + h
                x2 = x2 + mlp_apply(lp["mlp"], rms_norm(x2, lp["ln3"]), "gelu")
                new_l = {"k": new_c.k, "v": new_c.v}
            else:
                cache = A.KVCache(cache_l["k"], cache_l["v"], pos)
                x2, new_c = _dense_block(lp, x, cfg, positions, cache)
                new_l = {"k": new_c.k, "v": new_c.v}
            return x2, new_l

        x, new_caches = jax.lax.scan(body, x, (params["layers"], state.caches))

        if cfg.family == "vlm":
            patches = extra["patches"]
            acfg = cfg.attn_cfg(causal=False, rope=False)
            def cbody(x, xs):
                cp, lnc = xs
                h, _ = A.attn_apply(cp, rms_norm(x, lnc), acfg, kv_input=patches)
                return x + h, None
            x, _ = jax.lax.scan(cbody, x,
                                (params["cross_layers"], params["ln_cross"]))

    elif cfg.family == "ssm":
        scfg = cfg.ssm_cfg()
        def body(x, xs):
            lp, st_l = xs
            st = M2.SSMState(st_l["state"], jnp.zeros((B, 1), x.dtype))
            h, new_st = M2.mamba2_apply(lp["mamba"], rms_norm(x, lp["ln"]),
                                        scfg, st)
            return x + h, {"state": new_st.state}
        x, new_caches = jax.lax.scan(body, x, (params["layers"], state.caches))

    elif cfg.family == "hybrid":
        scfg = cfg.ssm_cfg()
        shared = params["shared_attn"]
        L = cfg.n_layers
        n_attn = max(1, L // cfg.attn_every)
        is_attn = (jnp.arange(L) % cfg.attn_every) == (cfg.attn_every - 1)
        attn_idx = jnp.clip(jnp.cumsum(is_attn.astype(jnp.int32)) - 1, 0, n_attn - 1)
        ssm_xs = {"state": state.caches["state"]}

        def body(carry, xs):
            x, kbuf, vbuf = carry
            lp, st_l, use_attn, ci = xs
            st = M2.SSMState(st_l["state"], jnp.zeros((B, 1), x.dtype))
            h, new_st = M2.mamba2_apply(lp["mamba"], rms_norm(x, lp["ln"]),
                                        scfg, st)
            x = x + h
            cache = A.KVCache(kbuf[ci], vbuf[ci], pos)
            def with_attn(op):
                x, kbuf, vbuf = op
                y, new_c = _dense_block(shared, x, cfg, positions, cache)
                return y, kbuf.at[ci].set(new_c.k), vbuf.at[ci].set(new_c.v)
            x, kbuf, vbuf = jax.lax.cond(
                use_attn, with_attn, lambda op: op, (x, kbuf, vbuf))
            return (x, kbuf, vbuf), {"state": new_st.state}

        (x, kbuf, vbuf), new_ssm = jax.lax.scan(
            body, (x, state.caches["k"], state.caches["v"]),
            (params["layers"], ssm_xs, is_attn, attn_idx))
        new_caches = {"state": new_ssm["state"], "k": kbuf, "v": vbuf}
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["ln_f"])
    logits = x @ params["unembed"]
    return logits, DecodeState(new_caches, state.position + 1)


def encode(params, frames: Array, cfg: ModelConfig) -> Array:
    """Whisper encoder forward (stub frontend: frames are embeddings)."""
    e = frames + params["enc_pos"][None, :frames.shape[1]]
    def ebody(e, lp):
        acfg = cfg.attn_cfg(causal=False, rope=False)
        h, _ = A.attn_apply(lp["attn"], rms_norm(e, lp["ln1"]), acfg)
        e = e + h
        e = e + mlp_apply(lp["mlp"], rms_norm(e, lp["ln2"]), "gelu")
        return e, None
    e, _ = jax.lax.scan(remat(ebody, cfg.remat_policy), e, params["enc_layers"])
    return rms_norm(e, params["enc_ln_f"])
