"""Mixture-of-Experts layer with per-group capacity dispatch (EP-native).

Design (rewritten in §Perf iteration moe-1, see EXPERIMENTS.md):

* capacity is **per batch row** (GShard-style groups), so every dispatch
  scatter is *local* to the data shard that owns the row — no cross-shard
  scatter, no giant global buffer;
* the dispatch buffer is (B, E, C, D) with B sharded over the data axes and
  E over "model" (expert parallelism).  The expert GEMMs are then fully
  local: device (i, j) processes batch shard i × expert shard j;
* the combine is a **scatter-add from buffer space to token space** (each
  slot knows its owning token), never a gather from the expert-sharded
  buffer.  GSPMD turns the sharded-updates scatter into local scatters plus
  one all-reduce of the (B, S, D) output — ~300× less wire than the
  all-reduce-of-buffers the gather formulation costs (77 TB → 0.24 TB per
  device per step for deepseek-v3 train_4k; §Perf).

kimi-k2 (384e, top-8) and deepseek-v3 (1 shared + 256 routed, top-8) both
route through this layer; the shared expert is a plain MLP added outside.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import (DATA, MODEL, dense_init, mlp_apply,
                                 shard_hint)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                  # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0          # deepseek-style always-on shared experts
    capacity_factor: float = 1.25
    router_dtype: jnp.dtype = jnp.float32


def moe_init(key, cfg: MoEConfig, dtype):
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e, dm, df = cfg.n_experts, cfg.d_model, cfg.d_ff
    params = {
        "router": dense_init(kr, dm, e, jnp.float32),
        "gate": jax.random.normal(kg, (e, dm, df), dtype) * (dm ** -0.5),
        "up": jax.random.normal(ku, (e, dm, df), dtype) * (dm ** -0.5),
        "down": jax.random.normal(kd, (e, df, dm), dtype) * (df ** -0.5),
    }
    # expert parallelism: the expert axis lives on MODEL so the (B, E, C, D)
    # dispatch buffer and the expert weights shard identically and the
    # per-expert GEMMs are communication-free.
    specs = {
        "router": P(None, None),
        "gate": P(MODEL, None, None),
        "up": P(MODEL, None, None),
        "down": P(MODEL, None, None),
    }
    if cfg.n_shared:
        params["shared"] = {
            "gate": dense_init(ks, dm, df * cfg.n_shared, dtype),
            "up": dense_init(kg, dm, df * cfg.n_shared, dtype),
            "down": dense_init(kd, df * cfg.n_shared, dm, dtype),
        }
        specs["shared"] = {"gate": P(None, MODEL), "up": P(None, MODEL),
                           "down": P(MODEL, None)}
    return params, specs


def _capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    c = -(-int(cfg.capacity_factor * tokens_per_group * cfg.top_k)
          // cfg.n_experts)
    if c >= 8:
        return -(-c // 8) * 8       # round up to 8 (MXU sublane alignment)
    return max(1, c)                # decode: S=1 rows — don't overpad 8×


def _dispatch_one(xt: Array, eids: Array, gate_w: Array, e: int, cap: int):
    """One group (S, D): scatter tokens into an (E, C, D) buffer.

    Returns (buf, tok_of_slot (E·C,), gate_of_slot (E·C,), keep_frac).
    Slots beyond capacity are dropped (sink row).
    """
    s, dm = xt.shape
    k = eids.shape[-1]
    flat_e = eids.reshape(-1)                                     # (S·k,)
    order = jnp.argsort(flat_e)                                   # stable
    sorted_e = flat_e[order]
    pos_in_sorted = jnp.arange(s * k) - jnp.searchsorted(sorted_e, sorted_e)
    pos = jnp.zeros_like(flat_e).at[order].set(pos_in_sorted)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)           # drop → sink

    tok_idx = jnp.repeat(jnp.arange(s), k)                        # (S·k,)
    buf = jnp.zeros((e * cap + 1, dm), xt.dtype).at[slot].set(xt[tok_idx])

    # slot-space inverse maps (for the scatter-based combine)
    tok_of_slot = jnp.full((e * cap + 1,), s, jnp.int32).at[slot].set(
        tok_idx.astype(jnp.int32))                                # sink → S
    gate_of_slot = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, gate_w.reshape(-1), 0.0).astype(jnp.float32))
    return (buf[:-1].reshape(e, cap, dm), tok_of_slot[:-1],
            gate_of_slot[:-1], keep)


def moe_apply(params, x: Array, cfg: MoEConfig):
    """x (B, S, D) → (B, S, D), plus aux losses dict.

    Capacity is per batch row: C = cf·S·top_k/E.
    """
    b, s, dm = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(s, cfg)

    logits = (x.astype(cfg.router_dtype) @ params["router"])      # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, eids = jax.lax.top_k(probs, k)                        # (B, S, k)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # --- per-row dispatch (local to each data shard) ------------------------
    buf, tok_of_slot, gate_of_slot, keep = jax.vmap(
        lambda xt, ei, gw: _dispatch_one(xt, ei, gw, e, cap))(x, eids, gate_w)
    # pin the (B→data, E→model) EP layout on the buffer and both GEMM
    # intermediates — without these hints GSPMD drops the batch sharding in
    # the backward pass and all-reduces replicated (E,F,B,C) cotangents
    # (§Perf iteration moe-3)
    buf = shard_hint(buf, "data", "model")
    tok_of_slot = shard_hint(tok_of_slot, "data", "model")
    gate_of_slot = shard_hint(gate_of_slot, "data", "model")

    # --- expert GEMMs: fully local under (B→data, E→model) sharding ---------
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["gate"]))
    h = h * shard_hint(jnp.einsum("becd,edf->becf", buf, params["up"]),
                       "data", "model")
    out_buf = shard_hint(
        jnp.einsum("becf,efd->becd", h, params["down"]), "data", "model")

    # --- combine: scatter-add slots → tokens (never gather the sharded buf).
    # updates are E-sharded; GSPMD emits local scatters + one all-reduce of
    # the (B, S, D) result.
    weighted = shard_hint(
        out_buf.reshape(b, e * cap, dm)
        * gate_of_slot.reshape(b, e * cap)[..., None].astype(out_buf.dtype),
        "data")

    def _combine_one(w_slots, toks):
        y_pad = jnp.zeros((s + 1, dm), w_slots.dtype).at[toks].add(w_slots)
        return y_pad[:s]

    y = jax.vmap(_combine_one)(weighted, tok_of_slot).astype(x.dtype)

    if cfg.n_shared:
        sp = params["shared"]
        y = y + mlp_apply(sp, x.reshape(b * s, dm)).reshape(b, s, dm)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(jax.nn.one_hot(eids, e, dtype=jnp.float32), axis=(0, 1, 2))
    ce = jnp.mean(probs, axis=(0, 1))
    aux = {"lb_loss": e * jnp.sum(me * ce),
           "drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return y, aux
