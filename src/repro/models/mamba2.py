"""Mamba2 (SSD — state-space duality) block, chunked-scan training +
constant-state decode.

Training uses the SSD chunked algorithm (arXiv:2405.21060 minimal form):
sequence split into chunks; intra-chunk terms are batched GEMMs (MXU food),
inter-chunk recurrence is a ``lax.scan`` over chunk states — the same
macro/micro-batch split FastMPS uses along the MPS chain (DESIGN.md §3).

Decode carries ``state (B, H, P, N)`` — the LM analogue of the MPS left
environment; ``long_500k`` works because this is O(1) in context length.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import DATA, MODEL, dense_init

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 128           # N
    d_head: int = 64             # P
    n_heads: int = 0             # H; 0 → 2·d_model/d_head (expand=2)
    n_groups: int = 1            # G (B/C groups, GQA-like)
    chunk: int = 128

    @property
    def heads(self) -> int:
        return self.n_heads or (2 * self.d_model // self.d_head)

    @property
    def d_inner(self) -> int:
        return self.heads * self.d_head


def mamba2_init(key, cfg: Mamba2Config, dtype):
    ks = jax.random.split(key, 6)
    dm, di, g, n = cfg.d_model, cfg.d_inner, cfg.n_groups, cfg.d_state
    h = cfg.heads
    params = {
        # fused input projection: [x (di), z gate (di), B (g·n), C (g·n), dt (h)]
        "w_in": dense_init(ks[0], dm, 2 * di + 2 * g * n + h, dtype),
        "w_out": dense_init(ks[1], di, dm, dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_g": jnp.ones((di,), dtype),
    }
    specs = {"w_in": P(None, MODEL), "w_out": P(MODEL, None),
             "A_log": P(None), "D": P(None), "dt_bias": P(None),
             "norm_g": P(MODEL)}
    return params, specs


class SSMState(NamedTuple):
    state: Array    # (B, H, P, N)
    conv: Array     # unused placeholder (conv frontend elided; kept for ckpt ABI)


def init_ssm_state(batch: int, cfg: Mamba2Config, dtype) -> SSMState:
    return SSMState(
        jnp.zeros((batch, cfg.heads, cfg.d_head, cfg.d_state), jnp.float32),
        jnp.zeros((batch, 1), dtype))


def _split_proj(z: Array, cfg: Mamba2Config):
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.heads
    x, zg, b, c, dt = jnp.split(z, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    return x, zg, b, c, dt


def _ssd_chunked(x, dt, a, b, c, cfg: Mamba2Config):
    """Minimal SSD. x (B,S,H,P); dt (B,S,H); a (H,)<0; b,c (B,S,G,N)."""
    B, S, H, Pd = x.shape
    G, N = b.shape[2], b.shape[3]
    L = min(cfg.chunk, S)
    while S % L:           # largest chunk ≤ cfg.chunk dividing S
        L -= 1
    nc = S // L
    rep = H // G

    # expand groups to heads
    bh = jnp.repeat(b, rep, axis=2)          # (B,S,H,N)
    ch = jnp.repeat(c, rep, axis=2)

    xc = x.reshape(B, nc, L, H, Pd)
    dtc = dt.reshape(B, nc, L, H)
    bc = bh.reshape(B, nc, L, H, N)
    cc = ch.reshape(B, nc, L, H, N)

    da = dtc * a[None, None, None, :]        # (B,nc,L,H)  log-decay increments
    cum = jnp.cumsum(da, axis=2)             # within-chunk cumulative
    seg_total = cum[:, :, -1]                # (B,nc,H)

    # intra-chunk (the "duality" quadratic term, causally masked)
    # decay(i←j) = exp(cum_i − cum_j) for i ≥ j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,nc,L,L,H)
    causal = jnp.tril(jnp.ones((L, L), bool))[None, None, :, :, None]
    # double-where: masked (acausal) entries have diff > 0 and exp(diff) can
    # overflow; zeroing diff first keeps both value and gradient finite.
    decay = jnp.where(causal, jnp.exp(jnp.where(causal, diff, 0.0)), 0.0)
    scores = jnp.einsum("bclhn,bckhn->bclkh", cc, bc) * decay    # (B,nc,L,L,H)
    y_intra = jnp.einsum("bclkh,bckh,bckhp->bclhp", scores, dtc, xc)

    # chunk input to state: sum_j exp(cum_last − cum_j)·dt_j·B_j ⊗ x_j
    in_decay = jnp.exp(seg_total[:, :, None, :] - cum)           # (B,nc,L,H)
    chunk_state = jnp.einsum("bclh,bclh,bclhn,bclhp->bchpn",
                             in_decay, dtc, bc, xc)              # (B,nc,H,P,N)

    # inter-chunk recurrence over nc
    def scan_fn(carry, inp):
        st_in = carry                                            # (B,H,P,N)
        cs, seg = inp                                            # (B,H,P,N), (B,H)
        st_out = st_in * jnp.exp(seg)[:, :, None, None] + cs
        return st_out, st_in

    init = jnp.zeros((B, H, Pd, N), x.dtype)
    final_state, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (chunk_state.swapaxes(0, 1), seg_total.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                     # (B,nc,H,P,N)

    # state-to-output within chunk: C_i · exp(cum_i) · state_prev
    out_decay = jnp.exp(cum)                                     # (B,nc,L,H)
    y_inter = jnp.einsum("bclhn,bclh,bchpn->bclhp", cc, out_decay, prev_states)

    y = (y_intra + y_inter).reshape(B, S, H, Pd)
    return y, final_state


def mamba2_apply(params, x: Array, cfg: Mamba2Config,
                 state: Optional[SSMState] = None):
    """x (B,S,D) → (B,S,D).  With ``state``: S must be 1 (decode step)."""
    B, S, dm = x.shape
    H, Pd, N, G = cfg.heads, cfg.d_head, cfg.d_state, cfg.n_groups

    z = x @ params["w_in"]
    xi, zg, b, c, dtr = _split_proj(z, cfg)
    xi = xi.reshape(B, S, H, Pd)
    b = b.reshape(B, S, G, N)
    c = c.reshape(B, S, G, N)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    a = -jnp.exp(params["A_log"])                                       # (H,) < 0

    if state is None:
        y, _ = _ssd_chunked(xi.astype(jnp.float32), dt, a,
                            b.astype(jnp.float32), c.astype(jnp.float32), cfg)
        new_state = None
    else:
        assert S == 1
        rep = H // G
        bh = jnp.repeat(b[:, 0], rep, axis=1)        # (B,H,N)
        ch = jnp.repeat(c[:, 0], rep, axis=1)
        dt0 = dt[:, 0]                               # (B,H)
        dec = jnp.exp(dt0 * a[None, :])              # (B,H)
        upd = jnp.einsum("bh,bhn,bhp->bhpn", dt0, bh, xi[:, 0].astype(jnp.float32))
        st = state.state * dec[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", ch, st)[:, None]              # (B,1,H,P)
        new_state = SSMState(st, state.conv)

    y = y + xi.astype(y.dtype) * params["D"][None, None, :, None]
    y = y.reshape(B, S, cfg.d_inner).astype(x.dtype)
    # gated RMS norm (Mamba2's norm-before-out)
    zg32 = jax.nn.silu(zg.astype(jnp.float32))
    y32 = y.astype(jnp.float32) * zg32
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype) * params["norm_g"]
    out = y @ params["w_out"]
    return out, new_state
