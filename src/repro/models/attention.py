"""Attention variants: GQA (opt. QKV bias), cross-attention, and MLA.

Decode uses a preallocated KV cache of ``cache_len`` with a scalar write
index — the FastMPS environment-carry pattern (DESIGN.md §3): the cache is
the LM's "left environment".  Head-type sharding: q/k/v/o projections are
split over the "model" axis on the head dimension; caches are sharded over
heads too, so decode TP matches the paper's χ-split.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import DATA, MODEL, apply_rope, dense_init

Array = jax.Array

# Route full-sequence attention through the Pallas flash kernel
# (kernels/flash_attention.py) — enabled on TPU backends by the launchers
# (§Perf iteration attn-1).  Decode steps (S=1, dynamic-length mask) and
# MLA keep the XLA path.
USE_FLASH = False


def set_flash(enabled: bool) -> None:
    global USE_FLASH
    USE_FLASH = enabled


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    causal: bool = True


def attn_init(key, cfg: AttnConfig, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, kvh, dh, dm = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    params = {
        "wq": dense_init(kq, dm, h * dh, dtype).reshape(dm, h, dh),
        "wk": dense_init(kk, dm, kvh * dh, dtype).reshape(dm, kvh, dh),
        "wv": dense_init(kv, dm, kvh * dh, dtype).reshape(dm, kvh, dh),
        "wo": dense_init(ko, h * dh, dm, dtype).reshape(h, dh, dm),
    }
    specs = {"wq": P(None, MODEL, None), "wk": P(None, MODEL, None),
             "wv": P(None, MODEL, None), "wo": P(MODEL, None, None)}
    if cfg.qkv_bias:
        params.update({
            "bq": jnp.zeros((h, dh), dtype), "bk": jnp.zeros((kvh, dh), dtype),
            "bv": jnp.zeros((kvh, dh), dtype)})
        specs.update({"bq": P(MODEL, None), "bk": P(MODEL, None),
                      "bv": P(MODEL, None)})
    return params, specs


class KVCache(NamedTuple):
    k: Array        # (B, cache_len, kvH, Dh)
    v: Array
    length: Array   # () int32 — tokens already in the cache


def init_kv_cache(batch: int, cache_len: int, cfg: AttnConfig, dtype) -> KVCache:
    shape = (batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def _sdpa(q: Array, k: Array, v: Array, mask: Optional[Array]) -> Array:
    """q (B,S,H,Dh), k/v (B,T,KVH,Dh) — GQA by head-group broadcast."""
    b, s, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    q = q.reshape(b, s, kvh, g, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    logits = logits / math.sqrt(dh)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, dh)


def attn_apply(params, x: Array, cfg: AttnConfig,
               positions: Optional[Array] = None,
               cache: Optional[KVCache] = None,
               kv_input: Optional[Array] = None):
    """Self/cross attention.

    * train/prefill: ``cache is None`` → full causal (or full, if not causal).
    * decode: ``cache`` given, x is (B, 1, D) → append & attend to prefix.
    * cross: ``kv_input`` given (B, T, D) → K/V from it, no causal mask.
    """
    b, s, dm = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    src = x if kv_input is None else kv_input
    k = jnp.einsum("btd,dhk->bthk", src, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", src, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]

    if positions is None:
        positions = jnp.arange(s)[None, :]
    if cfg.rope and kv_input is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and kv_input is None:
        # decode: write at cache.length, attend to [0, length]
        k_all = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), cache.length, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), cache.length, axis=1)
        t = cache.k.shape[1]
        valid = jnp.arange(t)[None, None, None, None, :] <= cache.length  # causal up to len
        out = _sdpa(q, k_all, v_all, valid)
        new_cache = KVCache(k_all, v_all, cache.length + s)
    else:
        if USE_FLASH:
            from repro.kernels.flash_attention import flash_attention
            out = flash_attention(q, k, v,
                                  causal=cfg.causal and kv_input is None)
        else:
            mask = None
            if cfg.causal and kv_input is None:
                mask = jnp.tril(jnp.ones((s, s), bool))[None, None, None, :, :]
            out = _sdpa(q, k, v, mask)

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return (y, new_cache) if cache is not None else (y, None)


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V3).  The KV cache stores the
# *compressed latent* (kv_lora_rank + rope dim) instead of per-head K/V —
# the paper's χ-compression idea applied to the cache.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    head_dim: int = 128          # nope head dim
    rope_head_dim: int = 64
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512


def mla_init(key, cfg: MLAConfig, dtype):
    ks = jax.random.split(key, 7)
    dm, h = cfg.d_model, cfg.n_heads
    dh, dr = cfg.head_dim, cfg.rope_head_dim
    params = {
        "wq_a": dense_init(ks[0], dm, cfg.q_lora_rank, dtype),
        "wq_b": dense_init(ks[1], cfg.q_lora_rank, h * (dh + dr), dtype
                           ).reshape(cfg.q_lora_rank, h, dh + dr),
        "wkv_a": dense_init(ks[2], dm, cfg.kv_lora_rank + dr, dtype),
        "wk_b": dense_init(ks[3], cfg.kv_lora_rank, h * dh, dtype
                           ).reshape(cfg.kv_lora_rank, h, dh),
        "wv_b": dense_init(ks[4], cfg.kv_lora_rank, h * dh, dtype
                           ).reshape(cfg.kv_lora_rank, h, dh),
        "wo": dense_init(ks[5], h * dh, dm, dtype).reshape(h, dh, dm),
    }
    specs = {"wq_a": P(None, None), "wq_b": P(None, MODEL, None),
             "wkv_a": P(None, None), "wk_b": P(None, MODEL, None),
             "wv_b": P(None, MODEL, None), "wo": P(MODEL, None, None)}
    return params, specs


class MLACache(NamedTuple):
    latent: Array     # (B, cache_len, kv_lora_rank + rope_dim)
    length: Array


def init_mla_cache(batch: int, cache_len: int, cfg: MLAConfig, dtype) -> MLACache:
    return MLACache(
        jnp.zeros((batch, cache_len, cfg.kv_lora_rank + cfg.rope_head_dim), dtype),
        jnp.zeros((), jnp.int32))


def mla_apply(params, x: Array, cfg: MLAConfig,
              positions: Optional[Array] = None,
              cache: Optional[MLACache] = None):
    b, s, dm = x.shape
    h, dh, dr, r = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim, cfg.kv_lora_rank
    if positions is None:
        positions = jnp.arange(s)[None, :]

    q = jnp.einsum("bsr,rhk->bshk", x @ params["wq_a"], params["wq_b"])
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = apply_rope(q_rope, positions)

    latent = x @ params["wkv_a"]                     # (B, S, r + dr)
    new_cache = None
    if cache is not None:
        lat_all = jax.lax.dynamic_update_slice_in_dim(
            cache.latent, latent.astype(cache.latent.dtype), cache.length, axis=1)
        t = cache.latent.shape[1]
        valid_len = cache.length
        latent_ctx = lat_all
        new_cache = MLACache(lat_all, cache.length + s)
        ctx_pos = jnp.arange(t)[None, :]
    else:
        latent_ctx = latent
        ctx_pos = positions
        t = s

    c_kv, k_rope_in = latent_ctx[..., :r], latent_ctx[..., r:]
    k_rope = apply_rope(k_rope_in[:, :, None, :], ctx_pos)[:, :, 0, :]

    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, params["wk_b"])
    v = jnp.einsum("btr,rhk->bthk", c_kv, params["wv_b"])

    logits = (jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
              + jnp.einsum("bshk,btk->bhst", q_rope, k_rope)).astype(jnp.float32)
    logits = logits / math.sqrt(dh + dr)
    if cache is not None:
        mask = jnp.arange(t)[None, None, None, :] <= cache.length
    else:
        mask = jnp.tril(jnp.ones((s, t), bool))[None, None, :, :]
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthk->bshk", w, v)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache
