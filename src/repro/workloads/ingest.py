"""BYO-MPS ingest: external site tensors → a sampling-ready GammaStore.

The rest of the framework assumes its own MPS form — uniform-χ stacked
``gammas (M, χ, χ, d)`` with the boundary row-0 convention and, for
``born`` semantics, tensors whose left-to-right conditionals are
normalized up to the per-site rescale.  External MPS (quantum-chemistry
DMRG output, a GBS covariance-matrix decomposition, another tensor
library's export) arrive as *ragged* chains ``[(D₀, D₁, d), (D₁, D₂, d),
…]`` with boundary dimensions 1 and no canonical form guarantee.

This module closes that gap:

* :func:`load_tensors` — accept a list of arrays or an ``.npz`` archive
  (sites in key-sorted order) and validate the chain structure: three
  axes per site, one physical dimension, matching bonds, boundary dims 1.
* :func:`canonicalize_born` — right-to-left QR sweep bringing a complex
  chain into right-canonical form (rows of ``A.reshape(Dl, Dr·d)``
  orthonormal), absorbing the R factors leftward and returning the state
  norm from site 0.  The sweep changes nothing physical — the sampled
  distribution is gauge-invariant — but it is what makes the per-site
  conditionals of Alg. 1 well-conditioned.
* :func:`isometry_errors` — the acceptance gate: per-site
  ``max |B B† − I|`` on the *ragged* tensors (before any χ padding, so
  zero-padded rows cannot mask a violation).  ``canonicalize=False``
  turns ingest into pure validation: a chain outside tolerance raises
  :class:`IngestError` instead of being silently re-gauged.
* :func:`build_mps` / :func:`ingest_mps` — embed the ragged chain into
  the uniform-χ form (each site placed at ``[:Dl, :Dr, :]``; the padding
  is exact, not approximate, because padded rows/columns are never
  reachable from the boundary row) and write it through
  :meth:`GammaStore.write_mps` + :meth:`write_digest_manifest`, so the
  ingested store is verified-I/O ready (PR 9) and result-cache
  addressable by digest from the first read.

``linear`` semantics (non-negative weights, the paper-faithful HMM mode)
has no gauge freedom to exploit — row re-normalization would change the
distribution — so ingest validates non-negativity and passes the weights
through unchanged.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Sequence

import numpy as np

__all__ = ["IngestError", "IngestReport", "build_mps", "canonicalize_born",
           "ingest_mps", "isometry_errors", "load_tensors"]


class IngestError(ValueError):
    """The external MPS failed structural or semantic validation."""


@dataclasses.dataclass(frozen=True)
class IngestReport:
    """What ingest did and how good the input was."""

    n_sites: int
    chi: int                       # uniform embedding dimension (max bond)
    d: int
    semantics: str
    canonicalized: bool
    norm: float                    # state norm absorbed at site 0 (born)
    max_isometry_error: float      # post-canonicalization residual (born)
    input_bytes: int               # raw tensor bytes ingested
    digest: Optional[str] = None   # store Merkle root (None: no store)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# -- loading & structural validation -----------------------------------------

def load_tensors(source) -> list[np.ndarray]:
    """External MPS → a validated ragged list of ``(Dl, Dr, d)`` arrays.

    ``source`` is a sequence of arrays or a path to an ``.npz`` archive
    whose arrays, in key-sorted order, are the site tensors (the order
    ``np.savez(path, *tensors)`` produces).  Raises :class:`IngestError`
    on any structural violation — wrong rank, mismatched physical or bond
    dimensions, non-trivial boundary bonds.
    """
    if isinstance(source, (str, os.PathLike)):
        with np.load(source) as z:
            keys = sorted(z.files)
            tensors = [np.asarray(z[k]) for k in keys]
    else:
        tensors = [np.asarray(t) for t in source]
    if not tensors:
        raise IngestError("empty MPS: no site tensors")
    for i, t in enumerate(tensors):
        if t.ndim != 3:
            raise IngestError(
                f"site {i}: expected a (Dl, Dr, d) tensor, got shape "
                f"{t.shape}")
    d = tensors[0].shape[2]
    for i, t in enumerate(tensors):
        if t.shape[2] != d:
            raise IngestError(
                f"site {i}: physical dimension {t.shape[2]} != {d} of "
                f"site 0 (the chain must share one physical dimension)")
    for i in range(len(tensors) - 1):
        if tensors[i].shape[1] != tensors[i + 1].shape[0]:
            raise IngestError(
                f"bond mismatch: site {i} right dim {tensors[i].shape[1]} "
                f"!= site {i + 1} left dim {tensors[i + 1].shape[0]}")
    if tensors[0].shape[0] != 1:
        raise IngestError(
            f"left boundary bond must be 1, got {tensors[0].shape[0]}")
    if tensors[-1].shape[1] != 1:
        raise IngestError(
            f"right boundary bond must be 1, got {tensors[-1].shape[1]}")
    return tensors


# -- canonical form -----------------------------------------------------------

def isometry_errors(tensors: Sequence[np.ndarray]) -> np.ndarray:
    """Per-site right-isometry residual ``max |B B† − I|`` with
    ``B = A.reshape(Dl, Dr·d)``, computed on the RAGGED tensors.

    Site 0 (Dl = 1) degenerates to ``| ‖A₀‖² − 1 |`` — the state-norm
    check.  Padding to uniform χ first would hide violations behind
    zero rows, which is why callers gate before embedding.
    """
    errs = np.empty(len(tensors))
    for i, t in enumerate(tensors):
        b = t.reshape(t.shape[0], -1)
        gram = b @ b.conj().T
        errs[i] = float(np.max(np.abs(gram - np.eye(t.shape[0]))))
    return errs


def canonicalize_born(tensors: Sequence[np.ndarray]
                      ) -> tuple[list[np.ndarray], float]:
    """Right-to-left QR sweep → (right-canonical ragged chain, norm).

    At each site i (last to first) the tensor's ``(Dr·d, Dl)``
    conjugate-transpose is QR-factored; Q† becomes the new site tensor
    (orthonormal rows by construction, possibly with a *smaller* left
    bond ``k = min(Dl, Dr·d)`` — rank truncation is exact here, no state
    change) and ``R†`` is absorbed into site i−1's right bond.  Site 0
    ends up carrying the whole state norm, which is divided out and
    returned.
    """
    out = [np.array(t, copy=True) for t in tensors]
    for i in range(len(out) - 1, 0, -1):
        a = out[i]
        dl, dr, d = a.shape
        b = a.reshape(dl, dr * d)
        q, r = np.linalg.qr(b.conj().T, mode="reduced")   # (Dr·d, k), (k, Dl)
        k = q.shape[1]
        out[i] = q.conj().T.reshape(k, dr, d)
        c = r.conj().T                                    # (Dl, k)
        out[i - 1] = np.einsum("lrs,rk->lks", out[i - 1], c)
    norm = float(np.linalg.norm(out[0]))
    if norm == 0.0:
        raise IngestError("zero-norm MPS: the state vanishes identically")
    out[0] = out[0] / norm
    return out, norm


# -- embedding ----------------------------------------------------------------

def _embed_uniform(tensors: Sequence[np.ndarray], dtype=None):
    """Ragged chain → uniform-χ stacked ``(M, χ, χ, d)`` gammas.

    Exact: each site occupies the top-left ``[:Dl, :Dr]`` block and the
    boundary row-0 convention of the samplers reaches only those blocks
    (the left env starts in row 0 = the Dl-1 boundary, and zero columns
    propagate zero weight)."""
    import jax.numpy as jnp
    chi = max(max(t.shape[0], t.shape[1]) for t in tensors)
    d = tensors[0].shape[2]
    dtype = dtype or np.result_type(*[t.dtype for t in tensors])
    g = np.zeros((len(tensors), chi, chi, d), dtype=dtype)
    for i, t in enumerate(tensors):
        g[i, :t.shape[0], :t.shape[1], :] = t
    real = np.zeros(0, dtype=dtype).real.dtype
    lam = np.ones((len(tensors), chi), dtype=real)
    return jnp.asarray(g), jnp.asarray(lam)


def build_mps(source, *, semantics: str = "born", canonicalize: bool = True,
              tol: float = 1e-6, lambdas=None):
    """External tensors → (framework :class:`~repro.core.mps.MPS`, report).

    born:   optionally canonicalize (right QR sweep), then gate on the
            per-site isometry residual — ``canonicalize=False`` rejects
            non-canonical input with :class:`IngestError` instead of
            fixing it.
    linear: validate non-negativity (no gauge freedom: re-normalizing
            rows would change the distribution); ``lambdas`` optionally
            supplies the per-site Λ vectors (default: ones).
    """
    from repro.core.mps import MPS
    tensors = load_tensors(source)
    input_bytes = sum(t.nbytes for t in tensors)
    norm = 1.0
    max_err = 0.0
    if semantics == "born":
        if lambdas is not None:
            raise IngestError("born ingest derives Λ = 1; the Schmidt "
                              "weights are absorbed into Γ by the QR sweep")
        if canonicalize:
            tensors, norm = canonicalize_born(tensors)
        errs = isometry_errors(tensors)
        max_err = float(errs.max())
        if max_err > tol:
            bad = int(errs.argmax())
            hint = ("QR sweep failed to converge — the input is "
                    "numerically degenerate" if canonicalize else
                    "pass canonicalize=True to re-gauge it")
            raise IngestError(
                f"site {bad} violates right-canonical form (isometry "
                f"residual {max_err:.3e} > tol {tol:.1e}); {hint}")
        g, lam = _embed_uniform(tensors)
    elif semantics == "linear":
        worst = min(float(np.min(t.real)) for t in tensors)
        if worst < -tol:
            raise IngestError(
                f"linear-semantics MPS must be non-negative; found entry "
                f"{worst:.3e} (a Born machine should ingest with "
                f"semantics='born')")
        if any(np.iscomplexobj(t) and np.abs(t.imag).max() > tol
               for t in tensors):
            raise IngestError("linear-semantics MPS must be real")
        tensors = [np.clip(t.real, 0.0, None) for t in tensors]
        g, lam = _embed_uniform(tensors)
        if lambdas is not None:
            lam = np.asarray(lam).copy()
            if len(lambdas) != len(tensors):
                raise IngestError(
                    f"{len(lambdas)} Λ vectors for {len(tensors)} sites")
            for i, l in enumerate(lambdas):
                l = np.asarray(l, dtype=lam.dtype)
                if l.ndim != 1 or l.shape[0] != tensors[i].shape[1]:
                    raise IngestError(
                        f"Λ[{i}] must be a ({tensors[i].shape[1]},) vector "
                        f"matching site {i}'s right bond, got {l.shape}")
                if float(l.min()) < -tol:
                    raise IngestError(f"Λ[{i}] has negative entries")
                lam[i, :l.shape[0]] = np.clip(l, 0.0, None)
            import jax.numpy as jnp
            lam = jnp.asarray(lam)
    else:
        raise IngestError(f"unknown semantics {semantics!r}")
    mps = MPS(g, lam, semantics)
    report = IngestReport(
        n_sites=mps.n_sites, chi=mps.chi, d=mps.phys_dim,
        semantics=semantics,
        canonicalized=bool(semantics == "born" and canonicalize),
        norm=norm, max_isometry_error=max_err, input_bytes=input_bytes)
    return mps, report


def ingest_mps(source, root: str, *, semantics: str = "born",
               canonicalize: bool = True, tol: float = 1e-6, lambdas=None,
               storage_dtype=None, compute_dtype=None):
    """The end-to-end ingest: validate → canonicalize → embed → persist.

    Returns ``(GammaStore, IngestReport)`` — the store is open (caller
    closes it), written with a digest manifest so every later read is
    verifiable (PR 9) and the serving gateway can cache results against
    ``report.digest`` immediately.

    Storage defaults follow the repo's §3.3.2 convention scaled to the
    input domain: two-byte bf16 for real chains, complex64 for complex
    ones (both halve the disk + broadcast bytes); pass full-width dtypes
    for a lossless round trip.
    """
    import jax.numpy as jnp

    from repro.data.gamma_store import GammaStore
    mps, report = build_mps(source, semantics=semantics,
                            canonicalize=canonicalize, tol=tol,
                            lambdas=lambdas)
    is_complex = np.issubdtype(np.asarray(mps.gammas).dtype, np.complexfloating)
    if storage_dtype is None:
        storage_dtype = jnp.complex64 if is_complex else jnp.bfloat16
    if compute_dtype is None:
        compute_dtype = jnp.complex128 if is_complex else jnp.float64
    store = GammaStore(root, storage_dtype=storage_dtype,
                       compute_dtype=compute_dtype)
    store.write_mps(mps)
    store.write_digest_manifest()
    report = dataclasses.replace(report, digest=store.digest())
    return store, report
