"""Workload subsystem: conditional sampling, BYO-MPS ingest, scenarios.

Three pillars over the execution stack (ROADMAP item 5):

- :mod:`repro.workloads.clamp` — the conditional/clamped-sampling spec
  carried on ``SamplerConfig.clamp`` through plan → engine → kernels;
  clamped sites force their outcome into the collapse path and the walk
  returns the clamped branch's Born weight as a per-sample ``log_prob``
  (exact marginals, rejection-free conditioning).
- :mod:`repro.workloads.ingest` — canonicalize an externally-trained MPS
  (site-tensor list or ``.npz`` bundle) into the repo's Γ/λ form,
  validate isometry, and write a digest-manifested ``GammaStore``.
- :mod:`repro.workloads.scenarios` — an eval-harness-style registry
  (build → sample → score) with each scenario emitting a reproducible
  ``BENCH.json`` row; driven by ``launch/scenarios.py``.

Only :mod:`.clamp` is imported eagerly: ``repro.api.config`` normalizes
clamp specs at config construction, and :mod:`.scenarios` imports the
api back — the lazy attributes below keep that cycle open.
"""
from repro.workloads.clamp import (ClampSpec, clamp_map, normalize_clamp,
                                   parse_clamp_arg, segment_clamp_arrays,
                                   validate_clamp)

__all__ = ["ClampSpec", "clamp_map", "ingest", "normalize_clamp",
           "parse_clamp_arg", "scenarios", "segment_clamp_arrays",
           "validate_clamp"]


def __getattr__(name):
    if name in ("ingest", "scenarios"):
        import importlib
        return importlib.import_module(f"repro.workloads.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
